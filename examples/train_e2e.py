"""End-to-end training driver: data pipeline -> offload-planned model ->
AdamW -> checkpoint/restart supervision -> straggler monitoring.

Default runs a ~20M-param llama-family model for 120 steps in a few minutes
on CPU; ``--full`` trains the ~100M config for 300 steps (same code path).

  PYTHONPATH=src python examples/train_e2e.py [--full] [--resume]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import block_offload_pass, default_db
from repro.core.frontends import module_frontend
from repro.data import Batcher, DataConfig, SyntheticLMDataset
from repro.models import build_model
from repro.models.plan import ExecPlan
from repro.optim import OptimizerConfig
from repro.optim.schedule import make_schedule
from repro.runtime.fault_tolerance import Supervisor
from repro.runtime.train import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params, 300 steps")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    base = get_config("tinyllama_1_1b")
    if args.full:  # ~100M params
        cfg = dataclasses.replace(base, n_layers=10, d_model=768, n_heads=12,
                                  n_kv_heads=4, head_dim=64, d_ff=2048,
                                  vocab=32_000)
        seq, gbs, steps = 256, 8, args.steps or 300
    else:          # ~20M params
        cfg = dataclasses.replace(base, n_layers=6, d_model=384, n_heads=6,
                                  n_kv_heads=2, head_dim=64, d_ff=1024,
                                  vocab=8_000)
        seq, gbs, steps = 128, 8, args.steps or 120

    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        model.param_shapes()))
    print(f"model: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab} "
          f"params={n_params/1e6:.1f}M")

    # offload plan from the pattern DB (block pass) — the paper's pipeline
    graph = module_frontend.build_graph(cfg)
    block = block_offload_pass(graph, default_db())
    plan = ExecPlan(compute_dtype="float32", attn_kv_chunk=128,
                    remat="none").replace(**block.plan_updates)
    print("offload plan:", {k: v for k, v in block.plan_updates.items()})

    data = SyntheticLMDataset(DataConfig(seq_len=seq, global_batch=gbs,
                                         vocab=cfg.vocab, seed=0))
    opt_cfg = OptimizerConfig(lr=1e-3, weight_decay=0.01)
    sched = make_schedule("cosine", peak_lr=1e-3, warmup_steps=20,
                          total_steps=steps)
    step_fn = jax.jit(make_train_step(model, plan, opt_cfg, sched),
                      donate_argnums=(0,))

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    state = init_train_state(model, jax.random.key(0))
    start = 0
    if args.resume and mgr.latest_step() is not None:
        start, state = mgr.restore(state)
        print(f"resumed from step {start}")

    def on_straggler(s, dt):
        print(f"  [straggler] step {s}: {dt*1e3:.0f}ms")

    sup = Supervisor(mgr, ckpt_every=25, on_straggler=on_straggler)
    batchers = [Batcher(data, start_step=start)]

    def batch_fn(s):
        bstep, batch = next(batchers[0])
        if bstep != s:  # restart rewound the step counter: re-seek prefetch
            batchers[0].close()
            batchers[0] = Batcher(data, start_step=s)
            bstep, batch = next(batchers[0])
        assert bstep == s, (bstep, s)
        return {k: jnp.asarray(v) for k, v in batch.items()}

    injector = None
    if args.inject_failure >= 0:
        hit = set()

        def injector(s):
            if s == args.inject_failure and s not in hit:
                hit.add(s)
                print(f"  [injected failure at step {s} — restoring]")
                return True
            return False

    t0 = time.time()
    losses = []

    def wrapped_step(state, batch):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if len(losses) % 10 == 0:
            rate = len(losses) / (time.time() - t0)
            print(f"step {start + len(losses):4d}  loss={losses[-1]:.4f}  "
                  f"({rate:.2f} steps/s)")
        return state, metrics

    state, report = sup.run(state, batch_fn, wrapped_step, n_steps=steps,
                            start_step=start, failure_injector=injector)
    batchers[0].close()
    print(f"\ndone: {report.steps_done} steps, {report.restarts} restarts, "
          f"{len(report.stragglers)} stragglers flagged")
    print(f"loss: {losses[0]:.4f} -> {np.mean(losses[-10:]):.4f}")
    assert np.mean(losses[-10:]) < losses[0]


if __name__ == "__main__":
    main()
