"""The paper's planner at production scale: GA over a model's offload sites
with COMPILED-ARTIFACT fitness on the 256-chip production mesh.

Every chromosome decodes to an ExecPlan, lowers + compiles the train step
(512 placeholder devices), and is scored by the roofline step time; plans
that exceed 16 GB/chip get fitness 0 (the compile-error analogue).  This is
`Offloader.plan` with the module frontend — function-block pass first, GA
over the remaining sites.

Runs a scaled-down architecture so each compile takes ~15 s on this CPU
container; the mechanics are identical for the full configs.

  PYTHONPATH=src python examples/plan_model_offload.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_cache")

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.frontends.registry import OffloadConfig
from repro.core.ga import GAConfig
from repro.core.offload import Offloader
from repro import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import lower_cell


def main():
    cfg = ArchConfig(arch_id="mini_dense", family="dense", n_layers=3,
                     d_model=512, n_heads=32, n_kv_heads=4, head_dim=16,
                     d_ff=1408, vocab=8000, mlp_act="silu",
                     tie_embeddings=False)
    shape = ShapeSpec("mini_train", 1024, 256, "train")
    mesh = make_production_mesh()
    n_active = cfg.param_count(active_only=True)
    model_flops = rl.model_flops_train(n_active, shape.tokens)

    def lower_fn(plan):
        lowered, _, _ = lower_cell(cfg, shape, mesh, plan)
        return lowered

    ocfg = OffloadConfig(
        frontend="module", ga=GAConfig(population=6, generations=2, seed=0),
        log=print,
        options={"lower_fn": lower_fn, "n_devices": mesh.size,
                 "model_flops": model_flops})
    res = Offloader(ocfg).plan(cfg)

    print("\n--- block pass (pattern DB) ---")
    for b in res.block.offloads:
        print(f"  {b.region}: {b.pattern} -> {b.plan_field}")
    print("\n--- GA over remaining sites ---")
    print("  sites:", [s.region for s in res.coding.sites])
    print("  best bits:", res.best.bits)
    base_t = res.baseline.time_s
    best_t = res.best.time_s
    print(f"\nbaseline (ref impls): {base_t*1e3:9.1f} ms/step (roofline est)")
    print(f"planned:              {best_t*1e3:9.1f} ms/step "
          f"-> {base_t/best_t:.2f}x")
    print("final plan:", {
        k: getattr(res.artifact, k)
        for k in ("attn_impl", "norm_impl", "mlp_impl", "qkv_fused",
                  "loss_impl", "remat", "gather_mode")})
    r = res.best.detail.get("roofline", {})
    if r:
        print(f"best-cell terms: compute={r['compute_s']*1e3:.1f}ms "
              f"memory={r['memory_s']*1e3:.1f}ms "
              f"collective={r['collective_s']*1e3:.1f}ms "
              f"dominant={r['dominant']}")


if __name__ == "__main__":
    main()
