"""The paper's scenario, end to end and for real: take a numeric Python
program written for CPU, automatically offload it.

  1. parse with `ast` (paper §3.3.2), extract loops + variables,
  2. function-block pass: pattern DB matches the naive matmul and DFT via
     Deckard-style similarity and replaces them with device libraries,
  3. GA loop pass over the remaining loops, wall-clock fitness with
     PCAST-style result verification,
  4. transfer plan: batched uploads hoisted out of interpreted loops.

  PYTHONPATH=src python examples/python_offload_demo.py
"""
import numpy as np

from repro.core.frontends.ast_frontend import PyProgram
from repro.core.frontends.registry import OffloadConfig
from repro.core.ga import GAConfig
from repro.core.offload import Offloader

SRC = """
def app(a, b, x, sig_re, sig_im, n, m, k, iters, fftn):
    c = np.zeros((n, m))
    for i in range(n):                      # naive O(n^3) matmul
        for j in range(m):
            acc = 0.0
            for t in range(k):
                acc = acc + a[i, t] * b[t, j]
            c[i, j] = acc
    out_re = np.zeros((fftn,))
    out_im = np.zeros((fftn,))
    for kk in range(fftn):                  # naive O(n^2) DFT
        sr = 0.0
        si = 0.0
        for t in range(fftn):
            ang = -2.0 * math.pi * kk * t / fftn
            sr = sr + sig_re[t] * math.cos(ang) - sig_im[t] * math.sin(ang)
            si = si + sig_re[t] * math.sin(ang) + sig_im[t] * math.cos(ang)
        out_re[kk] = sr
        out_im[kk] = si
    y = np.zeros((n,))
    for it in range(iters):                 # iterative vector update
        y = y + np.tanh(c @ x) * 0.1
    s = 0.0
    for i in range(n):                      # small scalar reduction
        s = s + y[i] * y[i]
    return c, y, s, out_re, out_im
"""


def main():
    consts = {"n": 24, "m": 24, "k": 24, "iters": 50, "fftn": 64}
    rng = np.random.default_rng(0)
    inputs = dict(a=rng.random((24, 24)), b=rng.random((24, 24)),
                  x=rng.random(24), sig_re=rng.random(64), sig_im=rng.random(64))

    program = PyProgram(SRC, consts=consts)
    print(f"parsed: {len(program.graph.regions)} regions, "
          f"{len(program.graph.loops())} loops")

    cfg = OffloadConfig(
        frontend="python_ast",
        ga=GAConfig(population=10, generations=5, seed=0),
        log=lambda s: print("  " + s))
    res = Offloader(cfg).plan(program, inputs)
    # claimed function blocks carry a bound library call; variant-site menus
    # (regions still in the gene) show up in res.pattern instead
    lib_calls = {r for r, e in res.details.get("lib_calls", {}).items()
                 if isinstance(e, dict) and "lib" in e}
    block_time_s = res.details.get("block_time_s", res.baseline.time_s)

    print("\n--- function-block offload (pattern DB) ---")
    for b in res.block.offloads:
        kept = "KEPT" if b.region in lib_calls else "rejected-by-measurement"
        print(f"  {b.region}: {b.pattern} via {b.how} (sim={b.score:.3f}) "
              f"-> {b.replacement} [{kept}]")

    print("\n--- GA loop offload ---")
    for h in res.ga.history:
        print(f"  gen {h['generation']}: best={h['best_time_s']*1e3:.2f}ms "
              f"mean={h['mean_time_s']*1e3:.2f}ms invalid={h['n_invalid']}")

    print("\n--- final pattern ---")
    for region, impl in sorted(res.pattern.items()):
        print(f"  {region}: {impl}")
    print(f"\nbaseline (all interpreted): {res.baseline.time_s*1e3:8.2f} ms")
    print(f"blocks only:                {block_time_s*1e3:8.2f} ms")
    print(f"final plan:                 {res.best.time_s*1e3:8.2f} ms")
    print(f"SPEEDUP: {res.speedup:.1f}x   "
          f"(transfers hoisted: {res.transfer_plan.n_hoisted})")


if __name__ == "__main__":
    main()
