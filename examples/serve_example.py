"""Batched serving example: prefill + KV-cache decode on an assigned arch.

  PYTHONPATH=src python examples/serve_example.py [--arch qwen3_0_6b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import REFERENCE_PLAN, build_model
from repro.runtime.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()   # reduced: runs on CPU
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    server = Server(model, params, REFERENCE_PLAN,
                    ServeConfig(max_new_tokens=args.max_new,
                                temperature=args.temperature))

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab,
                                    (args.batch, args.prompt_len)), jnp.int32)
    inputs = {"tokens": toks}
    if cfg.family == "encdec":
        inputs["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)
    if cfg.vision_patches:
        inputs["patch_feats"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.vision_patches, cfg.vision_dim)),
            jnp.bfloat16)

    t0 = time.time()
    out = server.generate(inputs)
    dt = time.time() - t0
    toks_total = args.batch * args.max_new
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"generated {toks_total} tokens in {dt:.2f}s "
          f"({toks_total/dt:.1f} tok/s incl. compile)")
    for i, row in enumerate(out.tolist()):
        print(f"  seq{i}: {row}")


if __name__ == "__main__":
    main()
