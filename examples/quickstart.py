"""Quickstart: build a model, let the unified offload pipeline pick
implementations, train a few steps, serve a few tokens.

The planner is one call for every frontend (`repro.core.offload.Offloader`):
here the *module* frontend plans an ArchConfig — the function-block pass
matches pattern-DB records, the GA searches the remaining offload sites, and
the returned artifact is the ExecPlan to train with.  The *jaxpr* frontend
goes further: its plan is **measured** — every chromosome becomes a
substituted program (kernel-registry variants spliced into the trace),
verified against the reference and wall-clock timed, and the artifact is
that runnable substituted callable.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import GAConfig, OffloadConfig, plan_offload
from repro.models import REFERENCE_PLAN, build_model
from repro.data import DataConfig, SyntheticLMDataset
from repro.optim import OptimizerConfig
from repro.optim.schedule import make_schedule
from repro.runtime.serve import ServeConfig, Server
from repro.runtime.train import init_train_state, make_train_step


def main():
    # 1. a reduced qwen3 (any of the 10 assigned archs works: --arch style)
    cfg = get_config("qwen3_0_6b").reduced()
    model = build_model(cfg)
    print(f"arch={cfg.arch_id} params={sum(x.size for x in jax.tree_util.tree_leaves(model.init(jax.random.key(0))))/1e6:.2f}M")

    # 2. unified offload planning: frontend detected from the target
    #    (ArchConfig -> module frontend; no lower_fn -> fast static-cost
    #    fitness.  Pass options={"lower_fn": ...} for AOT-compiled fitness.)
    res = plan_offload(cfg, config=OffloadConfig(
        ga=GAConfig(population=8, generations=4, seed=0)))
    plan = res.artifact.replace(compute_dtype="float32")
    print(f"planned via {res.frontend}: blocks="
          f"{[b.pattern for b in res.block.offloads]} "
          f"best={''.join(map(str, res.best.bits))} "
          f"destinations={res.destinations}")

    # 2b. measured jaxpr plan: a traced callable with an attention-shaped
    #     block — the plan's fitness is real wall-clock over substituted
    #     programs, and the artifact is the runnable winner
    def tiny_app(q, k, v, w):
        s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
        mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), bool))
        h = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1) @ v

        def body(c, _):
            return jnp.tanh(c @ w), ()

        h, _ = jax.lax.scan(body, h, None, length=4)
        return h

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 32)) * 0.1, jnp.float32)
    jres = plan_offload(tiny_app, config=OffloadConfig(
        ga=GAConfig(population=6, generations=3, seed=0),
        options={"example_args": (q, k, v, w)}, repeats=2))
    print(f"jaxpr plan: destinations={jres.destinations} "
          f"speedup={jres.speedup:.2f}x "
          f"verified={jres.verification['verified']} "
          f"substituted={jres.artifact.report.substituted}")
    _ = jres.artifact(q, k, v, w)            # the deliverable runs as-is

    # 2c. measured python_ast plan: the SAME variant alphabet on plain
    #     numeric Python — the matched loop nest keeps its gene, and the GA
    #     picks between the CPython interpreter and the kernel-registry
    #     variants (gpu_fused / gpu_pallas) by measured wall clock
    py_src = """
def rms_app(x, scale, n, d):
    out = np.zeros((n, d))
    for i in range(n):
        ss = 0.0
        for t in range(d):
            ss = ss + x[i][t] * x[i][t]
        inv = 1.0 / np.sqrt(ss / d + 1e-06)
        for t in range(d):
            out[i][t] = x[i][t] * inv * (1.0 + scale[t])
    return out
"""
    py_inputs = dict(x=rng.standard_normal((64, 32)),
                     scale=rng.standard_normal(32) * 0.1)
    pres = plan_offload(py_src, py_inputs, config=OffloadConfig(
        ga=GAConfig(population=6, generations=2, seed=0), repeats=1,
        options={"consts": {"n": 64, "d": 32}}))
    print(f"python_ast plan: destinations={pres.destinations} "
          f"speedup={pres.speedup:.2f}x "
          f"verified={pres.verification['verified']} "
          f"substituted={pres.report.substituted}")
    _ = pres.artifact.run(**py_inputs)       # runs under the chosen variant

    # 3. train a few steps under the planned ExecPlan
    data = SyntheticLMDataset(DataConfig(seq_len=64, global_batch=4,
                                         vocab=cfg.vocab, seed=0))
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(
        model, plan, OptimizerConfig(lr=3e-3, weight_decay=0.0),
        make_schedule("constant", peak_lr=3e-3, warmup_steps=1)))
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step(state, batch)
        if i % 3 == 0:
            print(f"step {i}: loss={float(metrics['loss']):.4f}")

    # 4. serve
    server = Server(model, state.params, REFERENCE_PLAN,
                    ServeConfig(max_new_tokens=8))
    toks = jnp.asarray(data.batch(0)["tokens"][:2, :16])
    out = server.generate({"tokens": toks})
    print("generated:", out.tolist())


if __name__ == "__main__":
    main()
