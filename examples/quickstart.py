"""Quickstart: build a model, let the unified offload pipeline pick
implementations, train a few steps, serve a few tokens.

The planner is one call for every frontend (`repro.core.offload.Offloader`):
here the *module* frontend plans an ArchConfig — the function-block pass
matches pattern-DB records, the GA searches the remaining offload sites, and
the returned artifact is the ExecPlan to train with.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import GAConfig, OffloadConfig, plan_offload
from repro.models import REFERENCE_PLAN, build_model
from repro.data import DataConfig, SyntheticLMDataset
from repro.optim import OptimizerConfig
from repro.optim.schedule import make_schedule
from repro.runtime.serve import ServeConfig, Server
from repro.runtime.train import init_train_state, make_train_step


def main():
    # 1. a reduced qwen3 (any of the 10 assigned archs works: --arch style)
    cfg = get_config("qwen3_0_6b").reduced()
    model = build_model(cfg)
    print(f"arch={cfg.arch_id} params={sum(x.size for x in jax.tree_util.tree_leaves(model.init(jax.random.key(0))))/1e6:.2f}M")

    # 2. unified offload planning: frontend detected from the target
    #    (ArchConfig -> module frontend; no lower_fn -> fast static-cost
    #    fitness.  Pass options={"lower_fn": ...} for AOT-compiled fitness.)
    res = plan_offload(cfg, config=OffloadConfig(
        ga=GAConfig(population=8, generations=4, seed=0)))
    plan = res.artifact.replace(compute_dtype="float32")
    print(f"planned via {res.frontend}: blocks="
          f"{[b.pattern for b in res.block.offloads]} "
          f"best={''.join(map(str, res.best.bits))} "
          f"destinations={res.destinations}")

    # 3. train a few steps under the planned ExecPlan
    data = SyntheticLMDataset(DataConfig(seq_len=64, global_batch=4,
                                         vocab=cfg.vocab, seed=0))
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(
        model, plan, OptimizerConfig(lr=3e-3, weight_decay=0.0),
        make_schedule("constant", peak_lr=3e-3, warmup_steps=1)))
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step(state, batch)
        if i % 3 == 0:
            print(f"step {i}: loss={float(metrics['loss']):.4f}")

    # 4. serve
    server = Server(model, state.params, REFERENCE_PLAN,
                    ServeConfig(max_new_tokens=8))
    toks = jnp.asarray(data.batch(0)["tokens"][:2, :16])
    out = server.generate({"tokens": toks})
    print("generated:", out.tolist())


if __name__ == "__main__":
    main()
