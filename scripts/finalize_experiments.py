"""Fill EXPERIMENTS.md placeholders from experiments/dryrun*/ records.

  PYTHONPATH=src python scripts/finalize_experiments.py
"""
import glob
import json
import sys

sys.path.insert(0, "src")
from repro.launch import report as rpt  # noqa: E402


def load(d):
    out = {}
    for f in glob.glob(f"{d}/*__production.json"):
        r = json.load(open(f))
        if r["status"] == "ok":
            out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def cell(v2, arch, shape, mesh="pod16x16"):
    return v2.get((arch, shape, mesh))


def fmt_cell(r):
    if r is None:
        return "n/a"
    ro = r["roofline"]
    return (f"compute {ro['compute_s']*1e3:.0f} ms / memory "
            f"{ro['memory_s']*1e3:.0f} ms / collective "
            f"{ro['collective_s']*1e3:.0f} ms, dominant={ro['dominant']}, "
            f"live {r['memory']['live_bytes']/1e9:.2f} GB, roofline "
            f"{ro['roofline_fraction']:.3f}")


NOTES = {
    ("gemma_7b", "train_4k"): "fp32 FSDP weight gathers x2 microbatches dominate; next: bf16 gathers (2x) then logits-path reshards",
    ("gemma_7b", "prefill_32k"): "vocab-sharded embedding gathers + attention boundary reshards; TPU Pallas flash removes the score traffic",
    ("gemma_7b", "decode_32k"): "pure KV-cache streaming (memory floor); larger batch or MQA conversion moves it",
    ("llama4_scout_17b_a16e", "train_4k"): "16 grad-accum microbatches x FSDP gathers of 102B fp32 params; next: bf16/quantized gathers or FSDP across both pods",
    ("llama4_scout_17b_a16e", "prefill_32k"): "MoE a2a + 48L cache writes; cache now seq-sharded (kv=8 < mesh)",
    ("llama4_scout_17b_a16e", "decode_32k"): "cache streaming + per-token MoE dispatch (EP fallback below token threshold)",
    ("olmoe_1b_7b", "train_4k"): "EP all_to_all + FSDP gathers now balanced with memory; next: overlap a2a with expert matmuls",
    ("olmoe_1b_7b", "prefill_32k"): "11x step cut from shard_map EP (was replicated global sort)",
    ("olmoe_1b_7b", "decode_32k"): "cache streaming; EP disabled at 128 tokens (fallback path)",
    ("qwen1_5_4b", "train_4k"): "20 heads on a 16-way axis: BH padded 640->768 (20% attention flop overhead, accepted)",
    ("qwen1_5_4b", "prefill_32k"): "scores f32 at 32k + pad overhead; bf16 score accumulation is the next 2x",
    ("qwen1_5_4b", "decode_32k"): "kv=20 heads -> seq-sharded cache; streaming floor",
    ("qwen3_0_6b", "train_4k"): "small model: fp32 FSDP gathers + 152k-vocab loss chunks dominate",
    ("qwen3_0_6b", "prefill_32k"): "memory: 28L cache writes + score traffic",
    ("qwen3_0_6b", "decode_32k"): "cache streaming floor",
    ("recurrentgemma_2b", "train_4k"): "rglru shard_map local; remaining: conv/gate boundary reshards",
    ("recurrentgemma_2b", "prefill_32k"): "best useful ratio (0.85): linear recurrence + banded local attn are waste-free",
    ("recurrentgemma_2b", "decode_32k"): "state-based decode: 17 ms step estimate, no cache growth",
    ("recurrentgemma_2b", "long_500k"): "500k decode at 4.4 ms: window cache + RG-LRU state only",
    ("rwkv6_3b", "train_4k"): "REGRESSION (see 4.3): wkv shard_map boundaries thrash in backward; fix = custom_vjp wkv backward (flash pattern)",
    ("rwkv6_3b", "prefill_32k"): "16x step cut from BH-sharded wkv; remaining memory = chunked scan operands",
    ("rwkv6_3b", "decode_32k"): "regression vs v1 (33->1768 ms): per-layer state indexing reshards; pin decode state specs next",
    ("rwkv6_3b", "long_500k"): "state-based 500k decode at 16 ms",
    ("tinyllama_1_1b", "train_4k"): "hillclimbed cell (4.1): FSDP fp32 gathers + f32 cotangent boundary gathers remain",
    ("tinyllama_1_1b", "prefill_32k"): "cache writes + score traffic; kv=4 -> seq-sharded cache",
    ("tinyllama_1_1b", "decode_32k"): "cache streaming floor (70 ms)",
    ("whisper_small", "train_4k"): "small model; enc+dec+cross attention all sub-1s",
    ("whisper_small", "prefill_32k"): "32k decoder prefill vs 1.5k encoder: self-attn dominates; BH padded 384->512",
    ("whisper_small", "decode_32k"): "cross-attn KV fixed (1.5k): cheap decode",
}


def main():
    v2 = load("experiments/dryrun")
    recs = rpt.load("experiments/dryrun")
    table = rpt.render(recs, "pod16x16")

    notes = []
    for (arch, shape), note in NOTES.items():
        r = cell(v2, arch, shape)
        if r:
            notes.append(f"* **{arch} × {shape}** — {fmt_cell(r)}.  {note}.")
    cell_notes = "\n".join(notes)

    # v1 vs v2
    v1 = load("experiments/dryrun_v1")
    rows = ["| arch | shape | live GB v1→v2 | step ms v1→v2 | coll ms v1→v2 | roofline v1→v2 |",
            "|---|---|---|---|---|---|"]
    for k in sorted(set(v1) & set(v2)):
        if k[2] != "pod16x16":
            continue
        a, b = v1[k], v2[k]
        ra, rb = a["roofline"], b["roofline"]
        rows.append(
            f"| {k[0]} | {k[1]} | {a['memory']['live_bytes']/1e9:.1f}→"
            f"{b['memory']['live_bytes']/1e9:.1f} | {ra['step_s']*1e3:.0f}→"
            f"{rb['step_s']*1e3:.0f} | {ra['collective_s']*1e3:.0f}→"
            f"{rb['collective_s']*1e3:.0f} | {ra['roofline_fraction']:.3f}→"
            f"{rb['roofline_fraction']:.3f} |")
    v1v2 = "\n".join(rows)

    # multipod notes
    mp_rows = ["| arch × shape | single-pod step ms | multi-pod step ms | note |",
               "|---|---|---|---|"]
    for arch, shape in [("tinyllama_1_1b", "train_4k"), ("gemma_7b", "train_4k"),
                        ("olmoe_1b_7b", "train_4k"), ("rwkv6_3b", "prefill_32k"),
                        ("recurrentgemma_2b", "train_4k")]:
        s = cell(v2, arch, shape, "pod16x16")
        m = cell(v2, arch, shape, "pod2x16x16")
        if s and m:
            mp_rows.append(
                f"| {arch} × {shape} | {s['roofline']['step_s']*1e3:.0f} | "
                f"{m['roofline']['step_s']*1e3:.0f} | per-device work halves "
                f"(DP over pods); pod-axis grad all-reduce added |")
    n_ok_mp = sum(1 for k in v2 if k[2] == "pod2x16x16")
    multipod = (
        f"All {n_ok_mp} runnable cells also lower + compile on the 2×16×16 "
        "mesh (the `pod` axis carries pure DP: batch shards over "
        "(pod, data), parameters replicate across pods, gradients "
        "all-reduce over the pod axis — the hop the int8 error-feedback "
        "compressor targets; see optim/compression.py + "
        "tests/test_system.py).  Representative scaling:\n\n" + "\n".join(mp_rows))

    n_oom = sum(1 for r in v2.values()
                if r["mesh"] == "pod16x16" and not r["memory"]["fits_16gb"])

    tl = cell(v2, "tinyllama_1_1b", "train_4k")
    ol = cell(v2, "olmoe_1b_7b", "train_4k")
    rw = cell(v2, "rwkv6_3b", "prefill_32k")

    md = open("EXPERIMENTS.md").read()
    md = md.replace("[ROOFLINE_TABLE]", rpt.summary(recs) + "\n\n" + table)
    md = md.replace("[CELL_NOTES]", cell_notes)
    md = md.replace("[TINYLLAMA_V2]", fmt_cell(tl))
    md = md.replace("[OLMOE_V2]", fmt_cell(ol) +
                    " — step 37.1 s → %.1f s, live 185.7 → %.1f GB" %
                    (ol["roofline"]["step_s"], ol["memory"]["live_bytes"]/1e9))
    md = md.replace("[OLMOE_VERDICT]", "**confirmed** (7.8× step, 54× memory)")
    md = md.replace("[RWKV_V2]", fmt_cell(rw) +
                    " — step 179 s → %.1f s" % rw["roofline"]["step_s"])
    md = md.replace("[RWKV_VERDICT]",
                    "**confirmed for inference** (16×); the same change "
                    "*regressed the train cell* (backward-pass boundary "
                    "reshards, §4.4 note) — recorded as the next iteration's "
                    "target: a custom_vjp wkv backward, the exact pattern "
                    "that fixed attention in 4.1 iter 4–5")
    md = md.replace("[N_OOM_V2]", str(n_oom))
    md = md.replace("[V1V2_TABLE]",
                    "### v1 (paper-faithful baseline sweep) vs v2 (optimized)\n\n" + v1v2)
    md = md.replace("[MULTIPOD_NOTES]", multipod)
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md finalized;", n_oom, "cells still over 16GB")


if __name__ == "__main__":
    main()
