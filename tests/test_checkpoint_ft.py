"""Checkpointing + fault tolerance: atomic commits, resume, supervised
restart on injected failures, straggler detection, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import StragglerMonitor, Supervisor, reshard


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "b": jnp.zeros((8,)),
            "nested": {"step": jnp.asarray(3, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = _tree()
    mgr.save(10, t)
    step, t2 = mgr.restore(t)
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [3, 4]


def test_async_save_with_donated_source(tmp_path):
    """save() snapshots host-side before returning, so the caller may reuse
    (donate) the buffers immediately."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    t = _tree()
    mgr.save(5, t)
    mgr.wait()
    _, t2 = mgr.restore(t)
    np.testing.assert_array_equal(np.asarray(t["w"]), np.asarray(t2["w"]))


def test_crash_mid_save_leaves_last_commit(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _tree(1))
    # simulate a crashed partial save: a .tmp dir without manifest commit
    os.makedirs(tmp_path / ".tmp_step_2")
    (tmp_path / ".tmp_step_2" / "arr_0.npy").write_bytes(b"junk")
    assert mgr.latest_step() == 1
    step, _ = mgr.restore(_tree(1))
    assert step == 1


def test_supervisor_recovers_from_injected_failures(tmp_path):
    """A 30-step run with failures at steps 7 and 19 completes with 2
    restarts and the same final state as a failure-free run."""
    def step_fn(state, batch):
        new = {"x": state["x"] + batch["v"]}
        return new, {"loss": float(np.sum(np.asarray(new["x"])))}

    def batch_fn(step):
        return {"v": jnp.ones((2,)) * (step + 1)}

    def run(inject):
        mgr = CheckpointManager(str(tmp_path / ("a" if inject else "b")),
                                keep=3, async_save=False)
        sup = Supervisor(mgr, ckpt_every=5, max_restarts=5)
        failed = set()

        def injector(step):
            if inject and step in (7, 19) and step not in failed:
                failed.add(step)
                return True
            return False
        state = {"x": jnp.zeros((2,))}
        return sup.run(state, batch_fn, step_fn, n_steps=30,
                       failure_injector=injector)

    s1, rep1 = run(True)
    s2, rep2 = run(False)
    assert rep1.restarts == 2 and rep2.restarts == 0
    np.testing.assert_allclose(np.asarray(s1["x"]), np.asarray(s2["x"]))


def test_supervisor_nan_loss_triggers_restart(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:
            return state, {"loss": float("nan")}
        return {"x": state["x"] + 1}, {"loss": 1.0}

    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    sup = Supervisor(mgr, ckpt_every=2, max_restarts=3)
    state, rep = sup.run({"x": jnp.zeros(())}, lambda s: {}, step_fn, n_steps=6)
    assert rep.restarts == 1
    assert float(state["x"]) == 6


def test_straggler_monitor_flags_slow_step():
    mon = StragglerMonitor(warmup=3)
    for i in range(10):
        assert not mon.observe(i, 0.10 + 0.001 * (i % 2))
    assert mon.observe(10, 0.55)       # 5x normal
    assert not mon.observe(11, 0.101)  # estimate not poisoned by the outlier


def test_elastic_reshard_restores_full_arrays(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = _tree()
    mgr.save(7, t)
    # "new mesh": plain single-device shardings (None = default placement)
    step, t2 = reshard(mgr, t, new_shardings=None)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(t["w"]), np.asarray(t2["w"]))
