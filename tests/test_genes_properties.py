"""Property-based gene-coding tests over *arbitrary* destination alphabets.

The shipped alphabets (binary, extended, variant) are three points in the
space the encoding must cover; these properties hold for any alphabet built
from registered destinations: decode totality + ``impl_index`` clamping on
sites with short implementation menus, decode/encode (``destinations_of``)
round-trip, cross-alphabet seed-value mapping, and phenotype-key
consistency (decode-equivalent chromosomes share a key).

Property tests run under hypothesis (via ``tests/_hypothesis_compat``,
skipping cleanly on bare environments); the example-based sections at the
bottom always run.
"""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.genes import (Destination, GeneCoding, Site,
                              coding_from_graph, get_destination,
                              register_destination)
from repro.core.ir import Region, RegionGraph
from repro.core.offload import _map_destination_value, phenotype_key

# a pool of synthetic executable destinations covering impl_index 0..4, so
# alphabets are *arbitrary*, not just the three shipped ones
for _i in range(5):
    try:
        register_destination(Destination(f"xdev{_i}", executable=True,
                                         impl_index=_i))
    except ValueError:
        pass                       # already registered by a previous import
try:
    register_destination(Destination("xstub", executable=False, impl_index=0,
                                     launch_overhead_s=1e-4))
except ValueError:
    pass

# mesh wire names are first-class alphabet entries (Destination v2): the
# properties must hold with them mixed in
ALPHA_POOL = ("cpu", "gpu", "fpga_stub", "gpu_fused", "gpu_pallas", "xstub",
              "xdev0", "xdev1", "xdev2", "xdev3", "xdev4",
              "mesh:data:4:batch", "mesh:model:2:feature")


def _sites(extra_counts):
    return tuple(
        Site(f"r{i}", "ref", "off",
             tuple(f"e{i}_{j}" for j in range(k)))
        for i, k in enumerate(extra_counts))


alphabets = st.lists(st.sampled_from(ALPHA_POOL), min_size=2, max_size=6,
                     unique=True).map(tuple)
site_menus = st.lists(st.integers(0, 3), min_size=1, max_size=5)


@given(alphabet=alphabets, extras=site_menus, data=st.data())
@settings(max_examples=60, deadline=None)
def test_decode_is_total_and_clamps(alphabet, extras, data):
    coding = GeneCoding(_sites(extras), alphabet)
    values = data.draw(st.lists(st.integers(0, coding.arity - 1),
                                min_size=coding.length,
                                max_size=coding.length))
    decoded = coding.decode(values)
    for s, v in zip(coding.sites, values):
        dest = get_destination(alphabet[v])
        impls = s.impls
        # clamping: an impl_index beyond the menu selects the last impl,
        # and decode never raises or invents an implementation
        assert decoded[s.region] == impls[min(dest.impl_index,
                                              len(impls) - 1)]
        assert decoded[s.region] in impls


@given(alphabet=alphabets, extras=site_menus, data=st.data())
@settings(max_examples=60, deadline=None)
def test_destinations_of_roundtrips_values(alphabet, extras, data):
    coding = GeneCoding(_sites(extras), alphabet)
    values = data.draw(st.lists(st.integers(0, coding.arity - 1),
                                min_size=coding.length,
                                max_size=coding.length))
    names = coding.destinations_of(values)
    # encode(decode) round-trip: unique alphabets map names back to values
    assert [alphabet.index(names[s.region]) for s in coding.sites] == values


@given(alphabet=alphabets, rec=st.lists(st.sampled_from(ALPHA_POOL),
                                        min_size=0, max_size=6).map(tuple),
       value=st.integers(-3, 9))
@settings(max_examples=80, deadline=None)
def test_cross_alphabet_seed_mapping_is_total(alphabet, rec, value):
    coding = GeneCoding(_sites([1]), alphabet)
    mapped = _map_destination_value(value, rec, coding)
    assert 0 <= mapped < coding.arity, "mapped seed must be a legal gene"
    if not rec:
        assert mapped == min(max(value, 0), coding.arity - 1)
    elif 0 <= value < len(rec):
        name = rec[value]
        if name in alphabet:
            assert mapped == alphabet.index(name)       # name-faithful
        elif value == 0:
            assert mapped == 0                          # ref stays ref
        else:
            assert mapped == (1 if coding.arity > 1 else 0)
    else:
        assert mapped == 0                              # corrupt record


@given(alphabet=alphabets, extras=site_menus, data=st.data())
@settings(max_examples=60, deadline=None)
def test_phenotype_key_matches_decode_equivalence(alphabet, extras, data):
    coding = GeneCoding(_sites(extras), alphabet)
    key = phenotype_key(coding)
    draw = lambda: tuple(data.draw(st.lists(  # noqa: E731
        st.integers(0, coding.arity - 1), min_size=coding.length,
        max_size=coding.length)))
    v1, v2 = draw(), draw()

    def pheno(values):
        return (tuple(sorted(coding.decode(values).items())),
                tuple((s.region, alphabet[v])
                      for s, v in zip(coding.sites, values)
                      if get_destination(alphabet[v]).placement_tag
                      is not None))

    assert (key(v1) == key(v2)) == (pheno(v1) == pheno(v2))


# ---------------------------------------------------------------------------
# example-based anchors (always run, hypothesis or not)
# ---------------------------------------------------------------------------


def _graph():
    return RegionGraph([
        Region("two", "loop", offloadable=True,
               alternatives=("ref", "kernel")),
        Region("three", "loop", offloadable=True,
               alternatives=("ref", "fused_jnp", "pallas")),
    ], "ir", "props")


def test_clamped_impl_index_aliases_to_last_impl():
    coding = coding_from_graph(_graph(),
                               destinations=("cpu", "gpu_fused",
                                             "gpu_pallas"))
    d1, d2 = coding.decode((1, 1)), coding.decode((2, 2))
    assert d1["two"] == d2["two"] == "kernel"       # clamped on the 2-menu
    assert d1["three"] == "fused_jnp" and d2["three"] == "pallas"


def test_phenotype_key_equates_clamped_chromosomes_only():
    coding = coding_from_graph(_graph(),
                               destinations=("cpu", "gpu_fused",
                                             "gpu_pallas"))
    key = phenotype_key(coding)
    assert key((1, 0)) == key((2, 0)), "clamped genes decode identically"
    assert key((0, 1)) != key((0, 2)), "real variants stay distinct"
    assert key((0, 0)) != key((1, 0))


def test_phenotype_key_separates_cost_only_parking():
    coding = coding_from_graph(_graph(),
                               destinations=("cpu", "gpu", "fpga_stub"))
    key = phenotype_key(coding)
    # both decode to the reference impl, but the stub charges modeled cost:
    # different phenotype, different measurement
    assert key((0, 0)) != key((2, 0))


def test_foreign_bits_never_crash_phenotype_key():
    coding = coding_from_graph(_graph())
    key = phenotype_key(coding)
    assert key((1,)) == ("raw", (1,))        # stale persisted line


# ---------------------------------------------------------------------------
# function-block genes: claiming semantics
# ---------------------------------------------------------------------------


def _block_graph():
    return RegionGraph([
        Region("a", "loop", offloadable=True,
               alternatives=("ref", "kernel"), trip_count=8),
        Region("b", "loop", offloadable=True,
               alternatives=("ref", "fused_jnp", "pallas"), trip_count=8),
        Region("c", "loop", offloadable=True,
               alternatives=("ref", "kernel"), trip_count=8),
        Region("blk", "block", offloadable=True,
               alternatives=("ref", "block_chunked", "block_fused"),
               meta={"block_members": ("a", "b")}),
    ], "ir", "block-props")


def test_coding_from_graph_carries_block_members():
    coding = coding_from_graph(_block_graph())
    by_region = {s.region: s for s in coding.sites}
    assert by_region["blk"].members == ("a", "b")
    assert all(not s.members for r, s in by_region.items() if r != "blk")


def test_active_block_gene_claims_members_to_ref():
    coding = coding_from_graph(_block_graph(),
                               destinations=("cpu", "gpu_fused",
                                             "gpu_pallas"))
    order = [s.region for s in coding.sites]
    values = tuple(1 for _ in order)            # everything on, block too
    assert coding.claimed_members(values) == frozenset({"a", "b"})
    decoded = coding.decode(values)
    # claimed members are inert — forced to their reference path even
    # though their own genes are on
    assert decoded["a"] == "ref" and decoded["b"] == "ref"
    assert decoded["c"] == "kernel"             # unclaimed keeps its gene
    assert decoded["blk"] == "block_chunked"


def test_inactive_block_gene_claims_nothing():
    coding = coding_from_graph(_block_graph(),
                               destinations=("cpu", "gpu_fused",
                                             "gpu_pallas"))
    order = [s.region for s in coding.sites]
    values = tuple(0 if r == "blk" else 1 for r in order)
    assert coding.claimed_members(values) == frozenset()
    decoded = coding.decode(values)
    assert decoded == {"a": "kernel", "b": "fused_jnp", "c": "kernel",
                       "blk": "ref"}


def test_phenotype_key_ignores_claimed_member_genes():
    coding = coding_from_graph(_block_graph(),
                               destinations=("cpu", "gpu_fused",
                                             "gpu_pallas"))
    key = phenotype_key(coding)
    order = [s.region for s in coding.sites]

    def chrom(**genes):
        return tuple(genes.get(r, 0) for r in order)

    # with the block gene active, the members' own genes cannot change the
    # program — one phenotype, one measurement
    assert key(chrom(blk=1)) == key(chrom(blk=1, a=1, b=2))
    # with the block gene off they are live again
    assert key(chrom()) != key(chrom(a=1))
    # and block on vs off is of course a different program
    assert key(chrom(blk=1)) != key(chrom())


def test_modeled_cost_skips_claimed_members():
    from repro.core.genes import modeled_cost_s
    graph = _block_graph()
    coding = coding_from_graph(graph,
                               destinations=("cpu", "gpu_fused",
                                             "fpga_stub"))
    order = [s.region for s in coding.sites]
    stub_a = tuple({"a": 2}.get(r, 0) for r in order)
    cost_alone = modeled_cost_s(graph, coding, stub_a)
    assert cost_alone > 0.0
    # parking a *claimed* member on the stub charges nothing: the block
    # adapter computes it, the stub never runs
    stub_a_claimed = tuple({"a": 2, "blk": 1}.get(r, 0) for r in order)
    assert modeled_cost_s(graph, coding, stub_a_claimed) == 0.0


@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_block_claiming_property_members_always_ref(data):
    coding = coding_from_graph(_block_graph(),
                               destinations=("cpu", "gpu_fused",
                                             "gpu_pallas"))
    values = tuple(data.draw(st.lists(st.integers(0, coding.arity - 1),
                                      min_size=coding.length,
                                      max_size=coding.length)))
    decoded = coding.decode(values)
    claimed = coding.claimed_members(values)
    for region in claimed:
        site = next(s for s in coding.sites if s.region == region)
        assert decoded[region] == site.ref_impl
    blk = next(s for s in coding.sites if s.region == "blk")
    if decoded["blk"] != blk.ref_impl:
        assert claimed == frozenset(blk.members)
    else:
        assert claimed == frozenset()


@pytest.mark.parametrize("value,rec,expect", [
    (1, ("cpu", "gpu"), 1),                  # same alphabet
    (1, ("cpu", "fpga_stub"), 1),            # offloaded name missing -> slot 1
    (0, ("cpu", "gpu"), 0),                  # ref stays ref
    (5, ("cpu", "gpu"), 0),                  # corrupt record
    (3, (), 1),                              # legacy clamp
    (-2, (), 0),                             # legacy clamp, lower bound
])
def test_map_destination_value_examples(value, rec, expect):
    coding = coding_from_graph(_graph())     # binary cpu/gpu
    assert _map_destination_value(value, rec, coding) == expect


# ---------------------------------------------------------------------------
# mesh destinations (Destination v2)
# ---------------------------------------------------------------------------


def test_mesh_wire_roundtrip():
    from repro.core.genes import Destination, MeshDestination

    d = MeshDestination(axis="data", n=4)
    assert d.name == d.wire() == "mesh:data:4:batch"
    assert d.device_count == 4 and d.shard_dim == 0
    assert MeshDestination.from_wire(d.wire()) == d
    assert Destination.from_wire(d.wire()) == d       # base-class entry too
    # model axis defaults to a feature-dim spec
    m = MeshDestination(axis="model", n=2)
    assert m.name == "mesh:model:2:feature" and m.shard_dim == -1
    assert get_destination("mesh:model:2:feature") == m
    # explicit dim specs parse
    k = MeshDestination.from_wire("mesh:data:2:dim1")
    assert k.shard_dim == 1


@pytest.mark.parametrize("wire", [
    "mesh:diag:2:batch",        # unknown axis
    "mesh:data:0:batch",        # no devices
    "mesh:data:two:batch",      # non-integer n
    "mesh:data:2:cols",         # unknown spec
    "mesh:data",                # too few fields
])
def test_mesh_bad_wire_raises(wire):
    from repro.core.genes import MeshDestination

    with pytest.raises(ValueError):
        MeshDestination.from_wire(wire)
    with pytest.raises(KeyError):
        get_destination(wire)


def test_mesh_gene_decodes_to_ref_and_tags_phenotype():
    coding = coding_from_graph(_graph(),
                               destinations=("cpu", "gpu",
                                             "mesh:data:4:batch"))
    # a mesh gene never invents an implementation: the decoded impl map is
    # the reference path (the frontend realizes sharding, or the cost model
    # charges it)
    decoded = coding.decode((2, 2))
    assert decoded == {"two": "ref", "three": "ref"}
    key = phenotype_key(coding)
    # ...but placement changes the phenotype: all-ref, stub-parked and
    # mesh-placed chromosomes are three different programs
    assert key((0, 0)) != key((2, 0))
    assert key((2, 0)) != key((2, 2))


def test_mesh_modeled_cost_charged_unless_executed():
    from repro.core import genes
    from repro.core.genes import modeled_cost_s, probed_device_count

    graph = _graph()
    coding = coding_from_graph(graph,
                               destinations=("cpu", "gpu",
                                             "mesh:data:4:batch"))
    mesh_bits = (2, 2)
    # on a single-device host the mesh is cost-only: positive modeled charge
    assert probed_device_count() < 4
    assert modeled_cost_s(graph, coding, mesh_bits) > 0.0
    assert modeled_cost_s(graph, coding, (0, 0)) == 0.0
    # a fitness that genuinely shard_maps (mesh_executed=True) on a host
    # that has the devices is not double-charged
    old = genes._PROBED_DEVICE_COUNT
    genes._PROBED_DEVICE_COUNT = 8
    try:
        assert modeled_cost_s(graph, coding, mesh_bits,
                              mesh_executed=True) == 0.0
        # modeled-only fitness still pays, even with the devices present
        assert modeled_cost_s(graph, coding, mesh_bits) > 0.0
    finally:
        genes._PROBED_DEVICE_COUNT = old


def test_mesh_proposals_respect_device_count():
    from repro.core.genes import (VARIANT_ALPHABET, mesh_proposals,
                                  with_mesh_destinations)

    assert mesh_proposals(device_count=1) == ()
    assert mesh_proposals(device_count=4) == ("mesh:data:2:batch",
                                              "mesh:data:4:batch")
    assert mesh_proposals(axes=("data", "model"), device_count=2) == \
        ("mesh:data:2:batch", "mesh:model:2:feature")
    # with_mesh_destinations extends an alphabet without duplicates
    ext = with_mesh_destinations(VARIANT_ALPHABET, device_count=4)
    assert ext[:len(VARIANT_ALPHABET)] == VARIANT_ALPHABET
    assert ext == with_mesh_destinations(ext, device_count=4)[:len(ext)]
    # single-device host: alphabet unchanged (CI fingerprints stay stable)
    assert with_mesh_destinations(VARIANT_ALPHABET, device_count=1) == \
        VARIANT_ALPHABET


def test_mesh_watts_scale_with_device_count():
    from repro.core.genes import MESH_DEVICE_POWER_W, MeshDestination

    d = MeshDestination(axis="data", n=4)
    assert d.watts() == 4 * MESH_DEVICE_POWER_W
    assert get_destination("cpu").watts() > 0.0
