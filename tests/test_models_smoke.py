"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; reference vs offloaded plan equivalence
(the PCAST check); prefill+decode vs full-sequence consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import OFFLOAD_PLAN, REFERENCE_PLAN, build_model

SMALL_OFFLOAD = OFFLOAD_PLAN.replace(
    attn_q_chunk=16, attn_kv_chunk=16, rglru_chunk=16, wkv_chunk=16,
    loss_vocab_chunk=64)


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        batch = m.demo_batch(jax.random.key(1), 2, 64)
        out[arch] = (cfg, m, params, batch)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_configs_exact(arch):
    """The registry carries the exact assigned architecture numbers."""
    cfg = get_config(arch)
    assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab > 0
    n = cfg.param_count()
    n_active = cfg.param_count(active_only=True)
    assert n >= n_active > 0
    if cfg.moe is not None:
        assert n > n_active  # MoE: total params exceed active


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(built, arch):
    cfg, m, params, batch = built[arch]
    loss, metrics = jax.jit(lambda p, b: m.loss(p, b, REFERENCE_PLAN))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert 3.0 < float(loss) < 9.0  # ~ln(vocab) at random init
    # one grad step produces finite grads of matching structure
    g = jax.grad(lambda p: m.loss(p, batch, REFERENCE_PLAN)[0])(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_offload_plan_matches_reference(built, arch):
    """PCAST analogue: offloaded implementations must agree with reference."""
    cfg, m, params, batch = built[arch]
    l_ref, _ = jax.jit(lambda p, b: m.loss(p, b, REFERENCE_PLAN))(params, batch)
    l_off, _ = jax.jit(lambda p, b: m.loss(p, b, SMALL_OFFLOAD))(params, batch)
    # MoE capacity dropping causes small diffs; dense paths are tighter
    tol = 5e-3 if cfg.moe is not None else 5e-4
    assert abs(float(l_ref) - float(l_off)) < tol


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "qwen3_0_6b", "olmoe_1b_7b",
                                  "recurrentgemma_2b", "rwkv6_3b",
                                  "whisper_small", "llava_next_mistral_7b"])
def test_decode_matches_full_forward(built, arch):
    cfg, m, params, _ = built[arch]
    S = 64 if cfg.family == "hybrid" else 33
    batch = m.demo_batch(jax.random.key(2), 2, S + 1 + (cfg.vision_patches or 0))
    toks = batch["tokens"]
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    inp_s = dict(inputs)
    inp_s["tokens"] = toks[:, :-1]
    cap = toks.shape[1] + (cfg.vision_patches or 0) + 4
    _, state = m.prefill(params, inp_s, REFERENCE_PLAN, cache_capacity=cap)
    lg_step, state2 = m.decode(params, toks[:, -1:], state, REFERENCE_PLAN)
    lg_full, _ = m.prefill(params, inputs, REFERENCE_PLAN)
    d = float(jnp.max(jnp.abs(lg_step.astype(jnp.float32)
                              - lg_full.astype(jnp.float32))))
    assert d < 2e-2
    assert int(state2["cache_len"]) == int(state["cache_len"]) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_model(built, arch):
    """input_specs must be sufficient to trace every step kind (this is what
    the dry-run lowers)."""
    from repro.configs.base import ShapeSpec
    cfg, m, params, _ = built[arch]
    train = ShapeSpec("t", 64, 2, "train")
    specs = m.input_specs(train)
    jax.eval_shape(lambda p, b: m.loss(p, b, REFERENCE_PLAN), params, specs)
    dec = ShapeSpec("d", 64, 2, "decode")
    specs_d = m.input_specs(dec)
    jax.eval_shape(lambda p, t, s: m.decode(p, t, s, REFERENCE_PLAN),
                   params, specs_d["token"], specs_d["state"])
