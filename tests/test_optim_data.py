"""Optimizer, schedules, gradient compression, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.data import Batcher, DataConfig, SyntheticLMDataset
from repro.optim import (OptimizerConfig, adamw_init, adamw_update,
                         compress_int8, decompress_int8, ef_compress_update,
                         ef_init, make_schedule)


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    state = adamw_init(params)
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw_update(g, state, params, cfg, cfg.lr)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    cfg = OptimizerConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    huge = {"w": jnp.full((4,), 1e6)}
    p2, _, metrics = adamw_update(huge, state, params, cfg, cfg.lr)
    assert float(metrics["grad_norm"]) > 1e5
    assert np.all(np.abs(np.asarray(p2["w"])) < 10.0)


def test_schedule_shapes():
    s = make_schedule("cosine", peak_lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1e-3)
    assert float(s(100)) == pytest.approx(1e-4, rel=0.05)
    assert float(s(5)) == pytest.approx(5e-4)


@given(st.integers(0, 2 ** 16), st.floats(0.1, 100.0))
@settings(max_examples=25, deadline=None)
def test_property_int8_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6  # half-ulp of the quant grid


def test_error_feedback_tracks_exact_sgd():
    """EF-int8 compressed gradient sum over steps matches exact within the
    final quantization residual (the EF guarantee)."""
    rng = np.random.default_rng(3)
    params = {"w": jnp.zeros((32,))}
    comp = ef_init(params)
    exact_sum = np.zeros(32)
    applied_sum = np.zeros(32)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=32), jnp.float32)}
        exact_sum += np.asarray(g["w"])
        qs, scales, comp = ef_compress_update(g, comp)
        applied_sum += np.asarray(decompress_int8(qs["w"], scales["w"]))
    resid = np.abs(np.asarray(comp.error["w"]))
    np.testing.assert_allclose(applied_sum, exact_sum, atol=resid.max() + 1e-5)
    # and the residual stays bounded (no divergence)
    assert resid.max() < 0.2


def test_synthetic_data_deterministic_and_shard_aware():
    cfg = DataConfig(seq_len=32, global_batch=8, vocab=100, seed=7)
    ds = SyntheticLMDataset(cfg)
    b1 = ds.batch(5, host_id=0, n_hosts=2)
    b2 = ds.batch(5, host_id=0, n_hosts=2)
    b3 = ds.batch(5, host_id=1, n_hosts=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # deterministic
    assert not np.array_equal(b1["tokens"], b3["tokens"])      # per-host shard
    assert b1["tokens"].shape == (4, 32)                       # 8 / 2 hosts
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_batcher_resumes_from_step():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=50, seed=1)
    ds = SyntheticLMDataset(cfg)
    b = Batcher(ds, start_step=10)
    step, batch = next(b)
    b.close()
    assert step == 10
    np.testing.assert_array_equal(batch["tokens"], ds.batch(10)["tokens"])
