"""Python-ast frontend: parsing, offload feasibility, executor correctness,
transfer accounting, and the transfer planner's predictions vs reality."""
import numpy as np
import pytest

from repro.core.frontends.ast_frontend import Executor, PyProgram
from repro.core.transfer_planner import plan_transfers

SRC = """
def app(a, b, x, n, m, k, iters):
    c = np.zeros((n, m))
    for i in range(n):
        for j in range(m):
            acc = 0.0
            for t in range(k):
                acc = acc + a[i, t] * b[t, j]
            c[i, j] = acc
    y = np.zeros((n,))
    for it in range(iters):
        y = y + np.tanh(c @ x) * 0.1
    s = 0.0
    for i in range(n):
        s = s + y[i] * y[i]
    return c, y, s
"""

CONSTS = {"n": 12, "m": 12, "k": 12, "iters": 10}


@pytest.fixture
def program():
    return PyProgram(SRC, consts=CONSTS)


@pytest.fixture
def inputs(rng):
    return dict(a=rng.random((12, 12)), b=rng.random((12, 12)), x=rng.random(12))


def test_parse_structure(program):
    g = program.graph
    assert g.frontend == "python_ast"
    loops = g.loops()
    assert len(loops) == 5  # 3 nested matmul + vector loop + reduction loop
    top = [r for r in loops if r.parent is None]
    assert len(top) == 3
    assert program.output_names == ["c", "y", "s"]
    mm = top[0]
    assert "c" in mm.defs and {"a", "b"} <= mm.uses
    assert mm.trip_count == 12


def test_offload_feasibility(program, inputs):
    ok = program.check_offloadable(inputs)
    assert len(ok) == 5  # every loop here compiles under the rewrite


def test_unoffloadable_loop_excluded():
    src = """
def app(xs, n):
    out = np.zeros((n,))
    total = 0.0
    for i in range(n):
        if xs[i] > 0.5:      # data-dependent branch: untraceable
            total = total + xs[i]
        out[i] = total
    return out
"""
    p = PyProgram(src, consts={"n": 4})
    ok = p.check_offloadable({"xs": np.asarray([0.1, 0.9, 0.2, 0.8])})
    assert ok == []
    r = p.graph.loops()[0]
    assert not r.offloadable and "offload_error" in r.meta


@pytest.mark.parametrize("pattern", ["none", "top_only", "all"])
def test_executor_equivalence(program, inputs, pattern):
    ok = program.check_offloadable(inputs)
    impl = {}
    if pattern == "top_only":
        impl = {ok[0]: "jit"}
    elif pattern == "all":
        impl = {k: "jit" for k in ok}
    ref_env = Executor(program, {}).run(**inputs)
    env = Executor(program, impl).run(**inputs)
    for name in program.output_names:
        np.testing.assert_allclose(np.asarray(env[name]),
                                   np.asarray(ref_env[name]), rtol=1e-6)


def test_transfer_hoisting_reduces_h2d(program, inputs):
    """Inner loop offloaded inside an interpreted outer loop: the hoisted
    executor uploads loop-invariant arrays once, the naive one per iteration."""
    program.check_offloadable(inputs)
    loops = [r for r in program.graph.loops() if r.parent is not None]
    inner = loops[0].name  # j-loop inside the matmul nest
    impl = {inner: "jit"}
    ex_hoist = Executor(program, impl, hoist_transfers=True)
    ex_hoist.run(**inputs)
    ex_naive = Executor(program, impl, hoist_transfers=False)
    ex_naive.run(**inputs)
    assert ex_hoist.stats.h2d < ex_naive.stats.h2d
    # a and b are loop-invariant: hoisted run uploads them ~once
    assert ex_hoist.stats.h2d <= ex_naive.stats.h2d / 2


def test_transfer_planner_matches_executor_direction(program, inputs):
    ok = program.check_offloadable(inputs)
    impl = {k: "jit" for k in ok if program.graph.by_name(k).parent is None}
    plan = plan_transfers(program.graph, impl, hoist=True)
    h2d_vars = {t.var for t in plan.transfers if t.direction == "h2d"}
    # inputs consumed by offloaded loops must be uploaded
    assert {"a", "b", "x"} <= h2d_vars


def test_lib_call_substitution(program, inputs):
    """Function-block offload: replace the matmul nest with jnp.matmul."""
    import jax.numpy as jnp
    program.check_offloadable(inputs)
    top = [r for r in program.graph.loops() if r.parent is None][0]
    lib = {top.name: (lambda a, b: jnp.matmul(a, b), ["a", "b"], ["c"])}
    env = Executor(program, {top.name: "lib"}, lib_calls=lib).run(**inputs)
    ref = Executor(program, {}).run(**inputs)
    np.testing.assert_allclose(np.asarray(env["c"]), ref["c"], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(env["s"]),
                               np.asarray(ref["s"]), rtol=1e-6)
