"""End-to-end planner tests: the paper's full pipeline on a Python program
(block offload first, GA second, verified results) and the module frontend's
gene/plan mapping."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.frontends import module_frontend
from repro.core.frontends.ast_frontend import PyProgram
from repro.core.ga import GAConfig
from repro.core.genes import coding_from_graph
from repro.core.offload import plan
from repro.models.plan import ExecPlan

SRC = """
def app(a, b, x, n, m, k, iters):
    c = np.zeros((n, m))
    for i in range(n):
        for j in range(m):
            acc = 0.0
            for t in range(k):
                acc = acc + a[i, t] * b[t, j]
            c[i, j] = acc
    y = np.zeros((n,))
    for it in range(iters):
        y = y + np.tanh(c @ x) * 0.1
    s = 0.0
    for i in range(n):
        s = s + y[i] * y[i]
    return c, y, s
"""


@pytest.mark.slow
def test_python_offload_end_to_end(rng):
    consts = {"n": 16, "m": 16, "k": 16, "iters": 20}
    p = PyProgram(SRC, consts=consts)
    inputs = dict(a=rng.random((16, 16)), b=rng.random((16, 16)),
                  x=rng.random(16))
    res = plan(p, inputs, ga=GAConfig(population=6, generations=3, seed=0),
               repeats=1)
    # block pass found and kept the matmul replacement
    assert any(b.pattern == "matmul" for b in res.block.offloads)
    # final plan beats the all-interpreted baseline
    assert res.best.time_s < res.baseline.time_s
    assert res.speedup > 2.0
    # block regions kept as lib calls are excluded from the GA gene
    claimed = {r for r, impl in res.pattern.items() if impl == "lib"}
    assert claimed
    assert all(s.region not in claimed for s in res.coding.sites)


def test_module_graph_sites_per_family():
    g_dense = module_frontend.build_graph(get_config("tinyllama_1_1b"))
    names = {r.name for r in g_dense.offloadable()}
    assert "attn_impl" in names and "moe_impl" not in names
    assert "rglru_impl" not in names and "wkv_impl" not in names

    g_moe = module_frontend.build_graph(get_config("olmoe_1b_7b"))
    assert "moe_impl" in {r.name for r in g_moe.offloadable()}

    g_ssm = module_frontend.build_graph(get_config("rwkv6_3b"))
    names = {r.name for r in g_ssm.offloadable()}
    assert "wkv_impl" in names and "attn_impl" not in names

    g_hyb = module_frontend.build_graph(get_config("recurrentgemma_2b"))
    names = {r.name for r in g_hyb.offloadable()}
    assert "rglru_impl" in names and "attn_impl" in names


def test_plan_from_bits_roundtrip():
    g = module_frontend.build_graph(get_config("qwen3_0_6b"))
    coding = coding_from_graph(g)
    plan_off = module_frontend.plan_from_bits(g, coding.all_on())
    plan_ref = module_frontend.plan_from_bits(g, coding.all_off())
    assert plan_off.attn_impl == "chunked" and plan_ref.attn_impl == "naive"
    assert plan_off.remat == "dots" and plan_ref.remat == "none"
    # exclusion honors the block pass's claims
    base = ExecPlan(norm_impl="fused")
    coding2 = coding_from_graph(g, exclude=("norm_impl",))
    plan2 = module_frontend.plan_from_bits(
        g, coding2.all_off(), base=base, exclude=("norm_impl",))
    assert plan2.norm_impl == "fused"  # block-pass claim preserved


def test_gene_length_matches_applicable_sites():
    for arch, expected_absent in [("gemma_7b", {"moe_impl", "wkv_impl", "rglru_impl"}),
                                  ("llama4_scout_17b_a16e", {"wkv_impl", "rglru_impl"})]:
        g = module_frontend.build_graph(get_config(arch))
        names = {r.name for r in g.offloadable()}
        assert not (names & expected_absent)
        coding = coding_from_graph(g)
        assert coding.length == len(names)
