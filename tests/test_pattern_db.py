"""Pattern DB: name matching, similarity matching, ambiguity handling,
persistence (the MySQL stand-in)."""
import ast
import textwrap

import pytest

from repro.core import similarity as sim
from repro.core.ir import Region
from repro.core.pattern_db import PatternDB, default_db


def _region_from_code(code: str, callees=()) -> Region:
    tree = ast.parse(textwrap.dedent(code))
    return Region(name="r0", kind="loop", callees=tuple(callees),
                  feature_vector=sim.ast_vector(tree), offloadable=True)


NAIVE_MATMUL = """
for i in range(n):
    for j in range(m):
        acc = 0.0
        for t in range(k):
            acc = acc + a[i][t] * b[t][j]
        c[i][j] = acc
"""

# "copied then modified": different names, fused scale factor
MODIFIED_MATMUL = """
for row in range(rows):
    for col in range(cols):
        s = 0.0
        for kk in range(inner):
            s = s + lhs[row][kk] * rhs[kk][col] * alpha
        out[row][col] = s + beta
"""

UNRELATED_IO = """
for i in range(n):
    if flags[i]:
        total = total + 1
    else:
        names.append(str(i))
"""


def test_name_match_beats_similarity():
    db = default_db()
    r = _region_from_code("for i in range(n):\n    pass", callees=["np.matmul"])
    ms = db.match_region(r, "python_ast")
    assert ms and ms[0].record.name == "matmul" and ms[0].how == "name"


def test_similarity_detects_naive_matmul():
    db = default_db()
    r = _region_from_code(NAIVE_MATMUL)
    ms = db.match_region(r, "python_ast")
    assert ms and ms[0].record.name == "matmul"
    assert ms[0].how == "similarity"
    assert ms[0].score > 0.9


def test_similarity_detects_copied_then_modified():
    """The Deckard use case: clone with renames + small edits still matches."""
    db = default_db()
    r = _region_from_code(MODIFIED_MATMUL)
    ms = db.match_region(r, "python_ast")
    assert ms and ms[0].record.name == "matmul"


def test_unrelated_code_does_not_match():
    db = default_db()
    r = _region_from_code(UNRELATED_IO)
    ms = [m for m in db.match_region(r, "python_ast") if not m.needs_confirmation]
    assert not ms


def test_interface_change_needs_confirmation():
    db = default_db()
    r = _region_from_code("for i in range(n):\n    pass", callees=["np.fft.fft"])
    ms = db.match_region(r, "python_ast")
    assert ms and ms[0].record.name == "fft"
    assert ms[0].needs_confirmation  # complex return vs (re, im) pair


def test_jaxpr_similarity_attention():
    import jax
    import jax.numpy as jnp
    from repro.core.frontends import jaxpr_frontend

    def my_attention(q, k, v):  # user's hand-rolled attention
        s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
        mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), bool))
        s = jnp.where(mask, s, -1e30)
        return jax.nn.softmax(s, axis=-1) @ v

    db = default_db()
    x = jnp.zeros((8, 4), jnp.float32)
    g = jaxpr_frontend.build_graph(my_attention, x, x, x)
    vec = g.meta["whole_program_vector"]
    rec = next(r for r in db.records if r.name == "softmax_attention")
    assert sim.similarity(vec, rec.vectors["jaxpr"]) > 0.8


def test_persistence_roundtrip(tmp_path):
    db = default_db()
    p = str(tmp_path / "patterns.json")
    db.save(p)
    db2 = PatternDB.load(p)
    assert [r.name for r in db2.records] == [r.name for r in db.records]
    r = _region_from_code(NAIVE_MATMUL)
    assert db2.match_region(r, "python_ast")[0].record.name == "matmul"
