"""Pattern DB: name matching, similarity matching, ambiguity handling,
persistence (the MySQL stand-in)."""
import ast
import textwrap

import pytest

from repro.core import similarity as sim
from repro.core.ir import Region
from repro.core.pattern_db import PatternDB, default_db


def _region_from_code(code: str, callees=()) -> Region:
    tree = ast.parse(textwrap.dedent(code))
    return Region(name="r0", kind="loop", callees=tuple(callees),
                  feature_vector=sim.ast_vector(tree), offloadable=True)


NAIVE_MATMUL = """
for i in range(n):
    for j in range(m):
        acc = 0.0
        for t in range(k):
            acc = acc + a[i][t] * b[t][j]
        c[i][j] = acc
"""

# "copied then modified": different names, fused scale factor
MODIFIED_MATMUL = """
for row in range(rows):
    for col in range(cols):
        s = 0.0
        for kk in range(inner):
            s = s + lhs[row][kk] * rhs[kk][col] * alpha
        out[row][col] = s + beta
"""

UNRELATED_IO = """
for i in range(n):
    if flags[i]:
        total = total + 1
    else:
        names.append(str(i))
"""


def test_name_match_beats_similarity():
    db = default_db()
    r = _region_from_code("for i in range(n):\n    pass", callees=["np.matmul"])
    ms = db.match_region(r, "python_ast")
    assert ms and ms[0].record.name == "matmul" and ms[0].how == "name"


def test_similarity_detects_naive_matmul():
    db = default_db()
    r = _region_from_code(NAIVE_MATMUL)
    ms = db.match_region(r, "python_ast")
    assert ms and ms[0].record.name == "matmul"
    assert ms[0].how == "similarity"
    assert ms[0].score > 0.9


def test_similarity_detects_copied_then_modified():
    """The Deckard use case: clone with renames + small edits still matches."""
    db = default_db()
    r = _region_from_code(MODIFIED_MATMUL)
    ms = db.match_region(r, "python_ast")
    assert ms and ms[0].record.name == "matmul"


def test_unrelated_code_does_not_match():
    db = default_db()
    r = _region_from_code(UNRELATED_IO)
    ms = [m for m in db.match_region(r, "python_ast") if not m.needs_confirmation]
    assert not ms


def test_interface_change_needs_confirmation():
    db = default_db()
    r = _region_from_code("for i in range(n):\n    pass", callees=["np.fft.fft"])
    ms = db.match_region(r, "python_ast")
    assert ms and ms[0].record.name == "fft"
    assert ms[0].needs_confirmation  # complex return vs (re, im) pair


def test_jaxpr_similarity_attention():
    import jax
    import jax.numpy as jnp
    from repro.core.frontends import jaxpr_frontend

    def my_attention(q, k, v):  # user's hand-rolled attention
        s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
        mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), bool))
        s = jnp.where(mask, s, -1e30)
        return jax.nn.softmax(s, axis=-1) @ v

    db = default_db()
    x = jnp.zeros((8, 4), jnp.float32)
    g = jaxpr_frontend.build_graph(my_attention, x, x, x)
    vec = g.meta["whole_program_vector"]
    rec = next(r for r in db.records if r.name == "softmax_attention")
    assert sim.similarity(vec, rec.vectors["jaxpr"]) > 0.8


def test_persistence_roundtrip(tmp_path):
    db = default_db()
    p = str(tmp_path / "patterns.json")
    db.save(p)
    db2 = PatternDB.load(p)
    assert [r.name for r in db2.records] == [r.name for r in db.records]
    r = _region_from_code(NAIVE_MATMUL)
    assert db2.match_region(r, "python_ast")[0].record.name == "matmul"


# ---------------------------------------------------------------------------
# precision feedback: verifier outcomes tighten a pattern's match threshold
# (low-precision patterns demand stricter similarity, with an evidence floor
# so one flaky measurement can never blacklist a pattern)
# ---------------------------------------------------------------------------

# a heavily edited clone: still matmul-shaped, but scoring between the
# static threshold (0.88) and the precision ceiling (0.98) — exactly the
# borderline match the feedback is supposed to gate
BORDERLINE_MATMUL = """
for row in range(rows):
    for col in range(cols):
        s = 1.0
        for kk in range(inner):
            s = s + lhs[row][kk] * rhs[kk][col] + eps
        out[row][col] = s
        acc[row] = acc[row] + s
"""


def _db_with_journal(tmp_path):
    from repro.core.pattern_db import PatternDB
    return PatternDB(default_db().records, precision_dir=str(tmp_path))


def test_precision_feedback_respects_evidence_floor(tmp_path):
    from repro.core.pattern_db import record_pattern_outcome
    db = _db_with_journal(tmp_path)
    rec = next(r for r in db.records if r.name == "matmul")
    # no journal entries: no evidence, static threshold
    assert db.precision_evidence("matmul") == (None, 0)
    assert db.effective_threshold(rec) == rec.threshold
    # one or two failures stay below PRECISION_MIN_EVIDENCE: unchanged
    for _ in range(db.PRECISION_MIN_EVIDENCE - 1):
        record_pattern_outcome(str(tmp_path), "matmul", "kernel",
                               "verify_fail")
        assert db.effective_threshold(rec) == rec.threshold
    # the third ran outcome crosses the floor: threshold tightens
    record_pattern_outcome(str(tmp_path), "matmul", "kernel", "verify_fail")
    assert db.precision_evidence("matmul") == (0.0, 3)
    assert db.effective_threshold(rec) == pytest.approx(
        min(db.PRECISION_CEILING,
            rec.threshold + db.PRECISION_TIGHTEN))


def test_precision_feedback_scales_caps_and_ignores_bind_fail(tmp_path):
    from repro.core.pattern_db import record_pattern_outcome
    db = _db_with_journal(tmp_path)
    matmul = next(r for r in db.records if r.name == "matmul")
    rms = next(r for r in db.records if r.name == "rmsnorm")
    # 50% precision: halfway tightening
    for outcome in ("ok", "ok", "verify_fail", "error"):
        record_pattern_outcome(str(tmp_path), "matmul", "kernel", outcome)
    assert db.effective_threshold(matmul) == pytest.approx(
        matmul.threshold + 0.5 * db.PRECISION_TIGHTEN)
    # bind_fail records never enter the denominator (nothing ran)
    record_pattern_outcome(str(tmp_path), "matmul", "kernel", "bind_fail")
    assert db.precision_evidence("matmul") == (0.5, 4)
    # a fully-failing pattern caps at the ceiling, not a hard blacklist
    for _ in range(4):
        record_pattern_outcome(str(tmp_path), "rmsnorm", "fused",
                               "verify_fail")
    assert db.effective_threshold(rms) == pytest.approx(db.PRECISION_CEILING)
    assert db.effective_threshold(rms) < 1.0
    # an all-ok pattern keeps its static threshold exactly
    for _ in range(4):
        record_pattern_outcome(str(tmp_path), "fft", "lib", "ok")
    fft = next(r for r in db.records if r.name == "fft")
    assert db.effective_threshold(fft) == fft.threshold


def test_precision_feedback_gates_borderline_match(tmp_path):
    from repro.core.pattern_db import record_pattern_outcome
    db = _db_with_journal(tmp_path)
    r = _region_from_code(BORDERLINE_MATMUL)
    # healthy pattern: the borderline clone matches
    before = db.match_region(r, "python_ast")
    assert before and before[0].record.name == "matmul"
    assert 0.88 < before[0].score < 0.98
    # after enough verifier failures the same region no longer matches it
    for _ in range(db.PRECISION_MIN_EVIDENCE):
        record_pattern_outcome(str(tmp_path), "matmul", "kernel",
                               "verify_fail")
    after = db.match_region(r, "python_ast")
    assert not any(m.record.name == "matmul" for m in after)
    # an explicit caller override always wins over the feedback
    forced = db.match_region(r, "python_ast", min_similarity=0.9)
    assert forced and forced[0].record.name == "matmul"
    # and the near-perfect clone still clears even the tightened bar
    # (measurement stays the final arbiter; feedback only raises the
    # evidence bar, it never hard-blacklists)
    naive = db.match_region(_region_from_code(NAIVE_MATMUL), "python_ast")
    assert naive and naive[0].record.name == "matmul"


def test_default_db_without_journal_is_unchanged(tmp_path):
    from repro.core.pattern_db import record_pattern_outcome
    # outcomes recorded somewhere on disk don't affect a DB that was never
    # pointed at that journal (default_db has no precision_dir)
    record_pattern_outcome(str(tmp_path), "matmul", "kernel", "verify_fail")
    db = default_db()
    assert db.precision_dir is None
    rec = next(r for r in db.records if r.name == "matmul")
    assert db.effective_threshold(rec) == rec.threshold
    # but the same journal read explicitly reports the evidence
    assert db.precision_evidence("matmul", str(tmp_path)) == (0.0, 1)
