"""Transfer planner: the paper's def/use transfer rule + hoisting, checked on
hand-built IR and property-tested for safety invariants."""
from _hypothesis_compat import given, settings, st

from repro.core.ir import Region, RegionGraph
from repro.core.transfer_planner import plan_transfers


def _loop(name, defs=(), uses=(), parent=None, kind="loop", trip=None):
    return Region(name=name, kind=kind, parent=parent,
                  defs=frozenset(defs), uses=frozenset(uses),
                  offloadable=(kind == "loop"), trip_count=trip,
                  alternatives=("interp", "jit"))


def test_h2d_for_device_consumed_var():
    g = RegionGraph([
        _loop("s0", defs={"x"}, kind="stmt"),
        _loop("l1", uses={"x"}, defs={"y"}),
    ], "python_ast")
    plan = plan_transfers(g, {"l1": "jit"})
    assert any(t.var == "x" and t.direction == "h2d" for t in plan.transfers)


def test_d2h_when_host_reads_device_result():
    g = RegionGraph([
        _loop("l1", uses={"x"}, defs={"y"}),
        _loop("s2", uses={"y"}, defs={"z"}, kind="stmt"),
    ], "python_ast")
    plan = plan_transfers(g, {"l1": "jit"})
    assert any(t.var == "y" and t.direction == "d2h" for t in plan.transfers)


def test_no_transfer_between_consecutive_device_regions():
    g = RegionGraph([
        _loop("l1", uses={"x"}, defs={"y"}),
        _loop("l2", uses={"y"}, defs={"z"}),
    ], "python_ast")
    plan = plan_transfers(g, {"l1": "jit", "l2": "jit"})
    assert not any(t.var == "y" and t.direction == "d2h" for t in plan.transfers)


def test_hoist_invariant_transfer_out_of_loop():
    # outer interpreted loop; inner offloaded uses loop-invariant `w`
    g = RegionGraph([
        _loop("outer", uses={"w"}, defs={"i"}, trip=10),
        _loop("inner", uses={"w", "i"}, defs={"acc"}, parent="outer"),
    ], "python_ast")
    plan = plan_transfers(g, {"inner": "jit"}, hoist=True)
    t = next(t for t in plan.transfers if t.var == "w" and t.direction == "h2d")
    assert t.at_region == "outer" and t.hoisted_from is not None
    plan2 = plan_transfers(g, {"inner": "jit"}, hoist=False)
    t2 = next(t for t in plan2.transfers if t.var == "w")
    assert t2.per_iteration


def test_host_mutated_var_not_hoisted():
    # sibling host stmt writes `w` every iteration -> must transfer per iter
    g = RegionGraph([
        _loop("outer", uses={"w"}, defs={"i"}, trip=5),
        _loop("mut", defs={"w"}, parent="outer", kind="stmt"),
        _loop("inner", uses={"w"}, defs={"acc"}, parent="outer"),
    ], "python_ast")
    plan = plan_transfers(g, {"inner": "jit"}, hoist=True)
    t = next(t for t in plan.transfers if t.var == "w" and t.direction == "h2d")
    assert t.at_region == "inner"  # could not hoist past the mutation


@given(st.lists(st.sampled_from(["jit", "interp"]), min_size=1, max_size=6),
       st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_property_chain_safety(impls, n_vars):
    """For a linear chain r0->r1->... where r_{i} defines v_i and uses
    v_{i-1}: every device-consumed var has an h2d upstream or a device def,
    and every host-consumed device-def has a d2h."""
    regions = []
    for i, im in enumerate(impls):
        regions.append(_loop(f"r{i}", defs={f"v{i}"},
                             uses={f"v{i-1}"} if i else {"inp"}))
    g = RegionGraph(regions, "python_ast")
    impl = {f"r{i}": im for i, im in enumerate(impls)}
    plan = plan_transfers(g, impl)
    on_dev = set()
    transfers = list(plan.transfers)
    for i, im in enumerate(impls):
        r = g.by_name(f"r{i}")
        if im == "jit":
            for u in r.uses:
                assert u in on_dev or any(
                    t.var == u and t.direction == "h2d" for t in transfers)
            on_dev |= r.defs
        else:
            for u in r.uses & on_dev:
                assert any(t.var == u and t.direction == "d2h" for t in transfers)
            on_dev -= r.defs
