"""GA engine invariants: convergence, caching, invalid handling, determinism.
Property-based tests via hypothesis."""
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.ga import Evaluation, GAConfig, run_ga

SETTINGS = dict(max_examples=20, deadline=None)


def _linear_fitness(weights):
    """Offloading bit i saves weights[i] (can be negative = hurts)."""
    def fit(bits):
        t = 1.0 + sum(w for b, w in zip(bits, weights) if b)
        if t <= 0:
            t = 1e-3
        return Evaluation(bits, t, True)
    return fit


def test_converges_to_known_optimum():
    # bits 0,2 help; bit 1 hurts; bit 3 neutral-negative
    weights = [-0.4, +0.3, -0.35, +0.1]
    res = run_ga(4, _linear_fitness(weights),
                 GAConfig(population=10, generations=12, seed=0))
    assert res.best.bits[0] == 1 and res.best.bits[2] == 1
    assert res.best.bits[1] == 0 and res.best.bits[3] == 0
    assert res.best.time_s == pytest.approx(1.0 - 0.75, abs=1e-9)


def test_baseline_recorded_and_speedup():
    res = run_ga(3, _linear_fitness([-0.2, -0.2, -0.2]),
                 GAConfig(population=8, generations=6, seed=1))
    assert res.baseline is not None
    assert res.baseline.time_s == pytest.approx(1.0)
    assert res.speedup_vs_baseline > 1.5


def test_invalid_patterns_never_win():
    # any pattern with bit 0 set is invalid (verification failure)
    def fit(bits):
        if bits and bits[0] == 1:
            return Evaluation(bits, float("inf"), False)
        t = 1.0 - 0.3 * sum(bits[1:])
        return Evaluation(bits, max(t, 0.01), True)
    res = run_ga(4, fit, GAConfig(population=10, generations=8, seed=2))
    assert res.best.bits[0] == 0
    assert res.best.valid


def test_measurement_cache_no_repeats():
    calls = []

    def fit(bits):
        calls.append(bits)
        return Evaluation(bits, 1.0 + sum(bits) * 0.1, True)

    res = run_ga(3, fit, GAConfig(population=8, generations=10, seed=3))
    # every measured chromosome measured exactly once (paper: patterns are
    # never re-measured)
    assert len(calls) == len(set(calls))
    assert res.evaluations == len(calls)
    assert res.cache_hits > 0  # small space -> revisits happen


def test_deterministic_given_seed():
    fit = _linear_fitness([-0.1, 0.2, -0.3, 0.05, -0.02])
    r1 = run_ga(5, fit, GAConfig(population=8, generations=5, seed=42))
    r2 = run_ga(5, fit, GAConfig(population=8, generations=5, seed=42))
    assert r1.best.bits == r2.best.bits
    assert [h["best_time_s"] for h in r1.history] == \
        [h["best_time_s"] for h in r2.history]


def test_zero_length_genome():
    res = run_ga(0, lambda b: Evaluation(b, 1.0, True), GAConfig())
    assert res.best.bits == ()


@given(length=st.integers(1, 8), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_property_best_is_min_of_measured(length, seed):
    measured = {}

    def fit(bits):
        t = 1.0 + 0.1 * int(np.dot(bits, 2 ** np.arange(len(bits)))) % 7
        measured[bits] = t
        return Evaluation(bits, t, True)

    res = run_ga(length, fit, GAConfig(population=6, generations=4, seed=seed))
    assert res.best.time_s == min(measured.values())
    assert measured[res.best.bits] == res.best.time_s


@given(length=st.integers(1, 6), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_property_monotone_history(length, seed):
    def fit(bits):
        return Evaluation(bits, 1.0 + sum(bits) * 0.05, True)
    res = run_ga(length, fit, GAConfig(population=5, generations=5, seed=seed))
    best_times = [h["best_time_s"] for h in res.history]
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(best_times, best_times[1:]))
    # all-off seeded: baseline must equal the all-zero measurement
    assert res.baseline.time_s == pytest.approx(1.0)
