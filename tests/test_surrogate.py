"""Journal-fitted surrogate + compile-overlap tests.

The fitted model must demonstrably out-rank the hand formula on a journal
whose per-site effects the formula cannot see, abstain below the record
threshold, persist/reload its coefficients, and plug into ``ga_search``'s
screening selection.  The compile-parallel/time-serial phase must produce
byte-identical Evaluations to serial warm-up (timing-independent
assertions on a deterministic two-phase fitness) and report its savings.
"""
import json
import math
import os

import numpy as np
import pytest

from repro.core.evaluator import Evaluator, transfer_cost_surrogate
from repro.core.ga import Evaluation, GAConfig, run_ga
from repro.core.genes import coding_from_graph
from repro.core.ir import Region, RegionGraph
from repro.core.offload import ga_search, phenotype_key, search_fingerprint
from repro.core.surrogate import (SURROGATE_FIT_FILE, FeatureExtractor,
                                  fit_surrogate, load_fit,
                                  spearman_rank_corr)


def _graph(n=5):
    return RegionGraph([
        Region(f"r{i}", "loop", uses=frozenset({f"v{i}"}),
               defs=frozenset({f"v{i}"}), offloadable=True,
               alternatives=("ref", "kernel"), trip_count=2 + i)
        for i in range(n)], "ir", "surrogate-test")


#: per-site effects the hand formula cannot see: r1's offload is slow,
#: r3's is very fast — transfer counts alone misrank these patterns
_W = (0.05, 0.9, -0.1, -0.6, -0.05)


def _site_effect_fitness(bits):
    t = 1.0 + sum(w * b for w, b in zip(_W, bits))
    return Evaluation(tuple(bits), t, True)


def _seed_journal(cache_dir, fingerprint="fp", n=40, seed=0):
    g = _graph()
    ev = Evaluator(_site_effect_fitness, cache_dir=str(cache_dir),
                   fingerprint=fingerprint)
    rng = np.random.default_rng(seed)
    ev.evaluate_batch([tuple(int(x) for x in rng.integers(0, 2, 5))
                       for _ in range(n)])
    return g


# ---------------------------------------------------------------------------
# spearman helper
# ---------------------------------------------------------------------------


def test_spearman_rank_corr_basics():
    assert spearman_rank_corr([1, 2, 3, 4], [10, 20, 30, 40]) \
        == pytest.approx(1.0)
    assert spearman_rank_corr([1, 2, 3, 4], [40, 30, 20, 10]) \
        == pytest.approx(-1.0)
    assert math.isnan(spearman_rank_corr([1, 2], [1, 2]))       # too few
    assert math.isnan(spearman_rank_corr([1, 1, 1], [1, 2, 3]))  # constant


# ---------------------------------------------------------------------------
# the fit
# ---------------------------------------------------------------------------


def test_fitted_surrogate_outranks_static_on_synthetic_journal(tmp_path):
    g = _seed_journal(tmp_path)
    coding = coding_from_graph(g)
    static = transfer_cost_surrogate(g, coding)
    fit = fit_surrogate(g, coding, str(tmp_path), "fp", prior=static,
                        min_records=10)
    assert fit is not None
    assert math.isfinite(fit.rank_corr)
    # the acceptance criterion: strictly exceeds the hand formula
    assert fit.rank_corr > fit.static_rank_corr
    assert fit.beats_static
    # and it is a usable ranking function over chromosomes
    scores = [fit(bits) for bits in
              [(0, 0, 0, 0, 0), (0, 1, 0, 0, 0), (0, 0, 0, 1, 0)]]
    assert scores[1] > scores[0] > scores[2]  # slow r1 last, fast r3 first


def test_fit_abstains_below_min_records(tmp_path):
    g = _seed_journal(tmp_path, n=4)
    coding = coding_from_graph(g)
    assert fit_surrogate(g, coding, str(tmp_path), "fp",
                         min_records=10) is None
    # and on a journal for a fingerprint that was never measured
    assert fit_surrogate(g, coding, str(tmp_path), "other",
                         min_records=10) is None


def test_fit_ignores_foreign_and_invalid_journal_rows(tmp_path):
    g = _seed_journal(tmp_path, n=20)
    coding = coding_from_graph(g)
    ev = Evaluator(lambda b: Evaluation(tuple(b), float("inf"), False),
                   cache_dir=str(tmp_path), fingerprint="fp2")
    ev.evaluate_batch([(1, 0, 0, 0, 0), (0, 1, 0, 0, 0), (0, 0, 1, 0, 0)])
    assert fit_surrogate(g, coding, str(tmp_path), "fp2",
                         min_records=3) is None   # invalid rows don't count


def test_coefficient_persistence_round_trip(tmp_path):
    g = _seed_journal(tmp_path)
    coding = coding_from_graph(g)
    fit = fit_surrogate(g, coding, str(tmp_path), "fp", min_records=10)
    assert os.path.exists(os.path.join(str(tmp_path), SURROGATE_FIT_FILE))
    rec = load_fit(str(tmp_path), "fp")
    assert rec is not None
    assert rec["n_records"] == fit.n_records
    assert rec["rank_corr"] == pytest.approx(fit.rank_corr)
    assert rec["static_rank_corr"] == pytest.approx(fit.static_rank_corr)
    assert rec["feature_names"] == list(fit.extractor.feature_names)
    assert rec["coefficients"] == pytest.approx(fit.coefficients())
    assert load_fit(str(tmp_path), "unknown") is None
    # refits journal newest-last; load returns the most recent record
    fit2 = fit_surrogate(g, coding, str(tmp_path), "fp", min_records=10)
    rec2 = load_fit(str(tmp_path), "fp")
    assert rec2["n_records"] == fit2.n_records


def test_feature_extractor_names_align_with_vector(tmp_path):
    g = _graph()
    coding = coding_from_graph(g)
    fx = FeatureExtractor(g, coding, prior=lambda b: 0.0)
    vec = fx(coding.all_on())
    assert len(vec) == len(fx.feature_names)
    named = dict(zip(fx.feature_names, vec))
    assert named["offload_trips"] > 0          # all-on offloads everything
    assert named["dest1"] == coding.length
    assert named["site0@1"] == 1.0


# ---------------------------------------------------------------------------
# ga_search selection: screening improves with every search
# ---------------------------------------------------------------------------


def test_ga_search_prefers_fitted_surrogate_when_it_ranks_better(tmp_path):
    g = _graph()
    cfg = dict(population=8, generations=5, cache_dir=str(tmp_path))
    _, ga1 = ga_search(g, _site_effect_fitness, GAConfig(seed=0, **cfg))
    assert ga1.surrogate_kind == "static"      # no journal yet at build time
    _, ga2 = ga_search(g, _site_effect_fitness, GAConfig(seed=1, **cfg))
    assert ga2.surrogate_kind == "fitted"
    # the measured (out-of-sample) rank correlation improved materially —
    # deterministic fitness, so this is exact, not luck
    assert ga2.surrogate_rank_corr > max(0.9, ga1.surrogate_rank_corr)
    # the fit was journaled beside search_meta.jsonl for inspection
    fp = search_fingerprint(g, coding_from_graph(g))
    assert load_fit(str(tmp_path), fp) is not None
    # and the evidence record names which surrogate produced it
    with open(os.path.join(str(tmp_path), "search_meta.jsonl")) as f:
        kinds = [json.loads(line).get("kind") for line in f if line.strip()]
    assert "fitted" in kinds


def test_ga_search_fit_opt_out(tmp_path):
    g = _graph()
    cfg = dict(population=8, generations=5, cache_dir=str(tmp_path),
               fit_surrogate=False)
    ga_search(g, _site_effect_fitness, GAConfig(seed=0, **cfg))
    _, ga2 = ga_search(g, _site_effect_fitness, GAConfig(seed=1, **cfg))
    assert ga2.surrogate_kind == "static"


# ---------------------------------------------------------------------------
# compile-parallel / time-serial phase
# ---------------------------------------------------------------------------


class _DeterministicTwoPhase:
    """prepare/measure fitness with exact, timing-free Evaluations."""

    def __init__(self, delay=0.0):
        import time
        self._sleep = (lambda: time.sleep(delay)) if delay else (lambda: None)
        self.prepared: list[tuple] = []

    def prepare(self, bits):
        self._sleep()                 # stands in for the warm-up compile
        self.prepared.append(tuple(bits))
        return ("prepared", tuple(bits))

    def measure(self, prep):
        tag, bits = prep
        assert tag == "prepared"
        return Evaluation(bits, 1.0 + 0.1 * sum(bits), True,
                          {"phase": "two"})

    def __call__(self, bits):
        return self.measure(self.prepare(bits))


def test_overlapped_equals_serial_fitness_values():
    pop = [(i % 2, (i // 2) % 2, (i // 4) % 2) for i in range(8)]
    serial = Evaluator(_DeterministicTwoPhase(),
                       compile_workers=0).evaluate_batch(pop)
    ev = Evaluator(_DeterministicTwoPhase(delay=0.01), compile_workers=4)
    overlapped = ev.evaluate_batch(pop)
    assert [(r.bits, r.time_s, r.valid, r.detail) for r in serial] \
        == [(r.bits, r.time_s, r.valid, r.detail) for r in overlapped]
    assert ev.stats.overlapped_compiles == 8
    assert ev.stats.compile_serial_s > 0
    assert ev.stats.compile_wall_s > 0
    assert "compile_overlap_saved_s" in ev.stats.as_dict()


def test_overlapped_ga_identical_to_serial_at_fixed_seed():
    cfg = dict(population=10, generations=5, seed=3)
    r_ser = run_ga(4, _DeterministicTwoPhase(),
                   GAConfig(**cfg, compile_workers=0))
    r_ovl = run_ga(4, _DeterministicTwoPhase(delay=0.002),
                   GAConfig(**cfg, compile_workers=4))
    assert r_ser.best.bits == r_ovl.best.bits
    assert r_ser.best.time_s == r_ovl.best.time_s
    assert [h["best_time_s"] for h in r_ser.history] \
        == [h["best_time_s"] for h in r_ovl.history]
    assert r_ser.evaluations == r_ovl.evaluations
    assert r_ovl.compile_overlap_saved_s >= 0.0


def test_overlap_prepare_failures_match_serial():
    class Flaky(_DeterministicTwoPhase):
        def prepare(self, bits):
            if sum(bits) == 2:        # deterministic "compile error"
                return ("prepared", tuple(bits))
            return super().prepare(bits)

        def measure(self, prep):
            tag, bits = prep
            if sum(bits) == 2:
                return Evaluation(bits, float("inf"), False,
                                  {"error": "boom"})
            return super().measure(prep)

    pop = [(0, 0), (1, 0), (1, 1), (0, 1)]
    serial = Evaluator(Flaky(), compile_workers=0).evaluate_batch(pop)
    overlapped = Evaluator(Flaky(), compile_workers=4).evaluate_batch(pop)
    assert [(r.bits, r.time_s, r.valid) for r in serial] \
        == [(r.bits, r.time_s, r.valid) for r in overlapped]
    bad = next(r for r in overlapped if r.bits == (1, 1))
    assert not bad.valid and bad.detail["error"] == "boom"


def test_wallclock_two_phase_matches_call_semantics():
    from repro.core.fitness import WallClockFitness

    calls = []

    def build(bits):
        calls.append(tuple(bits))
        if sum(bits) > 1:
            raise RuntimeError("no such kernel")
        return lambda: {"y": np.asarray([float(sum(bits))])}

    ref = {"y": np.asarray([0.0])}
    fit = WallClockFitness(build, reference_output=ref, repeats=1)
    # failure path: prepare carries the same Evaluation __call__ returns
    direct = fit((1, 1))
    phased = fit.measure(fit.prepare((1, 1)))
    assert (direct.bits, direct.valid, direct.detail) \
        == (phased.bits, phased.valid, phased.detail)
    # verification failure path
    direct = fit((1, 0))
    phased = fit.measure(fit.prepare((1, 0)))
    assert not direct.valid and not phased.valid
    assert "verify" in direct.detail and "verify" in phased.detail
    # success path: valid with a finite timing (values are wall-clock, so
    # only the structure is asserted)
    ok = fit.measure(fit.prepare((0, 0)))
    assert ok.valid and math.isfinite(ok.time_s)


def test_serial_only_wallclock_overlap_keeps_workers_serial():
    """compile_workers must not activate the thread-parallel *timing* path:
    only prepare overlaps, measure order is batch order."""
    order = []

    class Ordered(_DeterministicTwoPhase):
        def measure(self, prep):
            order.append(prep[1])
            return super().measure(prep)

    pop = [(1, 0), (0, 1), (1, 1), (0, 0)]
    Evaluator(Ordered(), compile_workers=4).evaluate_batch(pop)
    assert order == pop               # strictly serial, in batch order


# ---------------------------------------------------------------------------
# resolution fallbacks fold into the phenotype key
# ---------------------------------------------------------------------------


def test_phenotype_key_folds_resolver_fallbacks():
    from repro.core.genes import VARIANT_ALPHABET

    g = RegionGraph([
        Region("site", "loop", uses=frozenset({"a"}), defs=frozenset({"a"}),
               offloadable=True,
               alternatives=("ref", "fused_jnp", "pallas"), trip_count=4),
    ], "ir", "resolve")
    coding = coding_from_graph(g, destinations=VARIANT_ALPHABET)

    def resolver(region, impl):       # both variants fall back to ref
        return "ref" if str(impl) in ("fused_jnp", "pallas") else impl

    calls = []

    def fit(bits):
        calls.append(tuple(bits))
        return Evaluation(tuple(bits), 1.0, True)

    ev = Evaluator(fit, phenotype_key=phenotype_key(coding,
                                                    resolver=resolver))
    out = ev.evaluate_batch([(0,), (1,), (2,)])
    assert len(calls) == 1, "all three decode to the ref program"
    assert [r.bits for r in out] == [(0,), (1,), (2,)]
    # without the resolver the variants are distinct phenotypes
    calls2 = []

    def fit2(bits):
        calls2.append(tuple(bits))
        return Evaluation(tuple(bits), 1.0, True)

    Evaluator(fit2,
              phenotype_key=phenotype_key(coding)).evaluate_batch(
        [(0,), (1,), (2,)])
    assert len(calls2) == 3


def test_phenotype_key_resolver_errors_are_harmless():
    g = _graph(2)
    coding = coding_from_graph(g)

    def broken(region, impl):
        raise RuntimeError("resolver exploded")

    key = phenotype_key(coding, resolver=broken)
    assert key((0, 1)) == phenotype_key(coding)((0, 1))


def test_jaxpr_engine_resolved_impl_dedups_fallback_variants():
    """End to end on the real engine: a carry-only scan rejects both kernel
    variants, so gene values 1/2 resolve to ref and share one phenotype."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core import OffloadConfig
    from repro.core.frontends.registry import get_frontend

    def app(xs, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        c, _ = jax.lax.scan(body, xs, None, length=3)
        return c

    xs = jnp.ones((8, 8), jnp.float32)
    w = jnp.ones((8, 8), jnp.float32) * 0.1
    fe = get_frontend("jaxpr")
    cfg = OffloadConfig(repeats=1, options={"example_args": (xs, w)})
    graph = fe.build_graph(app, None, cfg)
    bundle = fe.make_fitness(graph, app, None, cfg)
    assert bundle.impl_resolver is not None
    matched = [r.name for r in graph.offloadable()
               if r.meta.get("pattern")]
    for region in matched:
        chosen1 = bundle.impl_resolver(region, "fused_jnp")
        chosen2 = bundle.impl_resolver(region, "pallas")
        # whatever binds, resolution is deterministic and "ref" on fallback
        assert isinstance(chosen1, str) and isinstance(chosen2, str)
    # unmatched regions: any requested variant resolves to ref (substitute
    # leaves their equations untouched), so their genes are phenotype-inert
    unmatched = [r.name for r in graph.offloadable()
                 if not r.meta.get("pattern") and r.meta.get("eqn_span")]
    for region in unmatched:
        assert bundle.impl_resolver(region, "kernel") == "ref"
