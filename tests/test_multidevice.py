"""Multi-device numerical equivalence tests.

These spawn a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must be set before jax initializes, and the main test process must
keep seeing 1 device), build a (2 data, 4 model) mesh, and compare the
sharded production paths against unsharded references.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import dataclasses
    from repro.configs.base import ArchConfig, MoEConfig
    from repro.models import build_model, REFERENCE_PLAN, OFFLOAD_PLAN
    from repro.runtime import sharding as shd
    from repro.runtime.pspec import axis_rules

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = shd.make_rules(mesh)

    cfg = ArchConfig(arch_id="mini_moe", family="moe", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                     d_ff=96, vocab=256, mlp_act="silu",
                     moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96,
                                   capacity_factor=8.0),  # no drops
                     tie_embeddings=False)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = m.demo_batch(jax.random.key(1), 8, 32)  # T=256 tokens: %8==0

    plan_off = OFFLOAD_PLAN.replace(attn_kv_chunk=16, wkv_chunk=16,
                                    loss_vocab_chunk=64,
                                    compute_dtype="float32")
    plan_ref = REFERENCE_PLAN.replace(compute_dtype="float32")

    # unsharded reference (no rules context)
    l_ref, _ = jax.jit(lambda p, b: m.loss(p, b, plan_ref))(params, batch)

    # sharded offloaded path (EP MoE + shard_map flash under the mesh)
    p_axes = shd.param_logical_axes(m.param_shapes(), cfg, mesh)
    p_shard = shd.tree_shardings(rules, params, p_axes)
    params_s = jax.device_put(params, p_shard)
    b_shard = shd.tree_shardings(rules, batch, shd.batch_logical_axes(batch))
    batch_s = jax.device_put(batch, b_shard)

    def loss_sharded(p, b):
        with axis_rules(rules):
            return m.loss(p, b, plan_off)

    l_off, _ = jax.jit(loss_sharded, in_shardings=(p_shard, b_shard))(
        params_s, batch_s)
    d = abs(float(l_ref) - float(l_off))
    print(f"ref={float(l_ref):.6f} off={float(l_off):.6f} d={d:.2e}")
    assert d < 5e-3, d

    # rwkv: shard_map wkv path on the mesh
    from repro.configs import get_config
    cfg2 = get_config("rwkv6_3b").reduced()
    cfg2 = dataclasses.replace(cfg2, d_model=64, rwkv_head_dim=16)  # 4 heads
    m2 = build_model(cfg2)
    params2 = m2.init(jax.random.key(0))
    batch2 = m2.demo_batch(jax.random.key(1), 4, 32)   # B*H = 16: %8==0
    l2_ref, _ = jax.jit(lambda p, b: m2.loss(p, b, plan_ref))(params2, batch2)
    p2_axes = shd.param_logical_axes(m2.param_shapes(), cfg2, mesh)
    p2_shard = shd.tree_shardings(rules, params2, p2_axes)
    params2_s = jax.device_put(params2, p2_shard)
    b2_shard = shd.tree_shardings(rules, batch2, shd.batch_logical_axes(batch2))
    batch2_s = jax.device_put(batch2, b2_shard)

    def loss2(p, b):
        with axis_rules(rules):
            return m2.loss(p, b, plan_off.replace(wkv_chunk=8))

    l2_off, _ = jax.jit(loss2, in_shardings=(p2_shard, b2_shard))(
        params2_s, batch2_s)
    d2 = abs(float(l2_ref) - float(l2_off))
    print(f"rwkv ref={float(l2_ref):.6f} off={float(l2_off):.6f} d={d2:.2e}")
    assert d2 < 5e-3, d2
    print("MULTIDEVICE_OK")
""")


@pytest.mark.slow
def test_sharded_paths_match_reference_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MULTIDEVICE_OK" in res.stdout, (res.stdout[-2000:], res.stderr[-3000:])
