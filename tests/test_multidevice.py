"""Multi-device numerical equivalence tests.

These spawn a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must be set before jax initializes, and the main test process must
keep seeing 1 device), build a (2 data, 4 model) mesh, and compare the
sharded production paths against unsharded references.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import dataclasses
    from repro.configs.base import ArchConfig, MoEConfig
    from repro.models import build_model, REFERENCE_PLAN, OFFLOAD_PLAN
    from repro.runtime import sharding as shd
    from repro.runtime.pspec import axis_rules

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = shd.make_rules(mesh)

    cfg = ArchConfig(arch_id="mini_moe", family="moe", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                     d_ff=96, vocab=256, mlp_act="silu",
                     moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96,
                                   capacity_factor=8.0),  # no drops
                     tie_embeddings=False)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = m.demo_batch(jax.random.key(1), 8, 32)  # T=256 tokens: %8==0

    plan_off = OFFLOAD_PLAN.replace(attn_kv_chunk=16, wkv_chunk=16,
                                    loss_vocab_chunk=64,
                                    compute_dtype="float32")
    plan_ref = REFERENCE_PLAN.replace(compute_dtype="float32")

    # unsharded reference (no rules context)
    l_ref, _ = jax.jit(lambda p, b: m.loss(p, b, plan_ref))(params, batch)

    # sharded offloaded path (EP MoE + shard_map flash under the mesh)
    p_axes = shd.param_logical_axes(m.param_shapes(), cfg, mesh)
    p_shard = shd.tree_shardings(rules, params, p_axes)
    params_s = jax.device_put(params, p_shard)
    b_shard = shd.tree_shardings(rules, batch, shd.batch_logical_axes(batch))
    batch_s = jax.device_put(batch, b_shard)

    def loss_sharded(p, b):
        with axis_rules(rules):
            return m.loss(p, b, plan_off)

    l_off, _ = jax.jit(loss_sharded, in_shardings=(p_shard, b_shard))(
        params_s, batch_s)
    d = abs(float(l_ref) - float(l_off))
    print(f"ref={float(l_ref):.6f} off={float(l_off):.6f} d={d:.2e}")
    assert d < 5e-3, d

    # rwkv: shard_map wkv path on the mesh
    from repro.configs import get_config
    cfg2 = get_config("rwkv6_3b").reduced()
    cfg2 = dataclasses.replace(cfg2, d_model=64, rwkv_head_dim=16)  # 4 heads
    m2 = build_model(cfg2)
    params2 = m2.init(jax.random.key(0))
    batch2 = m2.demo_batch(jax.random.key(1), 4, 32)   # B*H = 16: %8==0
    l2_ref, _ = jax.jit(lambda p, b: m2.loss(p, b, plan_ref))(params2, batch2)
    p2_axes = shd.param_logical_axes(m2.param_shapes(), cfg2, mesh)
    p2_shard = shd.tree_shardings(rules, params2, p2_axes)
    params2_s = jax.device_put(params2, p2_shard)
    b2_shard = shd.tree_shardings(rules, batch2, shd.batch_logical_axes(batch2))
    batch2_s = jax.device_put(batch2, b2_shard)

    def loss2(p, b):
        with axis_rules(rules):
            return m2.loss(p, b, plan_off.replace(wkv_chunk=8))

    l2_off, _ = jax.jit(loss2, in_shardings=(p2_shard, b2_shard))(
        params2_s, batch2_s)
    d2 = abs(float(l2_ref) - float(l2_off))
    print(f"rwkv ref={float(l2_ref):.6f} off={float(l2_off):.6f} d={d2:.2e}")
    assert d2 < 5e-3, d2
    print("MULTIDEVICE_OK")
""")


@pytest.mark.slow
def test_sharded_paths_match_reference_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MULTIDEVICE_OK" in res.stdout, (res.stdout[-2000:], res.stderr[-3000:])


_MESH_GA_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import Evaluation, GAConfig, OffloadConfig, Offloader
    from repro.core.frontends.registry import decoded_pattern
    from repro.core.genes import probed_device_count
    from repro.core.objectives import OBJECTIVES
    from repro.service import PlanStore, record_from_result

    assert jax.device_count() == 8
    assert probed_device_count() == 8

    def app(x, w1, w2):
        h = jnp.tanh(x @ w1)
        g = jax.nn.relu(h @ w2)
        y = g * 0.5 + h * 0.1
        return jnp.tanh(y @ w1) + y

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(64, 64)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(64, 64)) * 0.1, jnp.float32)
    args = (x, w1, w2)
    ref = np.asarray(app(*args))

    cfg = OffloadConfig(
        ga=GAConfig(population=10, generations=4, seed=0,
                    objectives=OBJECTIVES),
        options={"example_args": args}, repeats=1)
    off = Offloader(cfg)
    ctx = off.prepare(app)

    # the frontend proposed this host's real meshes alongside the variants
    alpha = ctx.coding.destinations
    mesh_names = [d for d in alpha if d.startswith("mesh:")]
    assert mesh_names == ["mesh:data:2:batch", "mesh:data:4:batch",
                          "mesh:data:8:batch"], alpha
    assert ctx.bundle.mesh_executed

    # deterministic fitness that still GENUINELY executes every chromosome:
    # decode -> substitute (mesh genes become shard_map spans on the real
    # 8-device mesh) -> run -> compare against the reference.  Latency is
    # then a deterministic function of what actually ran, so the search and
    # its Pareto front are reproducible.
    engine = ctx.bundle.context["engine"]
    coding = ctx.coding
    mesh_ran = set()

    def fitness(values):
        values = tuple(values)
        impl = decoded_pattern(coding, values, {})
        sub = engine.substitute(
            impl, destinations=coding.destinations_of(values))
        out = jax.jit(sub.fn)(*args)
        ok = bool(np.allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-5))
        t = 1.0
        for c in sub.report.choices:
            if c.chosen.startswith("mesh:"):
                mesh_ran.add(c.chosen)
                t -= 0.10                      # genuinely sharded: fastest
            elif c.chosen != "ref":
                t -= 0.04                      # single-device variant
        return Evaluation(values, max(t, 0.05), ok)

    ctx.config.fitness_fn = fitness
    res = off.search(ctx)
    assert mesh_ran, "no chromosome ever reached shard_map execution"

    def is_mesh(ev):
        return any(n.startswith("mesh:")
                   for n in coding.destinations_of(ev.bits).values())

    front = res.front
    mesh_points = [ev for ev in front if is_mesh(ev)]
    single_points = [ev for ev in front if not is_mesh(ev)]
    assert mesh_points, [ev.bits for ev in front]
    assert single_points, [ev.bits for ev in front]

    # the winning mesh plan's artifact matches the single-device reference
    best_mesh = min(mesh_points, key=lambda ev: ev.time_s)
    art = off.apply(ctx, best_mesh.bits)
    got = np.asarray(jax.jit(art.fn)(*args))
    assert np.allclose(got, ref, rtol=1e-4, atol=1e-5)
    assert any(c.chosen.startswith("mesh:") and "shard_map" in c.why
               for c in art.report.choices), art.report.choices

    # store -> load -> rehydrate -> serve, with no new search
    rec = record_from_result(res, ctx.fingerprint)
    rec = dataclasses.replace(rec, bits=tuple(best_mesh.bits))
    import tempfile
    store = PlanStore(tempfile.mkdtemp(prefix="mesh_plan_store_"))
    store.put(rec)
    loaded = store.load(ctx.fingerprint)
    assert loaded.mesh_destinations(), loaded.destinations
    art2 = store.rehydrate(loaded, app, config=cfg)
    got2 = np.asarray(jax.jit(art2.fn)(*args))
    assert np.allclose(got2, ref, rtol=1e-4, atol=1e-5)
    print("MESH_GA_OK")
""")


@pytest.mark.slow
def test_mesh_ga_search_on_8_devices_matches_reference():
    """The PR-10 acceptance loop: on a forced-8-device host the GA searches
    placement x parallelism (mesh genes alongside variants), the front
    carries mesh and single-device points, the winning mesh plan's outputs
    match the single-device reference, and the PlanStore round-trips it
    into a servable artifact without a new search."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _MESH_GA_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MESH_GA_OK" in res.stdout, (res.stdout[-2000:],
                                        res.stderr[-3000:])
