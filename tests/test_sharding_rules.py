"""Sharding rules: logical-axis resolution, divisibility fallbacks, param
pattern matching.  Uses a stub mesh (rules.pspec is pure — no devices)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import sharding as shd
from repro.runtime.pspec import ShardingRules


class StubMesh:
    def __init__(self, shape: dict):
        self.shape = shape


def _rules(shape=None):
    mesh = StubMesh(shape or {"data": 16, "model": 16})
    return ShardingRules(mesh, shd.logical_table(mesh))  # type: ignore


def test_divisible_dim_shards():
    r = _rules()
    assert r.pspec((32000, 2048), ("vocab", "fsdp")) == P("model", "data")


def test_non_divisible_dim_replicates():
    r = _rules()
    # 51865 % 16 != 0 -> vocab axis dropped (whisper's vocab)
    assert r.pspec((51865, 768), ("vocab", "fsdp")) == P(None, "data")


def test_axis_used_once():
    r = _rules()
    # both dims ask for "model": second one must drop
    spec = r.pspec((1024, 2048), ("vocab", "tensor"))
    assert spec == P("model", None)


def test_multi_axis_batch():
    mesh = StubMesh({"pod": 2, "data": 16, "model": 16})
    r = ShardingRules(mesh, shd.logical_table(mesh))  # type: ignore
    assert r.pspec((256, 128), ("batch", None)) == P(("pod", "data"), None)
    # batch=8 divides pod(2) but not pod*data(32): partial prefix kept
    assert r.pspec((8, 128), ("batch", None)) == P("pod", None)
    # batch=1: fully replicated
    assert r.pspec((1, 128), ("batch", None)) == P(None, None)


def test_param_axes_head_divisibility():
    class M:
        shape = {"data": 16, "model": 16}
    cfg = get_config("tinyllama_1_1b")  # 32 q heads (div), 4 kv heads (not)
    model = build_model(cfg)
    shapes = model.param_shapes()
    axes = shd.param_logical_axes(shapes, cfg, M())  # type: ignore
    assert axes["blocks"]["attn"]["wq"] == (None, "fsdp", "tensor")
    assert axes["blocks"]["attn"]["wk"] == (None, "fsdp", None)
    assert axes["blocks"]["attn"]["wo"] == (None, "tensor", "fsdp")

    cfg2 = get_config("gemma_7b")  # 16 heads == mesh: both shard
    m2 = build_model(cfg2)
    axes2 = shd.param_logical_axes(m2.param_shapes(), cfg2, M())  # type: ignore
    assert axes2["blocks"]["attn"]["wk"] == (None, "fsdp", "tensor")


def test_moe_expert_sharding():
    class M:
        shape = {"data": 16, "model": 16}
    cfg = get_config("olmoe_1b_7b")
    model = build_model(cfg)
    axes = shd.param_logical_axes(model.param_shapes(), cfg, M())  # type: ignore
    assert axes["blocks"]["moe"]["w_gate"] == (None, "experts", "fsdp", None)
    assert axes["blocks"]["moe"]["w_down"] == (None, "experts", None, "fsdp")


def test_state_axes_kv_fallback():
    class M:
        shape = {"data": 16, "model": 16}

        def __contains__(self, x):
            return x in self.shape
    cfg = get_config("tinyllama_1_1b")  # kv=4: not divisible -> shard seq
    ax = shd._axes_for_state("kv/k", (22, 2, 32768, 4, 64), cfg, M())  # type: ignore
    assert ax == (None, "batch", "kv_seq", None, None)
    cfg2 = get_config("gemma_7b")  # kv=16: divisible -> shard heads
    ax2 = shd._axes_for_state("kv/k", (28, 2, 32768, 16, 256), cfg2, M())  # type: ignore
    assert ax2 == (None, "batch", None, "kv_heads", None)
