"""Kernel substitution engine: per-variant numeric equivalence, predicate
fallbacks, and the measured jaxpr plan -> substitute -> verify loop."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GAConfig, OffloadConfig, Offloader, SubstitutedCallable,
                        SubstitutionEngine, VARIANT_ALPHABET, plan_offload)
from repro.core.frontends import jaxpr_frontend as jf
from repro.core.pattern_db import default_db
from repro.core.verifier import verify
from repro.kernels.registry import (CallSite, VariantUnavailable,
                                    auto_variant_order, default_registry)

EXECUTABLE_VARIANTS = ("fused_jnp", "pallas")


def _arr(rng, *shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# pattern apps: traced programs containing one matchable region each
# ---------------------------------------------------------------------------


def _attention_app(q, k, v, w):
    s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
    mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), bool))
    h = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1) @ v
    def body(c, _):
        return jnp.tanh(c @ w), ()
    h, _ = jax.lax.scan(body, h, None, length=2)
    return h


def _recurrence_app(la, b):
    def step(h, ab):
        h = jnp.exp(ab[0]) * h + ab[1]
        return h, h
    _, hs = jax.lax.scan(step, jnp.zeros(la.shape[-1]), (la, b))
    return hs * 1.5


def _wkv_app(r, k, v, lw, u):
    def step(s, rkvw):
        rt, kt, vt, lwt = rkvw
        kv = kt[:, None] * vt[None, :]
        y = rt @ (s + u[:, None] * kv)
        return jnp.exp(lwt)[:, None] * s + kv, y
    _, ys = jax.lax.scan(step, jnp.zeros((r.shape[-1], v.shape[-1])),
                         (r, k, v, lw))
    return ys


@jax.jit
def _rmsnorm(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * (1 + scale)


def _rmsnorm_app(x, scale, w):
    return _rmsnorm(x, scale) @ w


def _attention_case(rng, s, d, dtype=jnp.float32):
    # q, k, v must be DISTINCT: aliased operands (q, q, q) make an operand-
    # order bug in span binding numerically invisible
    q = _arr(rng, s, d, dtype=dtype)
    k = _arr(rng, s, d, dtype=dtype)
    v = _arr(rng, s, d, dtype=dtype)
    w = _arr(rng, d, d, dtype=dtype, scale=0.1)
    return _attention_app, (q, k, v, w), "softmax_attention"


def _recurrence_case(rng, s, d, dtype=jnp.float32):
    la = -jnp.abs(_arr(rng, s, d, dtype=dtype)) * 0.2
    b = _arr(rng, s, d, dtype=dtype, scale=0.5)
    return _recurrence_app, (la, b), "linear_recurrence"


def _wkv_case(rng, s, d, dtype=jnp.float32):
    r = _arr(rng, s, d, dtype=dtype, scale=0.5)
    k = _arr(rng, s, d, dtype=dtype, scale=0.5)
    v = _arr(rng, s, d, dtype=dtype, scale=0.5)
    lw = -jnp.abs(_arr(rng, s, d, dtype=dtype)) * 0.3
    u = _arr(rng, d, dtype=dtype, scale=0.1)
    return _wkv_app, (r, k, v, lw, u), "wkv_recurrence"


def _rmsnorm_case(rng, s, d, dtype=jnp.float32):
    x = _arr(rng, s, d, dtype=dtype)
    sc = _arr(rng, d, dtype=dtype, scale=0.1)
    w = _arr(rng, d, d, dtype=dtype)
    return _rmsnorm_app, (x, sc, w), "rmsnorm"


CASES = {
    "softmax_attention": _attention_case,
    "linear_recurrence": _recurrence_case,
    "wkv_recurrence": _wkv_case,
    "rmsnorm": _rmsnorm_case,
}


def _engine_for(fn, args):
    graph = jf.build_graph(fn, *args)
    jf.annotate_variants(graph, default_db())
    return SubstitutionEngine(fn, args, graph)


def _matched_region(engine, pattern):
    regions = [r.name for r in engine.graph.offloadable()
               if r.meta.get("pattern") == pattern]
    assert regions, f"no region matched {pattern}"
    return regions[0]


# ---------------------------------------------------------------------------
# per-variant numeric equivalence: every registry entry, >= 2 shapes/dtypes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pattern", sorted(CASES))
@pytest.mark.parametrize("variant", EXECUTABLE_VARIANTS)
@pytest.mark.parametrize("s,d,dtype", [
    (24, 8, jnp.float32),
    (33, 16, jnp.float32),       # ragged length exercises kernel padding
    (16, 8, jnp.bfloat16),
])
def test_variant_numeric_equivalence(rng, pattern, variant, s, d, dtype):
    fn, args, pat = CASES[pattern](rng, s, d, dtype=dtype)
    engine = _engine_for(fn, args)
    region = _matched_region(engine, pat)
    sub = engine.substitute({region: variant})
    assert sub.report.substituted == {region: variant}, \
        sub.report.fallbacks
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-2
    res = verify(engine.reference(), sub(*args), rtol=tol, atol=tol)
    assert res.ok, (pattern, variant, res)


def test_registry_covers_all_patterns():
    reg = default_registry()
    for pattern in CASES:
        assert set(reg.variant_names(pattern)) == set(EXECUTABLE_VARIANTS)
    assert set(auto_variant_order("tpu")) == set(EXECUTABLE_VARIANTS)
    assert auto_variant_order("cpu")[0] == "fused_jnp"
    assert auto_variant_order("tpu")[0] == "pallas"


# ---------------------------------------------------------------------------
# predicate rejection -> reference fallback, recorded and correct
# ---------------------------------------------------------------------------


def test_predicate_rejection_falls_back_to_ref(rng):
    # k/v shapes disagree with what the attention adapters accept (v has a
    # different head dim), so every variant must refuse and the engine must
    # run the original equations — bit-identically
    def odd_attention(q, k, v):
        s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
        mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), bool))
        return jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1) @ v

    q = _arr(rng, 16, 8)
    v = _arr(rng, 16, 4)                      # head-dim mismatch vs q/k
    engine = _engine_for(odd_attention, (q, q, v))
    region = _matched_region(engine, "softmax_attention")
    for variant in EXECUTABLE_VARIANTS:
        sub = engine.substitute({region: variant})
        assert sub.report.substituted == {}
        assert region in sub.report.fallbacks
        assert variant in sub.report.fallbacks[region]
        # jit-vs-eager numerics differ only in fusion rounding
        np.testing.assert_allclose(
            np.asarray(sub(q, q, v)), np.asarray(odd_attention(q, q, v)),
            rtol=1e-5, atol=1e-5)


def test_scan_structure_rejection(rng):
    # a reverse scan must not bind the recurrence kernels
    def rev_rec(la, b):
        def step(h, ab):
            return jnp.exp(ab[0]) * h + ab[1], h
        _, hs = jax.lax.scan(step, jnp.zeros(la.shape[-1]), (la, b),
                             reverse=True)
        return hs

    la = _arr(rng, 12, 4)
    engine = _engine_for(rev_rec, (la, la))
    for r in engine.graph.offloadable():
        if r.meta.get("pattern") == "linear_recurrence":
            sub = engine.substitute({r.name: "pallas"})
            assert sub.report.substituted == {}
            np.testing.assert_allclose(
                np.asarray(sub(la, la)), np.asarray(rev_rec(la, la)),
                rtol=1e-5, atol=1e-5)


def test_carry_only_scan_rejects_instead_of_crashing(rng):
    # ys=None scan: one output, not (carry, ys) — the recurrence predicates
    # must refuse (VariantUnavailable -> ref fallback), not IndexError
    def carry_only(la, b):
        def step(h, ab):
            return jnp.exp(ab[0]) * h + ab[1], None
        h, _ = jax.lax.scan(step, jnp.zeros(la.shape[-1]), (la, b))
        return h

    la = _arr(rng, 12, 4)
    engine = _engine_for(carry_only, (la, la))
    for r in engine.graph.offloadable():
        sub = engine.substitute({r.name: "pallas"})
        assert sub.report.substituted == {}
        np.testing.assert_allclose(
            np.asarray(sub(la, la)), np.asarray(carry_only(la, la)),
            rtol=1e-5, atol=1e-5)


def test_unknown_impl_and_unmatched_region_fall_back(rng):
    fn, args, pat = _recurrence_case(rng, 12, 4)
    engine = _engine_for(fn, args)
    region = _matched_region(engine, pat)
    sub = engine.substitute({region: "no-such-variant"})
    assert sub.report.substituted == {}
    assert "unknown implementation" in sub.report.fallbacks[region]
    # "kernel" (legacy auto) resolves to the backend-preferred variant
    sub2 = engine.substitute({region: "kernel"})
    assert sub2.report.substituted == {region: auto_variant_order(
        jax.default_backend())[0]}


def test_substituted_callable_is_reusable_and_jitted(rng):
    fn, args, pat = _rmsnorm_case(rng, 16, 8)
    engine = _engine_for(fn, args)
    region = _matched_region(engine, pat)
    sub = engine.substitute({region: "fused_jnp"})
    assert isinstance(sub, SubstitutedCallable)
    first = np.asarray(sub(*args))
    second = np.asarray(sub(*args))          # cached jit path
    np.testing.assert_array_equal(first, second)
    assert "fused_jnp" in repr(sub)


# ---------------------------------------------------------------------------
# the measured jaxpr pipeline end to end (the PR's acceptance loop)
# ---------------------------------------------------------------------------


def test_jaxpr_plan_measures_substituted_callable(rng):
    fn, args, _ = _attention_case(rng, 32, 16)
    cfg = OffloadConfig(ga=GAConfig(population=6, generations=2, seed=0),
                        options={"example_args": args}, repeats=1)
    res = Offloader(cfg).plan(fn)

    # gene alphabet: the frontend proposed the variant alphabet
    assert res.coding.destinations == VARIANT_ALPHABET
    # speedup comes from wall-clock measurement, not the static stub
    assert res.verification["mode"] == "measured"
    assert res.verification["verified"]
    assert "static_cost" not in res.best.detail
    assert math.isfinite(res.baseline.time_s) and res.baseline.time_s > 0
    assert math.isfinite(res.speedup)
    # the artifact is a runnable substituted callable whose outputs verify
    # against the unsubstituted reference
    assert isinstance(res.artifact, SubstitutedCallable)
    v = verify(fn(*args), res.artifact(*args))
    assert v.ok, v
    # every accelerated gene decodes to a registry variant at its site
    decoded = res.coding.decode(res.best.bits)
    for region, impl in decoded.items():
        assert res.pattern[region] == impl


def test_jaxpr_plan_forced_substitution_verifies(rng):
    # pin the fitness so the search is deterministic, then check that the
    # engine the bundle carries substitutes the matched attention block
    fn, args, _ = _attention_case(rng, 32, 16)
    cfg = OffloadConfig(ga=GAConfig(population=6, generations=2, seed=0),
                        options={"example_args": args}, repeats=1)
    res = Offloader(cfg).plan(fn)
    engine = res.details["engine"]
    region = _matched_region(engine, "softmax_attention")
    for variant in EXECUTABLE_VARIANTS:
        v = engine.verify({region: variant})
        assert v.ok, (variant, v)


def test_jaxpr_static_cost_path_is_opt_in(rng):
    fn, args, _ = _attention_case(rng, 16, 8)
    res = plan_offload(fn, config=OffloadConfig(
        ga=GAConfig(population=6, generations=2, seed=0),
        options={"example_args": args, "static_cost": True}))
    assert res.verification["mode"] == "static-cost"
    assert not res.verification["verified"]
    assert res.best.detail.get("static_cost")
    assert isinstance(res.artifact, dict)    # impl map, not a callable


# ---------------------------------------------------------------------------
# function-block substitution: whole-span equivalence, claiming, fallbacks
# ---------------------------------------------------------------------------

BLOCK_VARIANTS = ("block_chunked", "block_fused")


def _attention_stack_case(rng, s=64, d=16):
    @jax.jit
    def attention(q, k, v):
        sc = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
        mask = jnp.tril(jnp.ones((q.shape[0], q.shape[0]), bool))
        return jax.nn.softmax(jnp.where(mask, sc, -1e30), axis=-1) @ v

    def model(x, scale, wq, wk, wv, wo):
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        xn = x * jax.lax.rsqrt(var + 1e-6) * (1.0 + scale)
        q = xn @ wq
        k = xn @ wk
        v = xn @ wv
        o = attention(q, k, v)
        return x + o @ wo

    x = _arr(rng, s, d)
    scale = _arr(rng, d, scale=0.1)
    wq, wk, wv, wo = (_arr(rng, d, d, scale=1.0 / math.sqrt(d))
                      for _ in range(4))
    return model, (x, scale, wq, wk, wv, wo)


def _block_engine(rng):
    model, args = _attention_stack_case(rng)
    graph = jf.build_graph(model, *args)
    jf.annotate_variants(graph, default_db())
    jf.annotate_block_sites(graph, default_db())
    engine = SubstitutionEngine(model, args, graph)
    blocks = [r for r in graph.regions if r.meta.get("block_members")]
    assert blocks, "attention stack must produce a function-block region"
    return engine, blocks[0], model, args


@pytest.mark.parametrize("variant", BLOCK_VARIANTS)
def test_block_substitution_equivalence(rng, variant):
    engine, fb, model, args = _block_engine(rng)
    assert set(fb.alternatives) >= {"ref"} | set(BLOCK_VARIANTS)

    # block-granularity verification: adapter vs reference over the span
    res, chosen = engine.verify_block(fb.name, variant)
    assert chosen == variant
    assert res.ok, (variant, res)

    # whole-program substitution: the block adapter re-emits the span and
    # the claimed members drop to their reference path, reported as such
    sub = engine.substitute({fb.name: variant})
    assert sub.report.substituted[fb.name] == variant
    by_region = {c.region: c for c in sub.report.choices}
    for member in fb.meta["block_members"]:
        assert by_region[member].chosen == "ref"
        assert f"claimed by block {fb.name}" in by_region[member].why
    v = verify(model(*args), sub(*args))
    assert v.ok, (variant, v)


def test_block_gene_overrides_member_requests(rng):
    # a chromosome that turns on the block AND a claimed member: the block
    # wins, the member's request is overridden to ref (one owner per span)
    engine, fb, model, args = _block_engine(rng)
    member = fb.meta["block_members"][-1]
    sub = engine.substitute({fb.name: "block_fused", member: "fused_jnp"})
    assert sub.report.substituted == {fb.name: "block_fused"}
    choice = next(c for c in sub.report.choices if c.region == member)
    assert choice.chosen == "ref"
    assert f"claimed by block {fb.name}" in choice.why
    v = verify(model(*args), sub(*args))
    assert v.ok, v


def test_block_unknown_impl_releases_members(rng):
    # the block falls back to ref -> the members stay their own regions
    # (loop-level substitution still possible on them)
    engine, fb, model, args = _block_engine(rng)
    sub = engine.substitute({fb.name: "no-such-variant"})
    assert fb.name not in sub.report.substituted
    assert "unknown implementation" in sub.report.fallbacks[fb.name]
    for c in sub.report.choices:
        assert "claimed by block" not in c.why
    np.testing.assert_allclose(np.asarray(sub(*args)),
                               np.asarray(model(*args)),
                               rtol=1e-5, atol=1e-5)
    # verify_block on the same request is trivially the reference path
    res, chosen = engine.verify_block(fb.name, "no-such-variant")
    assert chosen == "ref" and res.ok


def test_block_predicate_rejection_falls_back_to_ref():
    # head dim beyond the kernel range: every attention_stack variant must
    # refuse via its predicate, and the shared fallback rule yields ref
    from repro.core.variants import resolve_variant

    d = 600                              # > the binder's 512 head-dim cap
    f32 = jnp.float32
    av = lambda *shape: jax.ShapeDtypeStruct(shape, f32)   # noqa: E731
    site = CallSite(pattern="attention_stack", kind="block",
                    in_avals=(av(32, d), av(d), av(d, d), av(d, d),
                              av(d, d)),
                    out_avals=(av(32, d),), out_used=(True,))
    for variant in BLOCK_VARIANTS:
        adapter, chosen, why = resolve_variant(site, variant)
        assert adapter is None and chosen == "ref"
        assert "head dim outside kernel range" in why


def test_block_sites_opt_out_leaves_graph_loop_only(rng):
    model, args = _attention_stack_case(rng)
    cfg = OffloadConfig(ga=GAConfig(population=6, generations=2, seed=0),
                        options={"example_args": args,
                                 "block_sites": False}, repeats=1)
    fe_res = Offloader(cfg).plan(model)
    assert not any(r.meta.get("block_members")
                   for r in fe_res.graph.regions)


def test_invalid_variant_result_is_rejected_by_verifier(rng):
    # non-causal attention *name*-matched to the causal kernels: the
    # substitution binds, but the output diverges -> the verifier rejects it
    # and the fitness marks the chromosome invalid (the paper's PCAST flow)
    @jax.jit
    def attention(q, k, v):                  # name match: "attention"
        s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
        return jax.nn.softmax(s, axis=-1) @ v    # NOT causal

    def noncausal_app(q, k, v, w):
        return jnp.tanh(attention(q, k, v) @ w)

    q = _arr(rng, 32, 16)
    w = _arr(rng, 16, 16, scale=0.1)
    graph = jf.build_graph(noncausal_app, q, q, q, w)
    jf.annotate_variants(graph, default_db())
    matched = [r.name for r in graph.offloadable()
               if r.meta.get("pattern") == "softmax_attention"]
    assert matched, "named call must name-match the attention pattern"
    engine = SubstitutionEngine(noncausal_app, (q, q, q, w), graph)
    v = engine.verify({matched[0]: "fused_jnp"})
    assert not v.ok                       # causal kernel != non-causal block

    # and through the pipeline: the same chromosome is measured invalid,
    # so the GA's winner keeps a verified pattern
    cfg = OffloadConfig(ga=GAConfig(population=6, generations=2, seed=0),
                        options={"example_args": (q, q, q, w)}, repeats=1)
    res = Offloader(cfg).plan(noncausal_app)
    assert res.verification["verified"]
    assert res.pattern[matched[0]] == "ref"


# ---------------------------------------------------------------------------
# mesh destinations: genuine shard_map execution and cost-only fallback
# ---------------------------------------------------------------------------


def test_mesh_destination_executes_span_under_shard_map(rng):
    # n=1 is a degenerate but *genuine* mesh: available on any host, so the
    # full route — gene name -> _mesh_adapter -> shard_map span -> numerics —
    # runs in-process on single-device CI
    fn, args, pat = _rmsnorm_case(rng, 16, 8)
    engine = _engine_for(fn, args)
    region = _matched_region(engine, pat)
    sub = engine.substitute({region: "ref"},
                            destinations={region: "mesh:data:1:batch"})
    choice = next(c for c in sub.report.choices if c.region == region)
    assert choice.requested == "mesh:data:1:batch"
    assert choice.chosen == "mesh:data:1:batch"
    assert "shard_map" in choice.why
    v = verify(fn(*args), sub(*args))
    assert v.ok, v


def test_mesh_unavailable_falls_back_to_variant_with_reason(rng):
    # single-device host, 8-way mesh: cost-only — the site takes the normal
    # variant path and the report says why
    fn, args, pat = _rmsnorm_case(rng, 16, 8)
    engine = _engine_for(fn, args)
    region = _matched_region(engine, pat)
    sub = engine.substitute({region: "fused_jnp"},
                            destinations={region: "mesh:data:8:batch"})
    choice = next(c for c in sub.report.choices if c.region == region)
    assert choice.requested == "mesh:data:8:batch"
    assert "unavailable" in choice.why and "modeled cost" in choice.why
    assert choice.chosen == "fused_jnp"
    v = verify(fn(*args), sub(*args))
    assert v.ok, v


def test_mesh_shape_rejection_falls_back_with_reason(rng):
    # batch extent 15 does not divide n=1? it does — use an indivisible
    # mesh instead: extent 15 on a 1-device mesh is fine, so force the
    # reject through a scalar-output span (no sharded dimension)
    def scalar_app(x, w):
        return jnp.sum(jnp.tanh(x @ w) ** 2) + jnp.sum(x * x) * 0.5

    x = _arr(rng, 8, 8)
    w = _arr(rng, 8, 8, scale=0.1)
    graph = jf.build_graph(scalar_app, x, w)
    jf.annotate_variants(graph, default_db())
    regions = [r.name for r in graph.offloadable()]
    assert regions
    engine = SubstitutionEngine(scalar_app, (x, w), graph)
    sub = engine.substitute({regions[0]: "ref"},
                            destinations={regions[0]: "mesh:data:1:batch"})
    choice = next(c for c in sub.report.choices if c.region == regions[0])
    assert "rejected" in choice.why
    assert choice.chosen == "ref"
    v = verify(scalar_app(x, w), sub(x, w))
    assert v.ok, v
