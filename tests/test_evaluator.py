"""Evaluation-engine tests: batched dispatch, persistent measurement cache,
in-flight dedup under concurrency, duplicate-avoiding offspring, surrogate
pre-screening, and serial/parallel GA equivalence at fixed seed."""
import threading
import time

import pytest

from repro.core.evaluator import Evaluator, transfer_cost_surrogate
from repro.core.ga import Evaluation, GAConfig, run_ga
from repro.core.genes import coding_from_graph
from repro.core.ir import Region, RegionGraph


def _counting_fitness(calls, cost=None, delay=0.0):
    def fit(bits):
        calls.append(bits)
        if delay:
            time.sleep(delay)
        t = cost(bits) if cost else 1.0 + 0.1 * sum(bits)
        return Evaluation(bits, t, True)
    return fit


# ---------------------------------------------------------------------------
# batching + dedup
# ---------------------------------------------------------------------------


def test_batch_dedups_within_population():
    calls = []
    ev = Evaluator(_counting_fitness(calls))
    res = ev.evaluate_batch([(0, 1), (1, 0), (0, 1), (0, 1)])
    assert len(calls) == 2
    assert [r.bits for r in res] == [(0, 1), (1, 0), (0, 1), (0, 1)]
    assert res[0].time_s == res[2].time_s == res[3].time_s
    assert ev.stats.measurements == 2
    assert ev.stats.measurements_saved == 2        # two in-batch duplicates


def test_batch_hits_memory_cache_across_generations():
    calls = []
    ev = Evaluator(_counting_fitness(calls))
    ev.evaluate_batch([(0, 0), (1, 1)])
    ev.evaluate_batch([(0, 0), (1, 0)])
    assert len(calls) == 3
    assert ev.stats.cache_hits == 1


def test_parallel_results_match_serial_order():
    calls_s, calls_p = [], []
    pop = [(i % 2, (i // 2) % 2, (i // 4) % 2) for i in range(8)]
    serial = Evaluator(_counting_fitness(calls_s)).evaluate_batch(pop)
    parallel = Evaluator(_counting_fitness(calls_p, delay=0.01),
                         workers=4).evaluate_batch(pop)
    assert [r.bits for r in serial] == [r.bits for r in parallel]
    assert [r.time_s for r in serial] == [r.time_s for r in parallel]
    assert sorted(calls_s) == sorted(calls_p)      # same unique measurements


# ---------------------------------------------------------------------------
# persistent cache
# ---------------------------------------------------------------------------


def test_cache_persists_across_engine_instances(tmp_path):
    calls = []
    fit = _counting_fitness(calls)
    e1 = Evaluator(fit, cache_dir=str(tmp_path), fingerprint="prog-a")
    e1.evaluate_batch([(0, 1), (1, 1)])
    assert len(calls) == 2

    e2 = Evaluator(fit, cache_dir=str(tmp_path), fingerprint="prog-a")
    res = e2.evaluate_batch([(0, 1), (1, 1), (1, 0)])
    assert len(calls) == 3                         # only (1,0) re-measured
    assert e2.stats.persistent_hits == 2
    assert res[0].time_s == pytest.approx(1.1)

    # a different program fingerprint must NOT see prog-a's measurements
    e3 = Evaluator(fit, cache_dir=str(tmp_path), fingerprint="prog-b")
    e3.evaluate_batch([(0, 1)])
    assert len(calls) == 4


def test_worker_failure_is_transient_not_cached(tmp_path):
    """A dead worker / broken pool must not poison the measurement cache."""
    class FailingExecutor:
        def submit(self, fn, *a):
            fut = __import__("concurrent.futures", fromlist=["Future"]).Future()
            fut.set_exception(RuntimeError("worker killed"))
            return fut

    ev = Evaluator(None, executor=FailingExecutor(), dispatch_fn=lambda b: b,
                   cache_dir=str(tmp_path), fingerprint="p")
    res = ev.evaluate((1, 0))
    assert not res.valid and res.detail.get("transient")
    assert ev.stats.measurements == 0
    assert not ev.is_measured((1, 0))               # retry stays possible

    # a fresh engine over the same cache dir sees nothing poisoned
    calls = []
    ev2 = Evaluator(_counting_fitness(calls),
                    cache_dir=str(tmp_path), fingerprint="p")
    assert ev2.evaluate((1, 0)).valid and len(calls) == 1


def test_persistent_cache_preserves_invalid_results(tmp_path):
    def fit(bits):
        return Evaluation(bits, float("inf"), False, {"error": "OOM"})
    e1 = Evaluator(fit, cache_dir=str(tmp_path), fingerprint="p")
    e1.evaluate((1,))
    e2 = Evaluator(lambda b: pytest.fail("must not re-measure"),
                   cache_dir=str(tmp_path), fingerprint="p")
    res = e2.evaluate((1,))
    assert not res.valid and res.time_s == float("inf")
    assert res.detail["error"] == "OOM"


# ---------------------------------------------------------------------------
# in-flight dedup under concurrency
# ---------------------------------------------------------------------------


def test_inflight_dedup_under_concurrency():
    calls = []
    started = threading.Event()
    release = threading.Event()

    def fit(bits):
        calls.append(bits)
        started.set()
        release.wait(timeout=5)
        return Evaluation(bits, 1.0, True)

    ev = Evaluator(fit, workers=2)
    out = {}

    def first():
        out["a"] = ev.evaluate((1, 0))

    def second():
        out["b"] = ev.evaluate((1, 0))

    t1 = threading.Thread(target=first)
    t1.start()
    assert started.wait(timeout=5)                 # measurement in flight
    t2 = threading.Thread(target=second)
    t2.start()
    time.sleep(0.05)                               # let t2 reach the join
    release.set()
    t1.join(timeout=5)
    t2.join(timeout=5)
    assert len(calls) == 1                         # measured exactly once
    assert out["a"].time_s == out["b"].time_s == 1.0
    assert ev.stats.inflight_hits == 1
    ev.close()


# ---------------------------------------------------------------------------
# surrogate pre-screening
# ---------------------------------------------------------------------------


def test_surrogate_screens_but_never_scores():
    calls = []
    ev = Evaluator(_counting_fitness(calls),
                   surrogate=lambda b: -sum(b),    # rank: more offload first
                   screen_top_k=2)
    pop = [(0, 0, 1), (1, 1, 1), (1, 0, 0), (0, 1, 1)]
    res = ev.evaluate_batch(pop)
    assert len(calls) == 2 and ev.stats.screened_out == 2
    assert set(calls) == {(1, 1, 1), (0, 1, 1)}
    # screened chromosomes are unmeasured (zero fitness), not surrogate-scored
    screened = [r for r in res if r.detail.get("screened")]
    assert all(not r.valid and r.fitness == 0.0 for r in screened)
    # measurement stays the final arbiter: a screened pattern measured later
    res2 = ev.evaluate((0, 0, 1))
    assert res2.valid and len(calls) == 3


def test_transfer_cost_surrogate_prefers_fewer_transfers():
    regions = [
        Region("outer", "loop", trip_count=100, offloadable=False),
        Region("hot", "loop", parent="outer", depth=1, uses=frozenset({"a"}),
               defs=frozenset({"a"}), offloadable=True,
               alternatives=("interp", "jit"), trip_count=10),
        Region("cold", "loop", uses=frozenset({"b"}), defs=frozenset({"b"}),
               offloadable=True, alternatives=("interp", "jit"), trip_count=2),
    ]
    g = RegionGraph(regions, "python_ast", "t")
    coding = coding_from_graph(g)
    cost = transfer_cost_surrogate(g, coding)
    # offloading everything hoists transfers; costs are finite and ordered
    assert cost(coding.all_on()) <= cost(coding.all_off()) + 1e6
    assert cost(coding.all_on()) == cost(coding.all_on())  # memoized, stable


# ---------------------------------------------------------------------------
# GA integration: duplicate avoidance, reproducibility, cache reuse
# ---------------------------------------------------------------------------


def test_ga_duplicate_avoiding_offspring_explores_more():
    # 3-bit space (8 patterns), population 8: without duplicate avoidance the
    # GA keeps re-proposing measured patterns; with it, coverage is complete
    def make_fit(calls):
        return _counting_fitness(calls)

    calls_on, calls_off = [], []
    res_on = run_ga(3, make_fit(calls_on),
                    GAConfig(population=8, generations=6, seed=5,
                             dup_retries=3))
    res_off = run_ga(3, make_fit(calls_off),
                     GAConfig(population=8, generations=6, seed=5,
                              dup_retries=0))
    assert len(set(calls_on)) >= len(set(calls_off))
    assert len(set(calls_on)) == 8                 # full coverage
    assert res_on.duplicates_avoided > 0
    assert res_off.duplicates_avoided == 0
    assert res_on.best.time_s <= res_off.best.time_s


def test_ga_serial_parallel_identical_at_fixed_seed():
    def fit(bits):
        return Evaluation(bits, 1.0 + 0.07 * sum(b * (i + 1) for i, b in
                                                 enumerate(bits)) % 0.9, True)
    cfg = dict(population=10, generations=6, seed=7)
    r_ser = run_ga(6, fit, GAConfig(**cfg, workers=0))
    r_par = run_ga(6, fit, GAConfig(**cfg, workers=4))
    assert r_ser.best.bits == r_par.best.bits
    assert r_ser.best.time_s == r_par.best.time_s
    assert [h["best_time_s"] for h in r_ser.history] == \
        [h["best_time_s"] for h in r_par.history]
    assert [h["mean_time_s"] for h in r_ser.history] == \
        [h["mean_time_s"] for h in r_par.history]
    assert r_ser.evaluations == r_par.evaluations


def test_ga_persistent_cache_reduces_measurements(tmp_path):
    calls = []
    fit = _counting_fitness(calls)

    def run(seed):
        ev = Evaluator(fit, cache_dir=str(tmp_path), fingerprint="ga-prog")
        try:
            return run_ga(3, fit, GAConfig(population=8, generations=5,
                                           seed=seed), evaluator=ev)
        finally:
            ev.close()

    r1 = run(0)
    n1 = len(calls)
    assert n1 == r1.evaluations > 0
    r2 = run(0)
    assert len(calls) - n1 < n1                    # warm start: fewer new
    assert r2.persistent_hits > 0
    assert r2.best.time_s <= r1.best.time_s
    assert r2.measurements_saved > 0


def test_ga_reports_search_wall_clock():
    res = run_ga(4, lambda b: Evaluation(b, 1.0 + sum(b), True),
                 GAConfig(population=6, generations=3, seed=0))
    assert res.wall_s > 0
    assert 0 < res.eval_wall_s <= res.wall_s


# ---------------------------------------------------------------------------
# phenotype dedup: decode-equivalent chromosomes share one measurement
# ---------------------------------------------------------------------------


def _variant_graph():
    return RegionGraph([
        Region("matched", "loop", uses=frozenset({"a"}),
               defs=frozenset({"a"}), offloadable=True,
               alternatives=("ref", "fused_jnp", "pallas"), trip_count=4),
        Region("plain", "loop", uses=frozenset({"b"}), defs=frozenset({"b"}),
               offloadable=True, alternatives=("ref", "kernel"),
               trip_count=2),
    ], "ir", "pheno")


def test_phenotype_dedup_measures_decode_equivalent_once():
    from repro.core.genes import VARIANT_ALPHABET
    from repro.core.offload import phenotype_key

    g = _variant_graph()
    coding = coding_from_graph(g, destinations=VARIANT_ALPHABET)
    calls = []
    ev = Evaluator(_counting_fitness(calls),
                   phenotype_key=phenotype_key(coding))
    # gene 1 and 2 on the clamped 2-impl site decode identically ("kernel")
    out = ev.evaluate_batch([(0, 1), (0, 2), (0, 0)])
    assert len(calls) == 2, "decode-equivalent chromosomes measured once"
    assert out[0].time_s == out[1].time_s
    # results are re-labelled with the *requesting* chromosome's bits
    assert out[0].bits == (0, 1) and out[1].bits == (0, 2)
    assert ev.stats.measurements == 2
    assert ev.stats.measurements_saved >= 1
    # is_measured sees through the phenotype too (dup-avoiding offspring)
    assert ev.is_measured((0, 2)) and ev.is_measured((0, 1))
    ev.close()


def test_phenotype_dedup_reaches_persistent_cache(tmp_path):
    from repro.core.genes import VARIANT_ALPHABET
    from repro.core.offload import phenotype_key

    g = _variant_graph()
    coding = coding_from_graph(g, destinations=VARIANT_ALPHABET)
    key = phenotype_key(coding)
    calls = []
    ev1 = Evaluator(_counting_fitness(calls), cache_dir=str(tmp_path),
                    fingerprint="pheno", phenotype_key=key)
    ev1.evaluate((0, 1))
    ev1.close()
    # a NEW engine loads the persisted measurement and serves the
    # decode-equivalent sibling from it — zero new measurements
    ev2 = Evaluator(_counting_fitness(calls), cache_dir=str(tmp_path),
                    fingerprint="pheno", phenotype_key=key)
    out = ev2.evaluate((0, 2))
    assert len(calls) == 1
    assert out.bits == (0, 2)
    assert ev2.stats.persistent_hits == 1
    ev2.close()


def test_ga_search_dedups_phenotypes_end_to_end():
    from repro.core.genes import VARIANT_ALPHABET
    from repro.core.offload import ga_search

    g = _variant_graph()
    coding = coding_from_graph(g, destinations=VARIANT_ALPHABET)
    calls = []
    _, ga = ga_search(g, _counting_fitness(calls), GAConfig(
        population=8, generations=6, seed=3), coding=coding)
    decoded = {tuple(sorted(coding.decode(b).items())) for b in calls}
    assert len(decoded) == len(calls), \
        "every verification measurement must buy a distinct program"


# ---------------------------------------------------------------------------
# search-meta staleness decay
# ---------------------------------------------------------------------------


def test_search_meta_decay_boundary(tmp_path):
    from repro.core.evaluator import (_SEARCH_META_HORIZON_S, last_rank_corr,
                                      record_search_meta)

    d = str(tmp_path)
    record_search_meta(d, "fp", 0.9, now=1_000.0)
    # fresh inside the horizon, stale one tick past it
    assert last_rank_corr(d, "fp", max_age_s=100.0, now=1_099.9) == 0.9
    assert last_rank_corr(d, "fp", max_age_s=100.0, now=1_100.1) is None
    # the default horizon applies when none is given
    assert last_rank_corr(d, "fp", now=1_000.0 + _SEARCH_META_HORIZON_S - 1) \
        == 0.9
    assert last_rank_corr(d, "fp", now=1_000.0 + _SEARCH_META_HORIZON_S + 1) \
        is None


def test_search_meta_stale_records_compact_away(tmp_path):
    import json
    import os

    from repro.core.evaluator import (_SEARCH_META_FILE, last_rank_corr,
                                      record_search_meta)

    d = str(tmp_path)
    record_search_meta(d, "old", 0.8, now=1_000.0, horizon_s=50.0)
    record_search_meta(d, "new", 0.7, now=2_000.0, horizon_s=50.0)
    path = os.path.join(d, _SEARCH_META_FILE)
    with open(path) as f:
        recs = [json.loads(line) for line in f if line.strip()]
    assert [r["fingerprint"] for r in recs] == ["new"], \
        "expired records must be compacted away, not just ignored"
    assert last_rank_corr(d, "old", now=2_000.0) is None


def test_search_meta_legacy_records_without_ts_are_stale(tmp_path):
    import json
    import os

    from repro.core.evaluator import _SEARCH_META_FILE, last_rank_corr

    path = os.path.join(str(tmp_path), _SEARCH_META_FILE)
    with open(path, "w") as f:
        f.write(json.dumps({"fingerprint": "fp", "rank_corr": 0.9}) + "\n")
    assert last_rank_corr(str(tmp_path), "fp") is None


def test_auto_screen_ignores_stale_rank_corr(tmp_path):
    import json
    import os

    from repro.core.evaluator import _SEARCH_META_FILE
    from repro.core.offload import ga_search

    g = RegionGraph([
        Region(f"r{i}", "loop", uses=frozenset({f"v{i}"}),
               defs=frozenset({f"v{i}"}), offloadable=True,
               alternatives=("ref", "kernel"), trip_count=2 + i)
        for i in range(6)], "ir", "stale")

    def fit(values):
        return Evaluation(tuple(values),
                          1.0 + sum(int(v) * (i + 1)
                                    for i, v in enumerate(values)), True)

    cfg = GAConfig(population=8, generations=4, seed=1,
                   cache_dir=str(tmp_path))
    _, ga1 = ga_search(g, fit, cfg)
    assert ga1.surrogate_rank_corr >= cfg.auto_screen_corr

    # age the recorded evidence past the horizon: auto-screen must not act
    path = os.path.join(str(tmp_path), _SEARCH_META_FILE)
    with open(path) as f:
        recs = [json.loads(line) for line in f if line.strip()]
    for rec in recs:
        rec["ts"] = rec["ts"] - cfg.auto_screen_horizon_s - 10.0
    with open(path, "w") as f:
        for rec in recs:
            f.write(json.dumps(rec) + "\n")

    _, ga2 = ga_search(g, fit, GAConfig(population=8, generations=4, seed=2,
                                        cache_dir=str(tmp_path)))
    assert ga2.screened_out == 0, "stale evidence must not justify screening"


# ---------------------------------------------------------------------------
# compile-overlap adaptive backoff
# ---------------------------------------------------------------------------


class _TwoPhaseFitness:
    """prepare/measure fitness whose prepare either parallelizes (sleep
    releases the GIL, like one big XLA compile) or is lock-serialized with
    extra contention overhead (like many small GIL-held compiles)."""

    def __init__(self, prep_s=0.02, contended=False):
        self.prep_s = prep_s
        self.contended = contended
        self._lock = threading.Lock()

    def prepare(self, bits):
        if not self.contended:
            time.sleep(self.prep_s)
        elif self._lock.acquire(blocking=False):
            time.sleep(self.prep_s)          # ran alone: the solo cost
            self._lock.release()
        else:
            with self._lock:                 # queued behind another prepare:
                time.sleep(self.prep_s * 1.5)  # serialized + thrash overhead
        return tuple(bits)

    def measure(self, prep):
        return Evaluation(tuple(prep), 1.0 + 0.1 * sum(prep), True)

    def __call__(self, bits):
        return self.measure(self.prepare(bits))


def _distinct_pop(n, length=8):
    return [tuple(1 if j == i else 0 for j in range(length))
            for i in range(n)]


def test_overlap_estimates_savings_when_compiles_parallelize():
    eng = Evaluator(_TwoPhaseFitness(contended=False), compile_workers=4)
    for lo in range(0, 8, 4):
        eng.evaluate_batch(_distinct_pop(8)[lo:lo + 4])
    assert eng.stats.overlapped_compiles == 8
    assert not eng.stats.overlap_disabled
    assert eng.stats.overlap_est_saved_s > 0.0
    assert eng.stats.compile_overlap_saved_s > 0.0


def test_overlap_disables_itself_under_contention():
    eng = Evaluator(_TwoPhaseFitness(contended=True), compile_workers=4)
    pop = _distinct_pop(12)
    for lo in range(0, 8, 4):
        eng.evaluate_batch(pop[lo:lo + 4])
    # two probed batches with a negative cumulative estimate trip the
    # backoff for the evaluator's lifetime
    assert eng.stats.overlap_disabled
    assert eng.stats.overlap_est_saved_s < 0.0
    overlapped_before = eng.stats.overlapped_compiles
    eng.evaluate_batch(pop[8:12])
    assert eng.stats.overlapped_compiles == overlapped_before, \
        "post-backoff batches must warm up serially"


# ---------------------------------------------------------------------------
# measurement-journal bounding
# ---------------------------------------------------------------------------


def test_measurement_journal_stays_bounded_and_keeps_newest(tmp_path):
    from repro.core.evaluator import MeasurementCache

    def bits(i, length=5):
        return tuple((i >> j) & 1 for j in range(length))

    cache = MeasurementCache(str(tmp_path), "fp", max_records=4)
    for i in range(12):
        cache.store(Evaluation(bits(i), 1.0 + i, True))
        with open(cache.path) as f:
            lines = sum(1 for line in f if line.strip())
        assert lines <= 2 * cache.max_records, \
            "journal must compact before outgrowing twice the bound"

    loaded = cache.load()
    # compaction trims to max_records, then appends grow the file again up
    # to the 2x trigger — the steady-state bound, never the raw 12 stores
    assert cache.max_records <= len(loaded) <= 2 * cache.max_records
    # the newest max_records patterns always survive
    for i in range(12 - cache.max_records, 12):
        assert bits(i) in loaded and loaded[bits(i)].time_s == 1.0 + i

    # last write wins: re-measuring a surviving pattern replaces it in place
    cache.store(Evaluation(bits(11), 0.25, True))
    assert cache.load()[bits(11)].time_s == 0.25


def test_measurement_journal_compaction_preserves_reload_fidelity(tmp_path):
    from repro.core.evaluator import MeasurementCache

    cache = MeasurementCache(str(tmp_path), "fp", max_records=2)
    cache.store(Evaluation((0, 0), float("inf"), False, {"err": "oom"}))
    cache.store(Evaluation((0, 1), 2.0, True, {"n": 3}))
    for i in range(6):  # push past the 2x threshold repeatedly
        cache.store(Evaluation((1, i % 2), 3.0 + i, True))
    loaded = cache.load()
    assert set(loaded) <= {(0, 0), (0, 1), (1, 0), (1, 1)}
    assert loaded[(1, 1)].time_s == 8.0 and loaded[(1, 0)].time_s == 7.0

    # a second cache on the same dir/fingerprint sees the identical state
    again = MeasurementCache(str(tmp_path), "fp", max_records=2).load()
    assert again == loaded
