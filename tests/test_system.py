"""End-to-end system behaviour: supervised training of a real (reduced)
model with checkpoint/restart, the serving loop, and the compressed-DP
step's convergence parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import REFERENCE_PLAN, build_model
from repro.models.plan import ExecPlan
from repro.optim import OptimizerConfig
from repro.optim.schedule import make_schedule
from repro.runtime.fault_tolerance import Supervisor
from repro.runtime.serve import ServeConfig, Server
from repro.runtime.train import (init_train_state, make_compressed_dp_step,
                                 make_train_step)

PLAN = ExecPlan(compute_dtype="float32")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3_0_6b").reduced()
    model = build_model(cfg)
    data = SyntheticLMDataset(DataConfig(seq_len=32, global_batch=4,
                                         vocab=cfg.vocab, seed=0))
    return cfg, model, data


def test_train_loss_decreases(setup):
    cfg, model, data = setup
    state = init_train_state(model, jax.random.key(0))
    opt = OptimizerConfig(lr=3e-3, weight_decay=0.0)
    step = jax.jit(make_train_step(model, PLAN, opt,
                                   make_schedule("constant", peak_lr=3e-3,
                                                 warmup_steps=1)))
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_supervised_training_with_failures(setup, tmp_path):
    cfg, model, data = setup
    state = init_train_state(model, jax.random.key(0))
    opt = OptimizerConfig(lr=1e-3)
    step = jax.jit(make_train_step(model, PLAN, opt,
                                   make_schedule("constant", peak_lr=1e-3,
                                                 warmup_steps=1)))

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in data.batch(i).items()}

    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    sup = Supervisor(mgr, ckpt_every=4, max_restarts=3)
    hit = set()

    def injector(s):
        if s == 6 and s not in hit:
            hit.add(s)
            return True
        return False

    state, report = sup.run(state, batch_fn, step, n_steps=12,
                            failure_injector=injector)
    assert report.restarts == 1
    assert len(report.losses) >= 12
    assert int(state.opt.step) == 12


def test_compressed_dp_step_tracks_exact(setup):
    """int8-EF compressed gradients converge like exact (single-axis mesh)."""
    cfg, model, data = setup
    mesh = jax.make_mesh((1,), ("data",))

    def run(compress):
        state = init_train_state(model, jax.random.key(1),
                                 with_compression=True)
        opt = OptimizerConfig(lr=3e-3, weight_decay=0.0)
        step = make_compressed_dp_step(
            model, PLAN, opt, make_schedule("constant", peak_lr=3e-3,
                                            warmup_steps=1),
            mesh, compress=compress)
        losses = []
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        return losses

    exact = run(False)
    comp = run(True)
    assert all(np.isfinite(comp))
    # same data, same init: trajectories should stay close at 1 pod
    np.testing.assert_allclose(comp, exact, rtol=0.05, atol=0.05)


def test_serving_loop_greedy_decode(setup):
    cfg, model, data = setup
    params = model.init(jax.random.key(0))
    server = Server(model, params, REFERENCE_PLAN,
                    ServeConfig(max_new_tokens=6))
    toks = jnp.asarray(data.batch(0)["tokens"][:2, :16])
    out = server.generate({"tokens": toks})
    assert out.shape == (2, 6)
    assert np.all(out >= 0) and np.all(out < cfg.vocab)
    # greedy decode is deterministic
    out2 = server.generate({"tokens": toks})
    np.testing.assert_array_equal(out, out2)
