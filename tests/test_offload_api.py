"""Frontend-conformance suite for the unified offload API.

One parametrized contract runs across every registered frontend: graph
invariants, plan round-trip through ``Offloader.plan`` with a unified
``OffloadResult``, serial==parallel reproducibility at fixed seed, and
multi-destination gene decode.  Plus the satellite surfaces: the ``plan()``
module-level wrapper, alphabet resolution, ``GAConfig.pool`` process-pool
selection, surrogate rank-correlation reporting, and the similarity seed
bank.
"""
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (DEFAULT_ALPHABET, EXTENDED_ALPHABET, Evaluation,
                        GAConfig, OffloadConfig, OffloadResult, Offloader,
                        Region, RegionGraph, coding_from_graph,
                        detect_frontend, frontend_names, get_frontend,
                        modeled_cost_s, plan, plan_offload, resolve_alphabet,
                        run_ga)
from repro.core.ga import GAResult
from repro.core.offload import SeedBank, _pattern_db_seed, ga_search
from repro.core.pattern_db import default_db

# ---------------------------------------------------------------------------
# per-frontend fixtures: (target, inputs, OffloadConfig kwargs)
# ---------------------------------------------------------------------------

PY_SRC = """
def app(a, x, n, iters):
    y = np.zeros((n,))
    for it in range(iters):
        y = y + np.tanh(a @ x) * 0.1
    s = 0.0
    for i in range(n):
        s = s + y[i] * y[i]
    return y, s
"""
PY_CONSTS = {"n": 10, "iters": 8}


def _py_inputs():
    rng = np.random.default_rng(0)
    return dict(a=rng.random((10, 10)), x=rng.random(10))


def _traced_fn(x):
    def step(c, t):
        return c * 0.9 + t, c
    _, ys = jax.lax.scan(step, jnp.zeros(()), x)
    return ys * 2.0


def _ir_graph():
    # no callees / vectors, so the pattern DB cannot claim any region and
    # the gene covers all three sites
    regions = [
        Region("outer", "loop", trip_count=50),
        Region("hot", "loop", parent="outer", depth=1,
               uses=frozenset({"a"}), defs=frozenset({"a"}),
               offloadable=True, alternatives=("ref", "kernel"),
               trip_count=10),
        Region("mid", "loop", uses=frozenset({"b"}), defs=frozenset({"b"}),
               offloadable=True, alternatives=("ref", "kernel"),
               trip_count=4),
        Region("cold", "loop", uses=frozenset({"c"}), defs=frozenset({"c"}),
               offloadable=True, alternatives=("ref", "kernel"),
               trip_count=2),
    ]
    return RegionGraph(regions, "ir", "toy")


FRONTEND_CASES = {
    "python_ast": lambda: (PY_SRC, _py_inputs(),
                           {"repeats": 1, "options": {"consts": PY_CONSTS}}),
    "jaxpr": lambda: (_traced_fn, None,
                      {"options": {"example_args": (jnp.ones(8),)}}),
    "module": lambda: (get_config("qwen3_0_6b"), None, {}),
    "ir": lambda: (_ir_graph(), None, {}),
}

ALL_FRONTENDS = sorted(FRONTEND_CASES)


def _config(kwargs, **over) -> OffloadConfig:
    ga = over.pop("ga", GAConfig(population=6, generations=2, seed=0))
    return OffloadConfig(ga=ga, **{**kwargs, **over})


def _det_fitness(values) -> Evaluation:
    # deterministic stand-in verification environment for contracts that
    # need bit-exact reproducibility regardless of wall-clock noise
    t = 1.0 + 0.05 * sum(int(v) * (i + 1) for i, v in enumerate(values))
    return Evaluation(tuple(values), t, True)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_has_all_frontends():
    assert set(ALL_FRONTENDS) <= set(frontend_names())


@pytest.mark.parametrize("name", ALL_FRONTENDS)
def test_detection_maps_target_to_frontend(name):
    target, inputs, kwargs = FRONTEND_CASES[name]()
    assert detect_frontend(target, _config(kwargs)) == name


def test_detection_rejects_unknown_target():
    with pytest.raises(TypeError):
        detect_frontend(12345, OffloadConfig())


# ---------------------------------------------------------------------------
# contract 1: graph invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_FRONTENDS)
def test_graph_invariants(name):
    target, inputs, kwargs = FRONTEND_CASES[name]()
    fe = get_frontend(name)
    cfg = _config(kwargs)
    if hasattr(fe, "normalize_target"):
        target = fe.normalize_target(target, inputs, cfg)
    graph = fe.build_graph(target, inputs, cfg)

    names = [r.name for r in graph.regions]
    assert len(names) == len(set(names)), "region names must be unique"
    for r in graph.regions:
        assert r.kind in ("loop", "call", "block", "stmt")
        if r.parent is not None:
            graph.by_name(r.parent)            # parents must exist
        if r.offloadable:
            assert len(r.alternatives) >= 2, \
                f"offloadable region {r.name} needs (ref, offload) impls"
    assert graph.offloadable(), "every fixture must expose offload sites"
    # the fingerprint is a pure content hash: rebuilding the same target
    # yields the same persistent-cache key
    target2, inputs2, _ = FRONTEND_CASES[name]()
    if hasattr(fe, "normalize_target"):
        target2 = fe.normalize_target(target2, inputs2, cfg)
    graph2 = fe.build_graph(target2, inputs2, cfg)
    assert graph.fingerprint("ctx") == graph2.fingerprint("ctx")


# ---------------------------------------------------------------------------
# contract 2: plan round-trip, one unified result
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_FRONTENDS)
def test_plan_roundtrip_unified_result(name):
    target, inputs, kwargs = FRONTEND_CASES[name]()
    res = plan_offload(target, inputs, config=_config(kwargs))

    assert isinstance(res, OffloadResult)
    assert res.frontend == name
    assert isinstance(res.ga, GAResult)
    assert res.coding.length > 0, "fixtures must leave genes for the GA"
    # decode(best) is embedded in the final pattern verbatim
    decoded = res.coding.decode(res.best.bits)
    for region, impl in decoded.items():
        assert res.pattern[region] == impl
    # destinations cover exactly the gene sites
    assert set(res.destinations) == {s.region for s in res.coding.sites}
    assert set(res.destinations.values()) <= set(res.coding.destinations)
    # result surfaces: baseline/best/savings/verification/artifact
    assert math.isfinite(res.baseline.time_s)
    assert math.isfinite(res.best.time_s)
    assert res.best.time_s <= res.ga.baseline.time_s + 1e-12
    assert res.artifact is not None
    assert res.verification["mode"] in ("measured", "static-cost")
    for key in ("measurements", "measurements_saved", "wall_s",
                "surrogate_rank_corr"):
        assert key in res.savings
    assert res.summary()["frontend"] == name


def test_python_artifact_runs_and_matches_reference():
    target, inputs, kwargs = FRONTEND_CASES["python_ast"]()
    res = plan_offload(target, inputs, config=_config(kwargs))
    out = res.artifact.run(**inputs)
    ref = res.details["program"]  # reference: interpret with no offloads
    from repro.core.frontends.ast_frontend import Executor
    env = Executor(ref, {}, hoist_transfers=False).run(**inputs)
    np.testing.assert_allclose(out["y"], np.asarray(env["y"]), rtol=1e-2)


def test_module_artifact_is_execplan_with_block_claims():
    target, inputs, kwargs = FRONTEND_CASES["module"]()
    res = plan_offload(target, inputs, config=_config(kwargs))
    from repro.models.plan import ExecPlan
    assert isinstance(res.artifact, ExecPlan)
    # block-pass claims survive into the final plan regardless of the GA
    for field, value in res.block.plan_updates.items():
        assert getattr(res.artifact, field) == value


# ---------------------------------------------------------------------------
# contract 3: serial == parallel reproducibility at fixed seed
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_FRONTENDS)
def test_serial_parallel_reproducible(name):
    target, inputs, kwargs = FRONTEND_CASES[name]()

    def plan(workers):
        t, i, k = FRONTEND_CASES[name]()
        cfg = _config(k, fitness_fn=_det_fitness,
                      ga=GAConfig(population=8, generations=3, seed=7,
                                  workers=workers))
        return Offloader(cfg).plan(t, i)

    r_ser = plan(0)
    r_par = plan(4)
    assert r_ser.best.bits == r_par.best.bits
    assert r_ser.best.time_s == r_par.best.time_s
    assert [h["best_time_s"] for h in r_ser.ga.history] == \
        [h["best_time_s"] for h in r_par.ga.history]


# ---------------------------------------------------------------------------
# contract 4: multi-destination decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_FRONTENDS)
def test_multi_destination_decode(name):
    target, inputs, kwargs = FRONTEND_CASES[name]()

    def plan():
        t, i, k = FRONTEND_CASES[name]()
        cfg = _config(k, destinations=EXTENDED_ALPHABET,
                      fitness_fn=_det_fitness,
                      ga=GAConfig(population=8, generations=3, seed=3))
        return Offloader(cfg).plan(t, i)

    r1 = plan()
    assert r1.coding.arity == 3
    assert all(0 <= int(v) < 3 for v in r1.best.bits)

    # an all-stub chromosome decodes every site to its *reference*
    # implementation (cost-only device) and charges a positive modeled cost
    stub = tuple(2 for _ in r1.coding.sites)
    decoded = r1.coding.decode(stub)
    for site in r1.coding.sites:
        assert decoded[site.region] == site.ref_impl
    assert set(r1.coding.destinations_of(stub).values()) == {"fpga_stub"}
    assert modeled_cost_s(r1.graph, r1.coding, stub) > 0
    ref = tuple(0 for _ in r1.coding.sites)
    assert modeled_cost_s(r1.graph, r1.coding, ref) == 0.0

    # fixed-seed search over the enlarged space is reproducible bit-for-bit
    r2 = plan()
    assert r1.best.bits == r2.best.bits
    assert [h["best_time_s"] for h in r1.ga.history] == \
        [h["best_time_s"] for h in r2.ga.history]


def test_destination_cost_steers_search_away_from_stub():
    # with a fitness that ignores the genes, the modeled stub cost is the
    # only signal — the GA must keep regions off the cost-only device
    g = _ir_graph()
    cfg = OffloadConfig(
        destinations=EXTENDED_ALPHABET,
        fitness_fn=lambda values: Evaluation(tuple(values), 1.0, True),
        ga=GAConfig(population=10, generations=6, seed=0),
        seed_from_db=False)
    res = Offloader(cfg).plan(g)
    assert "fpga_stub" not in res.destinations.values()
    # and a measured chromosome that used the stub was charged for it
    assert modeled_cost_s(g, res.coding, (2, 2, 2)) > 0


# ---------------------------------------------------------------------------
# satellites: plan() wrapper, alphabet resolution, process pool,
# rank correlation, seed bank
# ---------------------------------------------------------------------------


def test_plan_wrapper_assembles_config_from_kwargs():
    # the module-level one-liner that replaced the retired planner shims
    res = plan(_ir_graph(), fitness_fn=_det_fitness,
               ga=GAConfig(population=6, generations=2, seed=0))
    assert isinstance(res, OffloadResult)
    assert res.frontend == "ir"
    assert set(res.pattern) >= {s.region for s in res.coding.sites}
    # a whole config works too, and both at once is an error
    res2 = plan(_ir_graph(), config=OffloadConfig(
        fitness_fn=_det_fitness,
        ga=GAConfig(population=6, generations=2, seed=0)))
    assert res2.best.bits == res.best.bits
    with pytest.raises(ValueError, match="not both"):
        plan(_ir_graph(), config=OffloadConfig(), repeats=1)


def test_resolve_alphabet_explicit_config_wins():
    cfg = OffloadConfig(destinations=EXTENDED_ALPHABET)
    assert resolve_alphabet(cfg, ("cpu", "gpu")) == EXTENDED_ALPHABET


def test_resolve_alphabet_falls_back_to_frontend_proposal():
    assert resolve_alphabet(OffloadConfig(), ("cpu", "gpu_fused")) == \
        ("cpu", "gpu_fused")
    assert resolve_alphabet(None, ("cpu", "gpu_fused")) == \
        ("cpu", "gpu_fused")


def test_resolve_alphabet_defaults_when_nothing_given():
    assert resolve_alphabet(None) == DEFAULT_ALPHABET
    assert resolve_alphabet(OffloadConfig(), None) == DEFAULT_ALPHABET


def test_resolve_alphabet_validates_names():
    with pytest.raises(KeyError):
        resolve_alphabet(OffloadConfig(destinations=("cpu", "nope")))
    # mesh wire names parse on demand and are valid alphabet entries
    assert resolve_alphabet(None, ("cpu", "gpu", "mesh:data:4:batch")) == \
        ("cpu", "gpu", "mesh:data:4:batch")


def test_gaconfig_pool_runs_search_in_processes():
    # "smoke" is the registry's shipped factory; spawn workers rebuild it
    g = _ir_graph()
    cfg = GAConfig(population=6, generations=2, seed=0,
                   pool="smoke", workers=2)
    coding, ga = ga_search(g, None, cfg)
    # same trajectory as the in-process run of the identical fitness
    from repro.core.evaluator import _smoke_fitness_factory
    coding2, ga2 = ga_search(g, _smoke_fitness_factory(),
                             GAConfig(population=6, generations=2, seed=0))
    assert ga.best.bits == ga2.best.bits
    assert ga.best.time_s == ga2.best.time_s


def test_unknown_pool_factory_raises():
    with pytest.raises(KeyError):
        ga_search(_ir_graph(), None,
                  GAConfig(pool="no-such-factory", workers=2))


def test_offloader_rejects_pool():
    # the pipeline composes a fitness (block claims, exclusions, destination
    # costs) that spawn workers cannot rebuild from a factory — measuring a
    # different function than the one planned must be an error, not silent
    cfg = OffloadConfig(fitness_fn=_det_fitness,
                        ga=GAConfig(pool="smoke", workers=2))
    with pytest.raises(ValueError, match="Offloader.plan"):
        Offloader(cfg).plan(_ir_graph())


def test_surrogate_ranks_stub_behind_reference():
    # cost-only genes decode to the reference path (zero transfers); the
    # surrogate must charge their modeled cost so screening doesn't invert
    from repro.core.evaluator import transfer_cost_surrogate

    g = _ir_graph()
    coding = coding_from_graph(g, destinations=EXTENDED_ALPHABET)
    cost = transfer_cost_surrogate(g, coding)
    n = coding.length
    assert cost((2,) * n) > cost((0,) * n), \
        "stub-parked chromosome must rank behind the free reference path"


def test_surrogate_rank_corr_reported_by_search():
    g = _ir_graph()
    _, ga = ga_search(g, _det_fitness,
                      GAConfig(population=8, generations=4, seed=1))
    corr = ga.surrogate_rank_corr
    assert math.isfinite(corr) and -1.0 <= corr <= 1.0


def test_seed_bank_neighbor_warm_start(tmp_path):
    g = _ir_graph()
    coding = coding_from_graph(g)
    bank = SeedBank(str(tmp_path))
    bank.record(g, coding, (1, 0, 1))
    seeds = bank.neighbor_seeds(g, coding)
    assert seeds == [(1, 0, 1)]
    # a different frontend's record never leaks in
    g2 = RegionGraph(list(g.regions), "jaxpr", "other")
    assert bank.neighbor_seeds(g2, coding_from_graph(g2)) == []
    # values clamp to the current alphabet
    bank2 = SeedBank(str(tmp_path / "b2"))
    coding3 = coding_from_graph(g, destinations=EXTENDED_ALPHABET)
    bank2.record(g, coding3, (2, 0, 2))
    assert bank2.neighbor_seeds(g, coding)[0] == (1, 0, 1)


def test_seed_bank_size_bound_and_lru_eviction(tmp_path):
    g = _ir_graph()
    coding = coding_from_graph(g)
    bank = SeedBank(str(tmp_path), max_records=4)
    # 12 distinct records against a 4-record bound: journal must compact
    for i in range(12):
        bank.record(RegionGraph(list(g.regions), "ir", f"prog{i}"),
                    coding, (i % 2, 0, 1))
    live = bank._live()
    assert len(live) <= 4
    assert [r["source"] for r in live] == ["prog8", "prog9", "prog10",
                                           "prog11"]
    with open(bank.path) as f:
        assert sum(1 for _ in f) <= 2 * 4 + 1   # file itself stays bounded

    # LRU: a touched record outlives contemporaries it was older than
    bank2 = SeedBank(str(tmp_path / "lru"), max_records=3)
    bank2.record(g, coding, (1, 0, 1))                     # the survivor
    for i in range(2):
        bank2.record(RegionGraph(list(g.regions), "ir", f"noise{i}"),
                     coding, (0, 1, 0))
    assert bank2.neighbor_seeds(g, coding, limit=1) == [(1, 0, 1)]  # touch
    bank2.record(RegionGraph(list(g.regions), "ir", "noise2"),
                 coding, (0, 1, 0))
    sources = [r["source"] for r in bank2._live()]
    assert "toy" in sources and "noise0" not in sources    # LRU, not FIFO


def test_seed_bank_cross_destination_mapping(tmp_path):
    # a neighbor's GPU gene (binary alphabet) seeds a search over alphabets
    # that don't contain "gpu": offloaded genes land on the new primary
    # accelerator, reference genes stay reference
    from repro.core import VARIANT_ALPHABET

    g = _ir_graph()
    bank = SeedBank(str(tmp_path))
    bank.record(g, coding_from_graph(g), (1, 0, 1))        # cpu/gpu record
    variant_coding = coding_from_graph(g, destinations=VARIANT_ALPHABET)
    assert bank.neighbor_seeds(g, variant_coding) == [(1, 0, 1)]
    stub_coding = coding_from_graph(g, destinations=("cpu", "fpga_stub"))
    assert bank.neighbor_seeds(g, stub_coding) == [(1, 0, 1)]
    # and the reverse: a variant-alphabet record seeding a binary search
    bank2 = SeedBank(str(tmp_path / "rev"))
    bank2.record(g, variant_coding, (2, 0, 1))             # pallas/ref/fused
    assert bank2.neighbor_seeds(g, coding_from_graph(g)) == [(1, 0, 1)]


def test_auto_screen_from_prior_rank_corr(tmp_path):
    # search 1 records the surrogate's rank correlation for the program
    # fingerprint; search 2 sees it clear the bar and screens automatically.
    # (6 genes = 64 patterns, so a reseeded search still proposes offspring
    # the first search never measured — the ones screening acts on.)
    g = RegionGraph([
        Region(f"r{i}", "loop", uses=frozenset({f"v{i}"}),
               defs=frozenset({f"v{i}"}), offloadable=True,
               alternatives=("ref", "kernel"), trip_count=2 + i)
        for i in range(6)], "ir", "wide")

    def fit(values):
        # fitness aligned with the transfer-cost surrogate -> high corr
        return Evaluation(tuple(values),
                          1.0 + sum(int(v) * (i + 1)
                                    for i, v in enumerate(values)), True)

    cfg = GAConfig(population=8, generations=4, seed=1,
                   cache_dir=str(tmp_path))
    _, ga1 = ga_search(g, fit, cfg)
    assert ga1.screened_out == 0
    assert math.isfinite(ga1.surrogate_rank_corr)
    assert ga1.surrogate_rank_corr >= cfg.auto_screen_corr

    logs = []
    _, ga2 = ga_search(g, fit, GAConfig(population=8, generations=4, seed=2,
                                        cache_dir=str(tmp_path)),
                       log=logs.append)
    assert ga2.screened_out > 0
    assert any("auto-screen" in line for line in logs)
    # explicit opt-out wins
    _, ga3 = ga_search(g, fit, GAConfig(population=8, generations=4, seed=3,
                                        cache_dir=str(tmp_path),
                                        auto_screen=False))
    assert ga3.screened_out == 0


def test_pattern_db_seed_sets_matched_regions():
    regions = [
        Region("mm", "loop", callees=("np.matmul",), offloadable=True,
               alternatives=("interp", "jit")),
        Region("plain", "loop", offloadable=True,
               alternatives=("interp", "jit")),
    ]
    g = RegionGraph(regions, "python_ast", "seeded")
    coding = coding_from_graph(g)
    seeds = _pattern_db_seed(g, coding, default_db())
    assert seeds == [(1, 0)]


def test_run_ga_seed_injection_measures_seed_first():
    measured = []

    def fit(values):
        measured.append(tuple(values))
        return _det_fitness(values)

    run_ga(4, fit, GAConfig(population=6, generations=1, seed=0),
           seeds=[(1, 0, 1, 0)])
    assert (1, 0, 1, 0) in measured


def test_seed_bank_roundtrips_mesh_alphabets(tmp_path):
    # mesh wire names are ordinary destination names to the bank: a record
    # over a mesh-bearing alphabet seeds the same alphabet verbatim, and
    # cross-alphabet mapping stays name-faithful when the name is present
    g = _ir_graph()
    mesh_alpha = ("cpu", "gpu", "mesh:data:4:batch")
    mesh_coding = coding_from_graph(g, destinations=mesh_alpha)
    bank = SeedBank(str(tmp_path))
    bank.record(g, mesh_coding, (2, 0, 1))
    assert bank.neighbor_seeds(g, mesh_coding) == [(2, 0, 1)]
    # a wider alphabet containing the same mesh name keeps the placement
    wide = coding_from_graph(
        g, destinations=("cpu", "gpu", "gpu_fused", "mesh:data:4:batch"))
    assert bank.neighbor_seeds(g, wide) == [(3, 0, 1)]
    # an alphabet without it degrades to the primary accelerator slot
    assert bank.neighbor_seeds(g, coding_from_graph(g)) == [(1, 0, 1)]
