"""Observability-layer tests: span nesting/threading, the disabled no-op
path, metrics snapshot round-trip + Prometheus rendering, the e2e
``Offloader.plan`` trace across every registered frontend (phase spans must
account for >= 90% of the plan wall), the obsreport renderer, the
pattern-precision journal, and the plan-store TTL sweep.
"""
import json
import threading

import pytest

from repro.core import GAConfig, OffloadConfig, Offloader
from repro.core.pattern_db import (PatternDB, load_pattern_precision,
                                   record_pattern_outcome)
from repro.launch.obsreport import render
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from test_offload_api import ALL_FRONTENDS, FRONTEND_CASES, _config, _ir_graph


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_counter_gauge_histogram_roundtrip():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("hits", kind="a").inc()
    reg.counter("hits", kind="a").inc(2)         # same handle re-resolved
    reg.counter("hits", kind="b").inc(5)
    reg.gauge("level").set(1.5)
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)

    snap = reg.snapshot()
    json.loads(json.dumps(snap))                 # plain-JSON round trip
    by_labels = {tuple(s["labels"].items()): s["value"]
                 for s in snap["hits"]["series"]}
    assert by_labels == {(("kind", "a"),): 3.0, (("kind", "b"),): 5.0}
    assert snap["level"]["series"][0]["value"] == 1.5
    hs = snap["lat"]["series"][0]
    assert hs["count"] == 3
    assert hs["sum"] == pytest.approx(5.55)
    assert hs["min"] == 0.05 and hs["max"] == 5.0
    # cumulative le buckets: 0.05 <= 0.1; 0.5 <= 1.0; 5.0 only in +Inf
    assert hs["buckets"] == {"0.1": 1, "1": 2}

    text = reg.render_prometheus()
    assert '# TYPE hits counter' in text
    assert 'hits{kind="a"} 3' in text
    assert 'lat_bucket{le="+Inf"} 3' in text
    assert 'lat_count 3' in text

    reg.reset()
    assert reg.snapshot() == {}


def test_metric_name_is_bound_to_one_kind():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("x").inc()
    with pytest.raises(ValueError):
        reg.gauge("x")


def test_metrics_concurrent_increments_are_lossless():
    reg = obs_metrics.MetricsRegistry()

    def work():
        for _ in range(1000):
            reg.counter("n").inc()

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("n").value == 4000


# ---------------------------------------------------------------------------
# tracing: disabled no-op, nesting, threading, file round-trip
# ---------------------------------------------------------------------------


def test_disabled_tracing_is_a_shared_noop():
    assert obs_trace.active_tracer() is None
    s = obs_trace.span("anything", attr=1)
    assert s is obs_trace.NULL_SPAN              # no allocation per call
    with s as inner:
        assert inner.set(more=2) is inner
    assert obs_trace.current_span_id() is None


def test_span_nesting_and_parentage(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    obs_trace.enable(path, flush_every=1)
    try:
        with obs_trace.span("root") as root:
            with obs_trace.span("child") as child:
                assert child.parent == root.id
                assert obs_trace.current_span_id() == child.id
                with obs_trace.span("grandchild", depth=2) as g:
                    assert g.parent == child.id
            assert obs_trace.current_span_id() == root.id
        with obs_trace.span("sibling"):
            pass
    finally:
        obs_trace.disable()

    spans, snap = obs_trace.read_trace(path)
    by_name = {s["name"]: s for s in spans}
    assert by_name["grandchild"]["parent"] == by_name["child"]["id"]
    assert by_name["child"]["parent"] == by_name["root"]["id"]
    assert by_name["root"]["parent"] is None
    assert by_name["sibling"]["parent"] is None
    assert by_name["grandchild"]["attrs"] == {"depth": 2}
    assert all(s["dur_s"] >= 0 for s in spans)
    assert snap is not None                      # close() appended metrics


def test_spans_nest_per_thread_with_explicit_cross_thread_parent(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    obs_trace.enable(path)
    try:
        with obs_trace.span("dispatch") as d:
            parent = obs_trace.current_span_id()

            def worker(tag, explicit):
                # a fresh thread has its own empty stack: no implicit
                # parent leaks across threads
                kw = {"parent": explicit} if explicit else {}
                with obs_trace.span(f"work-{tag}", **kw):
                    pass

            threads = [threading.Thread(target=worker,
                                        args=("wired", parent)),
                       threading.Thread(target=worker, args=("free", None))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
    finally:
        obs_trace.disable()
    by_name = {s["name"]: s for s in obs_trace.read_trace(path)[0]}
    assert by_name["work-wired"]["parent"] == by_name["dispatch"]["id"]
    assert by_name["work-free"]["parent"] is None


def test_maybe_tracing_is_idempotent(tmp_path):
    outer = str(tmp_path / "outer.jsonl")
    inner = str(tmp_path / "inner.jsonl")
    with obs_trace.maybe_tracing(outer) as t1:
        with obs_trace.maybe_tracing(inner) as t2:   # already active: no-op
            assert t2 is t1
            with obs_trace.span("s"):
                pass
    assert obs_trace.active_tracer() is None
    assert not (tmp_path / "inner.jsonl").exists()
    spans, _ = obs_trace.read_trace(outer)
    assert [s["name"] for s in spans] == ["s"]
    with obs_trace.maybe_tracing(None) as t:
        assert t is None                             # falsy path: disabled
        assert obs_trace.span("x") is obs_trace.NULL_SPAN


def test_error_inside_span_is_recorded_and_reraised(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    with pytest.raises(RuntimeError):
        with obs_trace.maybe_tracing(path):
            with obs_trace.span("boom"):
                raise RuntimeError("nope")
    spans, _ = obs_trace.read_trace(path)
    assert spans[0]["attrs"]["error"] == "RuntimeError"


# ---------------------------------------------------------------------------
# e2e: Offloader.plan emits the phase spans on every frontend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_FRONTENDS)
def test_plan_trace_covers_phases_on_every_frontend(name, tmp_path):
    target, inputs, kwargs = FRONTEND_CASES[name]()
    path = str(tmp_path / "trace.jsonl")
    cfg = _config(kwargs, trace=path,
                  ga=GAConfig(population=6, generations=2, seed=0))
    Offloader(cfg).plan(target, inputs)
    assert obs_trace.active_tracer() is None     # plan closed its tracer

    spans, snap = obs_trace.read_trace(path)
    by_name = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(s)
    root = by_name["offload.plan"][0]
    assert root["parent"] is None
    phases = [s for phase in ("plan.prepare", "plan.search")
              for s in by_name[phase]]
    assert all(p["parent"] == root["id"] for p in phases)
    # the timeline accounts for the plan wall: prepare + search are the
    # only direct children and cover >= 90% of the root span
    covered = sum(p["dur_s"] for p in phases)
    assert covered >= 0.90 * root["dur_s"]
    # apply nests under search; the GA's generations under search too
    assert by_name["plan.apply"][0]["parent"] == by_name["plan.search"][0]["id"]
    assert len(by_name["ga.generation"]) == 2
    assert by_name["eval.batch"], "evaluator batches must be spanned"
    # the metrics snapshot rode along in the same file
    assert snap is not None and "ga.generations" in snap

    report = render(spans, snap)
    assert "offload.plan" in report and "plan.search" in report
    assert "coverage:" in report and "metrics:" in report


def test_plan_without_trace_writes_nothing(tmp_path):
    cfg = OffloadConfig(ga=GAConfig(population=4, generations=1, seed=0))
    assert cfg.trace is None
    Offloader(cfg).plan(_ir_graph())
    assert obs_trace.active_tracer() is None


# ---------------------------------------------------------------------------
# obsreport renderer
# ---------------------------------------------------------------------------


def test_obsreport_render_orphans_and_metrics():
    spans = [
        {"kind": "span", "trace": "t-x", "id": 1, "parent": None,
         "name": "root", "t0": 0.0, "dur_s": 1.0, "ts": 0.0, "attrs": {}},
        {"kind": "span", "trace": "t-x", "id": 2, "parent": 1,
         "name": "half", "t0": 0.1, "dur_s": 0.5, "ts": 0.0,
         "attrs": {"k": "v"}},
        # parent id 99 never finished (crash): rendered as a root, not lost
        {"kind": "span", "trace": "t-x", "id": 3, "parent": 99,
         "name": "orphan", "t0": 0.2, "dur_s": 0.1, "ts": 0.0, "attrs": {}},
    ]
    out = render(spans, {"c": {"kind": "counter",
                               "series": [{"labels": {}, "value": 2.0}]}})
    assert "spans=3 roots=2" in out
    assert "orphan" in out and "k=v" in out
    assert "account for 50.0% of root wall" in out
    assert "c" in out and "counter" in out


# ---------------------------------------------------------------------------
# pattern precision journal
# ---------------------------------------------------------------------------


def test_pattern_precision_journal_and_accessor(tmp_path):
    d = str(tmp_path)
    for outcome in ("ok", "ok", "ok", "verify_fail", "bind_fail"):
        record_pattern_outcome(d, "matmul", "pallas", outcome, region="r0")
    record_pattern_outcome(d, "scan", "pallas", "error")
    record_pattern_outcome(d, None, "pallas", "ok")      # dropped: no pattern
    record_pattern_outcome(None, "ghost", "pallas", "ok")  # metrics-only

    counts = load_pattern_precision(d)
    assert counts["matmul"] == {"ok": 3, "verify_fail": 1, "bind_fail": 1}
    assert "ghost" not in counts

    db = PatternDB([], precision_dir=d)
    # bind_fail is excluded from the denominator: 3 ok / 4 ran
    assert db.precision("matmul") == pytest.approx(0.75)
    assert db.precision("scan") == pytest.approx(0.0)
    assert db.precision("never-seen") is None            # no evidence
    assert PatternDB([]).precision("matmul") is None     # no journal dir
    # explicit cache_dir overrides the constructor default
    assert PatternDB([]).precision("matmul", cache_dir=d) == \
        pytest.approx(0.75)


def test_measured_jaxpr_plan_journals_pattern_outcomes(tmp_path):
    import jax
    import jax.numpy as jnp
    import numpy as np

    # the linear-recurrence shape the kernel registry can actually bind —
    # a substituted chromosome is a distinct phenotype, so the search
    # measures it and its verifier verdict reaches the journal
    def rec_app(la, b):
        def step(h, ab):
            h = jnp.exp(ab[0]) * h + ab[1]
            return h, h
        _, hs = jax.lax.scan(step, jnp.zeros(la.shape[-1]), (la, b))
        return hs * 1.5

    r = np.random.default_rng(0)
    la = -jnp.abs(jnp.asarray(r.random((12, 8), dtype=np.float32))) * 0.2
    b = jnp.asarray(r.random((12, 8), dtype=np.float32)) * 0.5
    cache = str(tmp_path / "cache")
    cfg = OffloadConfig(
        options={"example_args": (la, b)}, repeats=1,
        ga=GAConfig(population=6, generations=2, seed=0, cache_dir=cache))
    res = Offloader(cfg).plan(rec_app)
    assert res.frontend == "jaxpr"

    counts = load_pattern_precision(cache)
    assert "linear_recurrence" in counts
    assert sum(counts["linear_recurrence"].values()) >= 1
    assert set(counts["linear_recurrence"]) <= set("ok verify_fail error "
                                                   "bind_fail".split())
    p = PatternDB([], precision_dir=cache).precision("linear_recurrence")
    assert p is not None and 0.0 <= p <= 1.0


# ---------------------------------------------------------------------------
# store TTL eviction
# ---------------------------------------------------------------------------


def test_store_evict_stale_drops_old_keeps_live(tmp_path):
    import dataclasses as dc

    from repro.service import PlanStore
    from test_service import _store_record

    store = PlanStore(str(tmp_path))
    ctx, rec = _store_record(tmp_path)
    old = store.put(rec)
    other = store.put(dc.replace(rec, fingerprint="fp-other"))
    kept = store.put(dc.replace(rec, fingerprint="fp-kept"))

    now = max(old.ts, other.ts, kept.ts) + 100.0
    # everything is older than 50s, but "fp-kept" is pinned
    evicted = store.evict_stale(50.0, now=now, keep={"fp-kept"})
    assert evicted == tuple(sorted({ctx.fingerprint, "fp-other"}))
    assert store.load(ctx.fingerprint) is None
    assert store.load("fp-other") is None
    assert store.load("fp-kept").version == kept.version
    # unpinned, the survivor is stale too
    assert store.evict_stale(50.0, now=now) == ("fp-kept",)
    assert store.fingerprints() == ()
    # an empty store sweep is a no-op
    assert store.evict_stale(1e6) == ()


def test_service_evict_stale_counts_and_spares_deployed(tmp_path):
    import dataclasses as dc

    from repro.service import PlanService, PlanStore, record_from_result
    from test_service import _ir_config

    with PlanService(str(tmp_path), config=_ir_config()) as svc:
        served = svc.plan(_ir_graph())           # deployed: must survive
        # plant a second, retired fingerprint directly in the store
        retired = svc.store.put(
            dc.replace(served.record, fingerprint="fp-retired"))
        now = retired.ts + 100.0
        evicted = svc.evict_stale(50.0, now=now)
        assert evicted == ("fp-retired",)
        assert svc.stats.evictions == 1
        assert svc.store.load(served.fingerprint) is not None
        assert svc.current(served.fingerprint) is served
        assert svc.stats.as_dict()["evictions"] == 1
