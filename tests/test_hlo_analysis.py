"""HLO-text cost analyzer: loop multipliers, dot flops, collective model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.hlo_analysis import analyze_hlo
from repro.roofline import Roofline


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    hc = analyze_hlo(c.as_text(), 1)
    assert hc.flops == pytest.approx(2 * 64 * 128 * 32)


def test_while_trip_count_multiplier():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        def body(h, _):
            return jnp.tanh(h @ h), ()
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    c = _compile(f, a)
    hc = analyze_hlo(c.as_text(), 1)
    assert hc.flops == pytest.approx(7 * 2 * 64 ** 3, rel=0.01)


def test_nested_scan_multipliers_compose():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x):
        def outer(h, _):
            def inner(g, _):
                return g @ g, ()
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, ()
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    c = _compile(f, a)
    hc = analyze_hlo(c.as_text(), 1)
    assert hc.flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.01)


def test_dynamic_update_slice_counts_slice_not_operand():
    cache = jax.ShapeDtypeStruct((1024, 64), jnp.float32)
    tok = jax.ShapeDtypeStruct((1, 64), jnp.float32)

    def f(c, t):
        return jax.lax.dynamic_update_slice(c, t, (5, 0))

    # donate the cache so the update is in place (no defensive full copy)
    c = jax.jit(f, donate_argnums=(0,)).lower(cache, tok).compile()
    hc = analyze_hlo(c.as_text(), 1)
    # one-row write (2x read+write) — must NOT count the 1024-row cache
    assert hc.bytes < 1024 * 64 * 4 / 4


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12 * 0.01, hbm_bytes=819e9 * 0.05,
                 collective_bytes=50e9 * 0.002, n_devices=4,
                 model_flops=197e12 * 0.005)
    assert r.compute_s == pytest.approx(0.01)
    assert r.memory_s == pytest.approx(0.05)
    assert r.collective_s == pytest.approx(0.002)
    assert r.dominant == "memory"
    assert r.step_s == pytest.approx(0.05)
    assert r.roofline_fraction == pytest.approx(0.005 / 0.05)
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_collective_ring_model():
    from repro.hlo_analysis import _ring_bytes
    sz = 1000
    assert _ring_bytes("all-gather", sz, 4) == pytest.approx(750)
    assert _ring_bytes("all-reduce", sz, 4) == pytest.approx(1500)
    assert _ring_bytes("reduce-scatter", sz, 4) == pytest.approx(3000)
    assert _ring_bytes("collective-permute", sz, 4) == pytest.approx(1000)
    assert _ring_bytes("all-reduce", sz, 1) == 0.0
