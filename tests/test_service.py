"""Persistent planning-service tests: PlanStore versioning + round-trip,
PlanService admission (same-fingerprint coalescing, distinct-fingerprint
concurrency), background refinement with atomic hot-swap / rollback, and the
serving integration (``Server.from_store``, ``swap_plan`` under load).

The acceptance lifecycle: a cold request pays for a search and persists the
winner; a second request is a warm artifact load with no GA; refinement
finds a strictly better-measured plan and hot-swaps it while clients keep
calling, with outputs staying correct across the swap.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Evaluation, GAConfig, OffloadConfig, Offloader
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import REFERENCE_PLAN, build_model
from repro.models.plan import ExecPlan
from repro.runtime.serve import ServeConfig, Server
from repro.service import (PlanMismatchError, PlanService, PlanStore,
                           ServiceConfig, record_from_result)

from test_offload_api import (FRONTEND_CASES, _det_fitness, _ir_graph,
                              ALL_FRONTENDS)


def _ir_config(**over):
    ga = over.pop("ga", GAConfig(population=6, generations=2, seed=0))
    over.setdefault("fitness_fn", _det_fitness)
    return OffloadConfig(frontend="ir", ga=ga, **over)


# ---------------------------------------------------------------------------
# PlanStore: versioning, history, rollback, compaction, mismatch refusal
# ---------------------------------------------------------------------------


def _store_record(tmp_path, bits=(0, 0, 0), **over):
    off = Offloader(_ir_config())
    ctx = off.prepare(_ir_graph())
    res = off.search(ctx)
    rec = record_from_result(res, ctx.fingerprint)
    import dataclasses
    return ctx, dataclasses.replace(rec, bits=tuple(bits), **over)


def test_store_versions_grow_and_history_is_append_only(tmp_path):
    store = PlanStore(str(tmp_path))
    ctx, rec = _store_record(tmp_path)
    v1 = store.put(rec)
    v2 = store.put(rec)
    assert (v1.version, v2.version) == (1, 2)
    assert store.load(ctx.fingerprint).version == 2
    assert [r.version for r in store.history(ctx.fingerprint)] == [1, 2]
    assert store.fingerprints() == (ctx.fingerprint,)
    # rollback appends the previous version's content as a NEW head
    rb = store.rollback(ctx.fingerprint)
    assert rb.version == 3
    assert rb.meta["rolled_back_from"] == 2
    assert store.load(ctx.fingerprint).version == 3


def test_store_compaction_keeps_newest_history_depth(tmp_path):
    store = PlanStore(str(tmp_path), history_depth=3, max_records=4)
    ctx, rec = _store_record(tmp_path)
    for _ in range(10):
        store.put(rec)
    hist = store.history(ctx.fingerprint)
    assert len(hist) <= 4
    assert hist[-1].version == 10          # newest survives compaction
    assert store.load(ctx.fingerprint).version == 10


def test_store_check_refuses_mismatched_plan_or_coding(tmp_path):
    store = PlanStore(str(tmp_path))
    ctx, rec = _store_record(tmp_path)
    store.check(rec, ctx)                  # matching plan passes
    import dataclasses
    with pytest.raises(PlanMismatchError):
        store.check(dataclasses.replace(rec, fingerprint="deadbeef"), ctx)
    with pytest.raises(PlanMismatchError):
        store.check(dataclasses.replace(rec, sites=("other",)), ctx)
    # rehydrate without a payload needs the original target
    with pytest.raises(ValueError):
        store.rehydrate(rec)


# ---------------------------------------------------------------------------
# cold search -> persisted plan -> warm load (no GA) across a restart
# ---------------------------------------------------------------------------


def test_cold_search_persists_then_restart_warm_loads(tmp_path):
    cfg = _ir_config()
    with PlanService(str(tmp_path), config=cfg) as svc:
        plan = svc.plan(_ir_graph())
        assert not plan.warm and plan.version == 1
        assert svc.stats.searches == 1 and svc.stats.warm_loads == 0
        assert plan.record.meta["origin"] == "cold-search"
        assert plan.record.meta["evaluations"] > 0
        fp = plan.fingerprint

    # a fresh service on the same directory: pure artifact load, no search
    with PlanService(str(tmp_path), config=cfg) as svc2:
        plan2 = svc2.plan(_ir_graph())
        assert plan2.warm and plan2.fingerprint == fp
        assert plan2.record.bits == plan.record.bits
        assert plan2.record.pattern == plan.record.pattern
        assert svc2.stats.searches == 0 and svc2.stats.warm_loads == 1
        # second request in the same process: served from the live table
        plan3 = svc2.plan(_ir_graph())
        assert plan3 is plan2
        assert svc2.stats.live_hits == 1


# ---------------------------------------------------------------------------
# coalescing: N concurrent requests for one fingerprint -> exactly one search
# ---------------------------------------------------------------------------


def test_same_fingerprint_requests_coalesce_to_one_search(tmp_path):
    started, release = threading.Event(), threading.Event()
    calls: list = []
    calls_lock = threading.Lock()

    def blocking_fitness(values) -> Evaluation:
        with calls_lock:
            calls.append(tuple(values))
        started.set()
        assert release.wait(timeout=60)
        return _det_fitness(values)

    cfg = _ir_config(fitness_fn=blocking_fitness)
    with PlanService(str(tmp_path / "svc"), config=cfg) as svc:
        futs = [svc.submit(_ir_graph())]
        assert started.wait(timeout=60)    # first request is mid-search
        futs += [svc.submit(_ir_graph()) for _ in range(3)]
        release.set()
        plans = [f.result(timeout=120) for f in futs]

    assert svc.stats.requests == 4
    assert svc.stats.searches == 1         # the only admission that searched
    assert svc.stats.coalesced == 3        # everyone else joined it
    assert svc.stats.warm_loads == 0 and svc.stats.live_hits == 0
    assert all(p is plans[0] for p in plans)   # one future fanned out
    assert plans[0].version == 1

    # evidence the GA ran once: the service run measured exactly the same
    # chromosome set as one solo search with the same budget and seed
    solo_calls: list = []

    def counting_fitness(values) -> Evaluation:
        solo_calls.append(tuple(values))
        return _det_fitness(values)

    solo = Offloader(_ir_config(
        fitness_fn=counting_fitness,
        ga=GAConfig(population=6, generations=2, seed=0,
                    cache_dir=str(tmp_path / "solo"))))
    solo.plan(_ir_graph())
    assert sorted(set(calls)) == sorted(set(solo_calls))
    assert len(calls) == len(solo_calls)
    assert plans[0].record.meta["evaluations"] == len(solo_calls)


def test_distinct_fingerprints_plan_concurrently(tmp_path):
    # both searches must reach their first measurement at the same time; a
    # serial service would leave one side waiting at the barrier forever
    barrier = threading.Barrier(2)
    flags = {"a": False, "b": False}

    def fitness_for(tag):
        def fitness(values) -> Evaluation:
            if not flags[tag]:
                flags[tag] = True
                barrier.wait(timeout=60)   # raises BrokenBarrierError if the
            return _det_fitness(values)    # other search never starts
        return fitness

    from repro.core import RegionGraph

    def graph(tag):
        g = _ir_graph()
        return RegionGraph(list(g.regions), "ir", f"toy_{tag}")

    with PlanService(str(tmp_path), config=_ir_config(),
                     service=ServiceConfig(workers=2)) as svc:
        fa = svc.submit(graph("a"),
                        config=_ir_config(fitness_fn=fitness_for("a")))
        fb = svc.submit(graph("b"),
                        config=_ir_config(fitness_fn=fitness_for("b")))
        pa, pb = fa.result(timeout=120), fb.result(timeout=120)

    assert pa.fingerprint != pb.fingerprint
    assert svc.stats.searches == 2 and svc.stats.coalesced == 0
    assert len(svc.fingerprints()) == 2


# ---------------------------------------------------------------------------
# store round-trip for every frontend's artifact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_FRONTENDS)
def test_store_roundtrips_each_frontend_artifact(tmp_path, name):
    target, inputs, kwargs = FRONTEND_CASES[name]()
    cfg = OffloadConfig(ga=GAConfig(population=4, generations=1, seed=0),
                        **kwargs)
    with PlanService(str(tmp_path), config=cfg) as svc:
        cold = svc.plan(target, inputs)
        assert not cold.warm
    assert svc.stats.searches == 1

    target2, inputs2, _ = FRONTEND_CASES[name]()
    with PlanService(str(tmp_path), config=cfg) as svc2:
        warm = svc2.plan(target2, inputs2)
    assert warm.warm, "restart must load the stored plan, not search"
    assert svc2.stats.searches == 0 and svc2.stats.warm_loads == 1
    assert warm.record.bits == cold.record.bits
    assert warm.record.frontend == name
    assert warm.record.pattern == cold.record.pattern

    if name == "module":
        # self-contained payload: the ExecPlan round-trips through JSON
        assert isinstance(warm.artifact, ExecPlan)
        assert warm.artifact == cold.artifact
        assert "exec_plan" in warm.record.payload
    else:
        assert type(warm.artifact) is type(cold.artifact)
    if name == "jaxpr":                    # live artifact, re-applied: runs
        x = jnp.linspace(0.0, 1.0, 8)
        np.testing.assert_allclose(np.asarray(warm(x)),
                                   np.asarray(cold(x)), rtol=1e-5)
    if name == "python_ast":
        out_w, out_c = warm.artifact.run(**inputs2), cold.artifact.run(**inputs)
        assert set(out_w) == set(out_c)
        for k in out_w:
            np.testing.assert_allclose(np.asarray(out_w[k], dtype=float),
                                       np.asarray(out_c[k], dtype=float),
                                       rtol=1e-6)


# ---------------------------------------------------------------------------
# background refinement: strictly-better swap, atomicity, rollback
# ---------------------------------------------------------------------------

_TARGET_BITS = (1, 0, 1)


def _valley_fitness(values) -> Evaluation:
    # minimized at a non-trivial pattern the GA's seeded all-off / all-on
    # population cannot contain, so a tiny cold search deterministically
    # misses it and refinement has a strictly better plan to find
    t = 0.5 + 0.2 * sum(int(a != b) for a, b in zip(values, _TARGET_BITS))
    return Evaluation(tuple(values), t, True)


def test_refinement_hot_swaps_strictly_better_plan_then_rolls_back(tmp_path):
    cfg = _ir_config(fitness_fn=_valley_fitness,
                     ga=GAConfig(population=2, generations=1, seed=0))
    svc = PlanService(str(tmp_path), config=cfg,
                      service=ServiceConfig(refine_generations=6,
                                            refine_population=8))
    with svc:
        plan = svc.plan(_ir_graph())
        fp = plan.fingerprint
        # cold budget only covers the seeded corners: best is all-on
        assert plan.record.bits == (1, 1, 1)
        assert plan.record.best_time_s == pytest.approx(0.7)

        versions: list = []
        errors: list = []
        stop = threading.Event()

        def client():
            try:
                while not stop.is_set():
                    snap = svc.current(fp)   # immutable snapshot: record and
                    versions.append(snap.version)   # artifact always agree
                    assert snap.record.fingerprint == fp
                    assert snap.record.best_time_s == pytest.approx(
                        _valley_fitness(snap.record.bits).time_s)
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        t = threading.Thread(target=client)
        t.start()
        try:
            swapped = svc.refine_once(fp)
        finally:
            stop.set()
            t.join(timeout=30)
        assert not errors
        assert swapped, "refinement must find the strictly better plan"
        assert versions == sorted(versions), "clients never see a stale " \
            "plan after the swap published the new one"

        cur = svc.current(fp)
        assert cur.record.bits == _TARGET_BITS
        assert cur.record.best_time_s == pytest.approx(0.5)
        assert cur.version == 2
        assert cur.record.meta["origin"] == "refinement"
        assert cur.record.meta["replaced_version"] == 1
        assert svc.stats.refinements == 1 and svc.stats.swaps == 1

        # a further round has nothing strictly better: no swap, no new version
        assert svc.refine_once(fp) is False
        assert svc.current(fp).version == 2

        # rollback re-deploys the replaced plan as a new head version
        restored = svc.rollback(fp)
        assert restored.record.bits == (1, 1, 1)
        assert restored.version == 3
        assert svc.store.load(fp).version == 3
        assert svc.stats.rollbacks == 1


def test_refinement_loop_thread_runs_and_stops(tmp_path):
    cfg = _ir_config(fitness_fn=_valley_fitness,
                     ga=GAConfig(population=2, generations=1, seed=0))
    svc = PlanService(str(tmp_path), config=cfg,
                      service=ServiceConfig(refine_generations=6,
                                            refine_population=8))
    with svc:
        plan = svc.plan(_ir_graph())
        svc.start_refinement(interval_s=0.05)
        deadline = time.monotonic() + 60
        while svc.stats.swaps == 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        svc.stop_refinement()
        assert svc.stats.swaps >= 1
        assert svc.current(plan.fingerprint).record.bits == _TARGET_BITS


# ---------------------------------------------------------------------------
# the acceptance lifecycle on a live artifact: clients keep calling through
# the hot-swap, outputs stay correct (allclose vs reference) throughout
# ---------------------------------------------------------------------------


def test_hot_swap_under_load_keeps_outputs_correct(tmp_path):
    from test_offload_api import PY_CONSTS, PY_SRC, _py_inputs

    target_bits = (1, 0)     # jit the first loop only: not a seeded corner

    def valley(values) -> Evaluation:
        t = 0.5 + 0.2 * sum(int(a != b) for a, b in zip(values, target_bits))
        return Evaluation(tuple(values), t, True)

    cfg = OffloadConfig(frontend="python_ast", fitness_fn=valley, repeats=1,
                        ga=GAConfig(population=2, generations=1, seed=0),
                        options={"consts": PY_CONSTS})
    svc = PlanService(str(tmp_path), config=cfg,
                      service=ServiceConfig(refine_generations=6,
                                            refine_population=8))
    with svc:
        inputs = _py_inputs()
        plan = svc.plan(PY_SRC, inputs)
        fp = plan.fingerprint
        # cold budget only measured the seeded corners — both miss the valley
        assert plan.record.bits in ((0, 0), (1, 1))
        assert plan.record.best_time_s == pytest.approx(0.7)

        # the reference: the all-interpreted program's outputs — every plan
        # must compute the same values, swapped or not
        off = Offloader(cfg)
        reference = off.apply(off.prepare(PY_SRC, inputs),
                              (0, 0)).run(**inputs)
        call = svc.endpoint(fp)

        def check(out):
            assert set(out) == set(reference)
            for k in reference:
                np.testing.assert_allclose(
                    np.asarray(out[k], dtype=float),
                    np.asarray(reference[k], dtype=float), rtol=1e-6)

        check(call(**inputs))

        errors: list = []
        stop = threading.Event()

        def client():
            try:
                while not stop.is_set():
                    check(call(**inputs))   # snapshots current() per call
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        t = threading.Thread(target=client)
        t.start()
        try:
            swapped = svc.refine_once(fp)
            # the swapped-in plan serves the very next snapshot
            check(call(**inputs))
        finally:
            stop.set()
            t.join(timeout=30)
        assert not errors, f"client failed across the swap: {errors[:1]}"
        assert swapped, "refinement must find the strictly better plan"
        cur = svc.current(fp)
        assert cur.record.bits == target_bits and cur.version == 2
        assert cur.record.best_time_s == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# serving integration: Server.from_store + swap_plan during generate
# ---------------------------------------------------------------------------


def _generate_hist_count() -> int:
    from repro.obs import metrics as obs_metrics
    fam = obs_metrics.snapshot().get("serve.generate_seconds")
    return sum(s.get("count", 0) for s in fam["series"]) if fam else 0


def test_server_from_store_and_swap_plan_under_generate(tmp_path):
    arch = get_config("qwen3_0_6b")
    with PlanService(str(tmp_path),
                     config=OffloadConfig(
                         ga=GAConfig(population=4, generations=1,
                                     seed=0))) as svc:
        plan = svc.plan(arch)
        fp = plan.fingerprint
    assert isinstance(plan.artifact, ExecPlan)
    assert "exec_plan" in plan.record.payload

    cfg = arch.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    data = SyntheticLMDataset(DataConfig(seq_len=32, global_batch=4,
                                         vocab=cfg.vocab, seed=0))
    toks = jnp.asarray(data.batch(0)["tokens"][:2, :16])

    # construct straight from the persisted artifact: no planner in the loop
    store = PlanStore(str(tmp_path))
    server = Server.from_store(model, params, store, fp,
                               ServeConfig(max_new_tokens=6))
    assert server.plan == plan.artifact
    hist0 = _generate_hist_count()
    out_stored = server.generate({"tokens": toks})
    assert out_stored.shape == (2, 6)

    with pytest.raises(LookupError):
        Server.from_store(model, params, store, "no-such-fp")

    # expected outputs for each plan (greedy decode is deterministic)
    server.swap_plan(REFERENCE_PLAN)
    assert server.plan == REFERENCE_PLAN
    out_ref = server.generate({"tokens": toks})
    expected = [out_stored, out_ref]

    errors: list = []
    stop = threading.Event()

    def client():
        try:
            while not stop.is_set():
                bound_plan = server.plan          # which plan is current now
                out = server.generate({"tokens": toks})
                # every generation ran ONE complete plan end-to-end: its
                # output matches one of the two plans' expected tokens
                ok = any(np.array_equal(out, exp) for exp in expected)
                assert ok, f"torn generation under swap (plan={bound_plan})"
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    t = threading.Thread(target=client)
    t.start()
    try:
        for i in range(4):                       # hammer the swap path
            server.swap_plan(plan.artifact if i % 2 == 0 else REFERENCE_PLAN)
            time.sleep(0.05)
        server.swap_plan(plan.artifact)
    finally:
        stop.set()
        t.join(timeout=120)
    assert not errors, f"generate failed across swaps: {errors[:1]}"
    # post-swap calls serve the new plan
    np.testing.assert_array_equal(server.generate({"tokens": toks}),
                                  out_stored)
    # the per-generate latency histogram lives in the process-wide metrics
    # registry, not on the plan snapshot: five swap_plan calls later it has
    # kept accumulating (>= the 3 deterministic generate calls above)
    assert _generate_hist_count() >= hist0 + 3


# ---------------------------------------------------------------------------
# cross-host plan reuse: a stored plan measured on different hardware is
# re-verified by re-measurement instead of blindly reused
# ---------------------------------------------------------------------------


def test_environment_fingerprint_and_matching():
    from repro.service import env_matches, environment_fingerprint

    env = environment_fingerprint()
    assert set(env) == {"device_kind", "device_count", "cpu_count",
                        "jax_version"}
    assert env_matches(env)
    # an empty / missing fingerprint is the unsafe legacy case: mismatch
    assert not env_matches({})
    assert not env_matches({"device_kind": env["device_kind"]})
    foreign = dict(env, device_kind="tpu-v99", device_count=4096)
    assert not env_matches(foreign)
    assert env_matches(foreign, current=foreign)


def test_env_mismatch_remeasures_instead_of_warm_load(tmp_path):
    import dataclasses

    cfg = _ir_config()
    with PlanService(str(tmp_path), config=cfg) as svc:
        plan = svc.plan(_ir_graph())
        fp = plan.fingerprint
    assert plan.record.env  # searches stamp the host fingerprint

    # tamper: pretend the stored plan was measured on foreign hardware
    store = PlanStore(str(tmp_path))
    rec = store.load(fp)
    store.put(dataclasses.replace(
        rec, env=dict(rec.env, device_kind="tpu-v99", device_count=4096)))

    with PlanService(str(tmp_path), config=cfg) as svc2:
        plan2 = svc2.plan(_ir_graph())
        # the chromosome fits but its measurements are not evidence here:
        # a seeded re-search ran, no blind warm load
        assert not plan2.warm
        assert svc2.stats.env_mismatches == 1
        assert svc2.stats.searches == 1 and svc2.stats.warm_loads == 0
        assert plan2.record.meta["origin"] == "env-remeasure"

    # the re-measured head now carries THIS host's env: warm loads resume
    with PlanService(str(tmp_path), config=cfg) as svc3:
        plan3 = svc3.plan(_ir_graph())
        assert plan3.warm
        assert svc3.stats.env_mismatches == 0 and svc3.stats.searches == 0


def test_pre_env_records_always_remeasure(tmp_path):
    import dataclasses

    cfg = _ir_config()
    with PlanService(str(tmp_path), config=cfg) as svc:
        fp = svc.plan(_ir_graph()).fingerprint
    store = PlanStore(str(tmp_path))
    store.put(dataclasses.replace(store.load(fp), env={}))   # pre-PR-9 record
    with PlanService(str(tmp_path), config=cfg) as svc2:
        plan = svc2.plan(_ir_graph())
        assert not plan.warm and svc2.stats.env_mismatches == 1


# ---------------------------------------------------------------------------
# operating points: the persisted Pareto front is served without a search
# ---------------------------------------------------------------------------


def _mo_ir_config(**over):
    from repro.core.genes import EXTENDED_ALPHABET
    from repro.core.objectives import OBJECTIVES

    def speedup(values) -> Evaluation:
        t = 1.0 - 0.12 * sum(int(v) == 1 for v in values)
        return Evaluation(tuple(values), t, True)

    ga = over.pop("ga", GAConfig(population=8, generations=2, seed=0,
                                 objectives=OBJECTIVES))
    over.setdefault("fitness_fn", speedup)
    over.setdefault("destinations", EXTENDED_ALPHABET)
    return OffloadConfig(frontend="ir", ga=ga, **over)


def test_select_operating_point_swaps_without_search(tmp_path):
    with PlanService(str(tmp_path), config=_mo_ir_config()) as svc:
        plan = svc.plan(_ir_graph())
        fp = plan.fingerprint
        assert len(plan.record.front) >= 2
        searches = svc.stats.searches

        # already latency-optimal (the GA best): a no-op, not a repoint
        lat = svc.select_operating_point(fp, "latency")
        assert lat is plan and svc.stats.repoints == 0

        en = svc.select_operating_point(fp, "energy")
        assert svc.stats.searches == searches, "repoint must not search"
        assert svc.stats.repoints == 1
        assert en.record.bits != lat.record.bits
        assert en.record.meta["origin"] == "operating-point"
        assert en.record.meta["objective"] == "energy"
        assert en.version > lat.version          # persisted as a new head
        assert svc.current(fp) is en
        # the energy point trades latency for joules
        en_pt = min(plan.record.front, key=lambda p: p["energy_j"])
        assert en.record.bits == tuple(en_pt["bits"])
        assert en.record.best_time_s >= lat.record.best_time_s

        # swap back: rollback target retained, still no search
        back = svc.select_operating_point(fp, "latency")
        assert back.record.bits == lat.record.bits
        assert svc.stats.searches == searches and svc.stats.repoints == 2

        with pytest.raises(ValueError):
            svc.select_operating_point(fp, "carbon")
        with pytest.raises(LookupError):
            svc.select_operating_point("no-such-fp")


def test_select_for_traffic_policy(tmp_path):
    svc_cfg = ServiceConfig(busy_hz=2.0)
    with PlanService(str(tmp_path), config=_mo_ir_config(),
                     service=svc_cfg) as svc:
        fp = svc.plan(_ir_graph()).fingerprint
        busy = svc.select_for_traffic(fp, traffic_hz=10.0)
        idle = svc.select_for_traffic(fp, traffic_hz=0.1)
        assert busy.record.bits != idle.record.bits
        assert idle.record.meta["objective"] == "energy"
        # threshold boundary: at busy_hz the latency point serves
        again = svc.select_for_traffic(fp, traffic_hz=2.0)
        assert again.record.bits == busy.record.bits
        # explicit threshold override wins over ServiceConfig
        forced = svc.select_for_traffic(fp, traffic_hz=1.0, busy_hz=0.5)
        assert forced.record.bits == busy.record.bits


def test_single_objective_record_has_no_front_and_keeps_plan(tmp_path):
    with PlanService(str(tmp_path), config=_ir_config()) as svc:
        plan = svc.plan(_ir_graph())
        # single-objective search: a one-point front (the best) persists,
        # so every objective resolves to the deployed plan — no swap
        assert len(plan.record.front) == 1
        same = svc.select_operating_point(plan.fingerprint, "energy")
        assert same.record.bits == plan.record.bits
        assert svc.stats.repoints == 0


def test_server_traffic_hz_tracks_request_rate():
    server = Server.__new__(Server)          # rate window only, no model
    import collections
    server._req_times = collections.deque(maxlen=256)
    assert server.traffic_hz() == 0.0
    now = time.perf_counter()
    server._req_times.extend([now - 0.5, now - 0.2, now - 0.1])
    assert server.traffic_hz(window_s=60.0) == pytest.approx(3 / 60.0)
    assert server.traffic_hz(window_s=0.0) == 0.0
    # requests older than the window age out of the rate
    server._req_times.appendleft(now - 120.0)
    assert server.traffic_hz(window_s=60.0) == pytest.approx(3 / 60.0)


# ---------------------------------------------------------------------------
# TTL eviction: the background refinement loop sweeps the store
# ---------------------------------------------------------------------------


def test_refinement_loop_runs_ttl_sweep(tmp_path):
    from repro.core import RegionGraph

    def graph(tag):
        g = _ir_graph()
        return RegionGraph(list(g.regions), "ir", f"toy_{tag}")

    cfg = _ir_config()
    with PlanService(str(tmp_path), config=cfg) as seeder:
        fp_live = seeder.plan(graph("live")).fingerprint
        fp_stale = seeder.plan(graph("stale")).fingerprint

    svc = PlanService(str(tmp_path), config=cfg,
                      service=ServiceConfig(plan_ttl_s=0.2,
                                            refine_generations=1,
                                            refine_population=2))
    with svc:
        svc.plan(graph("live"))              # deployed: spared by the sweep
        time.sleep(0.3)                      # both records age past the TTL
        svc.start_refinement(interval_s=0.05)
        deadline = time.monotonic() + 60
        while svc.stats.evictions == 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        svc.stop_refinement()
        assert svc.stats.evictions == 1
        assert svc.store.load(fp_stale) is None, "stale plan swept"
        assert svc.store.load(fp_live) is not None, "deployed plan spared"


def test_no_ttl_configured_means_no_sweep(tmp_path):
    svc = PlanService(str(tmp_path), config=_ir_config(),
                      service=ServiceConfig(refine_generations=1,
                                            refine_population=2))
    with svc:
        fp = svc.plan(_ir_graph()).fingerprint
        svc.start_refinement(interval_s=0.05)
        time.sleep(0.3)
        svc.stop_refinement()
        assert svc.stats.evictions == 0
        assert svc.store.load(fp) is not None


def test_store_roundtrips_mesh_destinations(tmp_path):
    # mesh placements are wire names in the alphabet, so a mesh plan rides
    # the JSONL schema unchanged: store -> load -> parsed MeshDestination
    from repro.core.genes import MeshDestination

    alphabet = ("cpu", "gpu", "mesh:data:4:batch")
    off = Offloader(_ir_config(destinations=alphabet))
    ctx = off.prepare(_ir_graph())
    res = off.search(ctx)
    rec = record_from_result(res, ctx.fingerprint)
    import dataclasses
    mesh_bits = tuple(2 if i == 0 else 0
                      for i in range(len(rec.sites)))
    rec = dataclasses.replace(rec, bits=mesh_bits)

    store = PlanStore(str(tmp_path))
    store.put(rec)
    loaded = store.load(ctx.fingerprint)
    assert loaded.destinations == alphabet
    parsed = loaded.mesh_destinations()
    assert parsed == {rec.sites[0]: MeshDestination(axis="data", n=4)}
    assert parsed[rec.sites[0]].wire() == "mesh:data:4:batch"
    # the stored plan still drives the program: rehydrate checks coding
    # compatibility against the live context (no new search)
    store.check(loaded, ctx)
