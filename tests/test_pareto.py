"""Multi-objective (Pareto) offload search: NSGA selection primitives,
the latency × energy × transfer objective models, front surfacing through
``OffloadResult``, per-objective surrogate fits, and the guarantee that the
single-objective path is bit-identical to the pre-Pareto GA."""
import math

import pytest

from _hypothesis_compat import given, settings, st
from repro.core import Evaluation, GAConfig, OffloadConfig, Offloader
from repro.core import objectives as objmod
from repro.core.ga import (crowding_distances, dominates, non_dominated_sort,
                           pareto_front)
from repro.core.genes import EXTENDED_ALPHABET, coding_from_graph
from repro.core.ir import Region, RegionGraph

from test_offload_api import _det_fitness, _ir_graph

INF = float("inf")


# ---------------------------------------------------------------------------
# synthetic mixed-destination workload: GPU genes cut latency but burn watts,
# CPU genes are slow-and-cool, the fpga_stub adds modeled seconds at low
# watts — so a genuine latency/energy trade-off exists on CPU-only CI
# ---------------------------------------------------------------------------


def _synth_graph(n: int = 5) -> RegionGraph:
    regions = [Region(f"r{i}", "loop", uses=frozenset({f"v{i}"}),
                      defs=frozenset({f"v{i}"}), offloadable=True,
                      alternatives=("ref", "kernel"), trip_count=2 + i)
               for i in range(n)]
    return RegionGraph(regions, "ir", "pareto_synth")


def _speedup_fitness(values) -> Evaluation:
    # each GPU gene shaves a deterministic slice off the wall clock; the
    # pipeline charges the fpga_stub's modeled seconds on top of this
    t = 1.0 - 0.12 * sum(int(v) == 1 for v in values)
    return Evaluation(tuple(values), t, True)


def _mo_config(**over):
    ga = over.pop("ga", GAConfig(population=8, generations=3, seed=0,
                                 objectives=objmod.OBJECTIVES))
    over.setdefault("fitness_fn", _speedup_fitness)
    over.setdefault("destinations", EXTENDED_ALPHABET)
    return OffloadConfig(frontend="ir", ga=ga, **over)


# ---------------------------------------------------------------------------
# dominance + sorting primitives
# ---------------------------------------------------------------------------


def test_dominates_basics():
    assert dominates((1.0, 2.0), (2.0, 2.0))
    assert not dominates((2.0, 2.0), (1.0, 2.0))
    assert not dominates((1.0, 2.0), (1.0, 2.0))      # equal: neither wins
    assert not dominates((1.0, 3.0), (3.0, 1.0))      # trade-off: neither
    assert not dominates((INF, INF), (INF, INF))      # invalid points are
    assert dominates((1.0, 1.0), (INF, INF))          # mutually neutral but
                                                      # dominated by any real


def test_non_dominated_sort_partitions_and_layers():
    pts = [(1.0, 1.0), (2.0, 2.0), (0.5, 3.0), (3.0, 0.5), (2.5, 2.5)]
    fronts = non_dominated_sort(pts)
    assert sorted(i for f in fronts for i in f) == list(range(len(pts)))
    assert sorted(fronts[0]) == [0, 2, 3]
    assert pareto_front(pts) == [0, 2, 3]
    # each later-front point is dominated by someone one layer up
    for k in range(1, len(fronts)):
        for j in fronts[k]:
            assert any(dominates(pts[i], pts[j]) for i in fronts[k - 1])


def test_crowding_preserves_extremes():
    assert crowding_distances([]) == []
    assert crowding_distances([(1.0, 2.0)]) == [INF]
    assert crowding_distances([(1.0, 2.0), (2.0, 1.0)]) == [INF, INF]
    d = crowding_distances([(0.0, 2.0), (1.0, 1.0), (2.0, 0.0)])
    assert d[0] == INF and d[2] == INF            # per-axis boundary points
    assert d[1] == pytest.approx(2.0)             # normalized gap sum


_VEC_SETS = st.integers(1, 4).flatmap(
    lambda m: st.lists(
        st.tuples(*[st.floats(0, 100, allow_nan=False)] * m),
        min_size=1, max_size=12))


@given(_VEC_SETS)
@settings(max_examples=60, deadline=None)
def test_dominance_trichotomy_and_sort_partition(pts):
    """For every pair exactly one of {a dom b, b dom a, neither} holds, no
    point dominates itself, and the sort is a partition whose first front
    is exactly the non-dominated set."""
    for a in pts:
        assert not dominates(a, a)
        for b in pts:
            assert not (dominates(a, b) and dominates(b, a))
    fronts = non_dominated_sort(pts)
    seen = sorted(i for f in fronts for i in f)
    assert seen == list(range(len(pts)))
    front0 = set(fronts[0])
    for i in range(len(pts)):
        dominated = any(dominates(pts[j], pts[i])
                        for j in range(len(pts)) if j != i)
        assert (i in front0) == (not dominated)


# ---------------------------------------------------------------------------
# objective models
# ---------------------------------------------------------------------------


def test_modeled_energy_orders_destinations_by_watts():
    graph = _synth_graph()
    coding = coding_from_graph(graph, destinations=EXTENDED_ALPHABET)
    n = coding.length
    cpu = objmod.modeled_energy_j(graph, coding, (0,) * n, 1.0)
    gpu = objmod.modeled_energy_j(graph, coding, (1,) * n, 1.0)
    assert cpu == pytest.approx(65.0)             # all-host second at 65 W
    assert gpu > cpu                              # hot silicon costs joules
    assert objmod.modeled_energy_j(graph, coding, (0,) * n, INF) == INF
    assert objmod.modeled_energy_j(graph, coding, (0,) * n, -1.0) == INF


def test_objective_values_prefers_measured_detail_fields():
    graph = _synth_graph()
    coding = coding_from_graph(graph, destinations=EXTENDED_ALPHABET)
    bits = (0,) * coding.length
    ev = Evaluation(bits, 1.0, True,
                    {"energy_j": 123.0, "transfer_bytes": 7.0})
    vals = objmod.objective_values(ev, graph, coding)
    assert vals == (1.0, 123.0, 7.0)
    # invalid evaluations map to all-inf (mutually neutral, never selected)
    bad = Evaluation(bits, 1.0, False)
    assert objmod.objective_values(bad, graph, coding) == (INF, INF, INF)
    with pytest.raises(ValueError):
        objmod.objective_values(ev, graph, coding, objectives=("carbon",))


def test_annotate_objectives_stamps_without_overwriting():
    graph = _synth_graph()
    coding = coding_from_graph(graph, destinations=EXTENDED_ALPHABET)
    ann = objmod.annotate_objectives(graph, coding)
    bits = (1,) * coding.length
    ev = ann(Evaluation(bits, 0.5, True))
    assert ev.detail["energy_j"] == pytest.approx(
        objmod.modeled_energy_j(graph, coding, bits, 0.5))
    assert ev.detail["transfer_bytes"] == pytest.approx(
        objmod.static_transfer_bytes(graph, coding, bits))
    # a power-instrumented fitness's own measurement always wins
    ev2 = ann(Evaluation(bits, 0.5, True, {"energy_j": 9.0}))
    assert ev2.detail["energy_j"] == 9.0
    # invalid measurements pass through untouched
    bad = Evaluation(bits, 0.5, False)
    assert ann(bad) is bad


# ---------------------------------------------------------------------------
# the search: mixed-destination Pareto front with a real trade-off
# ---------------------------------------------------------------------------


def test_multi_objective_search_returns_tradeoff_front(tmp_path):
    off = Offloader(_mo_config(
        ga=GAConfig(population=8, generations=3, seed=0,
                    objectives=objmod.OBJECTIVES,
                    cache_dir=str(tmp_path))))
    ctx = off.prepare(_synth_graph())
    res = off.search(ctx)

    front = res.front
    assert len(front) >= 2
    assert res.summary()["front_size"] == len(front)
    pts = [objmod.objective_values(ev, res.graph, res.coding)
           for ev in front]
    for i, a in enumerate(pts):          # the front is pairwise non-dominated
        for j, b in enumerate(pts):
            assert i == j or not dominates(a, b), (front[i], front[j])

    lat = res.operating_point("latency")
    en = res.operating_point("energy")
    assert lat.bits != en.bits
    lat_v = objmod.objective_values(lat, res.graph, res.coding)
    en_v = objmod.objective_values(en, res.graph, res.coding)
    # energy-optimal measurably trades latency for joules, and vice versa
    assert en_v[1] < lat_v[1] and en_v[0] > lat_v[0]
    assert lat.bits == res.best.bits     # best stays the latency winner
    with pytest.raises(ValueError):
        res.operating_point("carbon")

    rows = res.front_summary()
    assert len(rows) == len(front)
    for row in rows:
        assert set(row) == {"bits", "latency_s", "energy_j",
                            "transfer_bytes"}
        assert all(math.isfinite(row[k]) for k in
                   ("latency_s", "energy_j", "transfer_bytes"))

    # per-objective ridge fits landed in the cache beside the latency fit
    from repro.core.surrogate import load_fit
    for obj in ("energy", "transfer"):
        fit = load_fit(str(tmp_path), ctx.fingerprint, objective=obj)
        assert fit is not None and fit["objective"] == obj


def test_single_objective_path_is_unchanged_and_deterministic(tmp_path):
    # an explicit 1-tuple objectives config takes the exact same code path
    # as the default: same RNG stream, same best, same history
    runs = []
    for objectives in (("latency",), ("latency",), objmod.OBJECTIVES):
        cfg = _mo_config(ga=GAConfig(population=8, generations=3, seed=0,
                                     objectives=objectives))
        res = Offloader(cfg).plan(_synth_graph())
        runs.append(res)
    a, b, multi = runs
    assert a.best.bits == b.best.bits
    assert a.ga.history == b.ga.history
    # single-objective searches report a one-point "front": the best
    assert [ev.bits for ev in a.front] == [a.best.bits]
    assert "front_size" not in a.ga.history[-1]
    # the multi run tracked front growth per generation
    assert all(e["front_size"] >= 1 for e in multi.ga.history)


def test_single_objective_matches_default_alphabet_fixture():
    # the tier-1 fixture config (binary alphabet, _det_fitness) must search
    # identically whether or not the objectives field is spelled out
    base = OffloadConfig(frontend="ir", fitness_fn=_det_fitness,
                         ga=GAConfig(population=6, generations=2, seed=0))
    spelled = OffloadConfig(frontend="ir", fitness_fn=_det_fitness,
                            ga=GAConfig(population=6, generations=2, seed=0,
                                        objectives=("latency",)))
    ra = Offloader(base).plan(_ir_graph())
    rb = Offloader(spelled).plan(_ir_graph())
    assert ra.best.bits == rb.best.bits
    assert ra.ga.history == rb.ga.history
