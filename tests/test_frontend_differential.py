"""Cross-frontend differential conformance suite.

The paper's claim is ONE offload method across source languages; PR 4's
claim is that *variant selection* is part of that method on every frontend.
This suite proves it differentially: the same logical workloads (attention,
rmsnorm, recurrence) planned via the python_ast, jaxpr, and module
frontends produce

  * numerically equivalent outputs per chosen variant (allclose against the
    frontend's reference AND against each other at matched tolerances),
  * a uniform :class:`~repro.core.variants.SubstitutionReport`
    (``OffloadResult.report``) of the same shape on every frontend, and
  * bit-identical serial vs parallel plans (reports included).

The report-shape contracts parametrize over ``frontend_names()`` so a
future frontend is auto-covered the moment it registers (it must then add a
workload fixture here — the test fails loudly until it does).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (GAConfig, OffloadConfig, Offloader, Evaluation,
                        Region, RegionGraph, SubstitutionReport,
                        VARIANT_ALPHABET, coding_from_graph, frontend_names,
                        get_frontend, plan_offload)

RTOL = ATOL = 2e-2           # matched tolerances: the verifier's own bars

# ---------------------------------------------------------------------------
# the shared logical workloads
# ---------------------------------------------------------------------------
#
# One rng seeds every frontend's inputs, so the python interpreter, the
# substituted jaxpr program, and the module executors all compute over the
# same numbers.

S, D = 12, 8                 # attention/recurrence extent (interp-friendly)
RS, RD = 48, 16              # rmsnorm rows/cols


def _rng():
    return np.random.default_rng(7)


# --- python_ast sources: the paper's numeric-Python form -------------------

ATTN_SRC = """
def attn_app(q, k, v, n, d, scale):
    out = np.zeros((n, d))
    for i in range(n):
        m = -1e30
        for j in range(i + 1):
            s = 0.0
            for t in range(d):
                s = s + q[i][t] * k[j][t]
            s = s * scale
            if s > m:
                m = s
        z = 0.0
        for j in range(i + 1):
            e = 0.0
            for t in range(d):
                e = e + q[i][t] * k[j][t]
            z = z + np.exp(e * scale - m)
        for t in range(d):
            acc = 0.0
            for j in range(i + 1):
                e = 0.0
                for u in range(d):
                    e = e + q[i][u] * k[j][u]
                acc = acc + np.exp(e * scale - m) / z * v[j][t]
            out[i][t] = acc
    return out
"""

RMS_SRC = """
def rms_app(x, scale, n, d):
    out = np.zeros((n, d))
    for i in range(n):
        ss = 0.0
        for t in range(d):
            ss = ss + x[i][t] * x[i][t]
        inv = 1.0 / np.sqrt(ss / d + 1e-06)
        for t in range(d):
            out[i][t] = x[i][t] * inv * (1.0 + scale[t])
    return out
"""

REC_SRC = """
def rec_app(a, b, h, n, d):
    out = np.zeros((n, d))
    for t in range(n):
        for c in range(d):
            h[c] = np.exp(a[t][c]) * h[c] + b[t][c]
            out[t][c] = h[c]
    return out
"""


def _attn_inputs():
    r = _rng()
    return dict(q=r.standard_normal((S, D)), k=r.standard_normal((S, D)),
                v=r.standard_normal((S, D)))


def _rms_inputs():
    r = _rng()
    return dict(x=r.standard_normal((RS, RD)),
                scale=r.standard_normal(RD) * 0.1)


def _rec_inputs():
    r = _rng()
    return dict(a=-np.abs(r.standard_normal((S, D))) * 0.2,
                b=r.standard_normal((S, D)) * 0.5,
                h=np.zeros((D,)))


PY_WORKLOADS = {
    "attention": (ATTN_SRC, {"n": S, "d": D, "scale": 1.0 / math.sqrt(D)},
                  _attn_inputs, "out", "softmax_attention"),
    "rmsnorm": (RMS_SRC, {"n": RS, "d": RD}, _rms_inputs, "out", "rmsnorm"),
    "recurrence": (REC_SRC, {"n": S, "d": D}, _rec_inputs, "out",
                   "linear_recurrence"),
}


# --- jaxpr apps: the same math, traced ------------------------------------


def _jx_attn_app(q, k, v):
    s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
    mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), bool))
    return jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1) @ v


def _jx_rms_app(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * (1 + scale)


def _jx_rec_app(la, b):
    def step(h, ab):
        h = jnp.exp(ab[0]) * h + ab[1]
        return h, h
    _, hs = jax.lax.scan(step, jnp.zeros(la.shape[-1]), (la, b))
    return hs


def _jx_case(workload):
    if workload == "attention":
        i = _attn_inputs()
        return _jx_attn_app, tuple(jnp.asarray(i[n], jnp.float32)
                                   for n in ("q", "k", "v"))
    if workload == "rmsnorm":
        i = _rms_inputs()
        return _jx_rms_app, (jnp.asarray(i["x"], jnp.float32),
                             jnp.asarray(i["scale"], jnp.float32))
    i = _rec_inputs()
    return _jx_rec_app, (jnp.asarray(i["a"], jnp.float32),
                         jnp.asarray(i["b"], jnp.float32))


# ---------------------------------------------------------------------------
# per-frontend plan bundles (cached: make_fitness interprets/measures)
# ---------------------------------------------------------------------------

_PY_BUNDLES: dict = {}
_JX_BUNDLES: dict = {}


def _py_bundle(workload):
    if workload not in _PY_BUNDLES:
        src, consts, inputs_fn, out_name, pattern = PY_WORKLOADS[workload]
        inputs = inputs_fn()
        fe = get_frontend("python_ast")
        cfg = OffloadConfig(repeats=1, options={"consts": consts})
        program = fe.normalize_target(src, inputs, cfg)
        graph = fe.build_graph(program, inputs, cfg)
        bundle = fe.make_fitness(graph, program, inputs, cfg)
        coding = coding_from_graph(graph, exclude=bundle.claimed,
                                   destinations=bundle.destinations
                                   or ("cpu", "gpu"))
        from repro.core.frontends.ast_frontend import Executor
        env0 = Executor(program, {}, hoist_transfers=False).run(**inputs)
        _PY_BUNDLES[workload] = (fe, graph, bundle, coding, inputs,
                                 np.asarray(env0[out_name]))
    return _PY_BUNDLES[workload]


def _jx_bundle(workload):
    if workload not in _JX_BUNDLES:
        fn, args = _jx_case(workload)
        fe = get_frontend("jaxpr")
        cfg = OffloadConfig(repeats=1, options={"example_args": args})
        graph = fe.build_graph(fn, None, cfg)
        bundle = fe.make_fitness(graph, fn, None, cfg)
        coding = coding_from_graph(graph, exclude=bundle.claimed,
                                   destinations=bundle.destinations)
        _JX_BUNDLES[workload] = (fe, graph, bundle, coding, args,
                                 np.asarray(fn(*args)))
    return _JX_BUNDLES[workload]


def _values_for(coding, graph, pattern, gene_value):
    """All-reference chromosome with the matched site set to gene_value."""
    sites = [s.region for s in coding.sites
             if graph.by_name(s.region).meta.get("pattern") == pattern]
    assert sites, f"no gene site matched {pattern}"
    return tuple(gene_value if s.region == sites[0] else 0
                 for s in coding.sites), sites[0]


VARIANT_GENE = {"fused_jnp": 1, "pallas": 2}    # VARIANT_ALPHABET positions


# ---------------------------------------------------------------------------
# contract 1: per-variant numeric equivalence, python_ast vs jaxpr
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workload", sorted(PY_WORKLOADS))
@pytest.mark.parametrize("variant", sorted(VARIANT_GENE))
def test_python_and_jaxpr_variant_outputs_match(workload, variant):
    pattern = PY_WORKLOADS[workload][4]
    gene = VARIANT_GENE[variant]

    fe, graph, bundle, coding, inputs, py_ref = _py_bundle(workload)
    assert bundle.destinations == VARIANT_ALPHABET
    values, region = _values_for(coding, graph, pattern, gene)
    artifact = fe.apply_plan(graph, coding, values, bundle)
    assert artifact.report.substituted == {region: variant}, \
        artifact.report.fallbacks
    out_name = PY_WORKLOADS[workload][3]
    py_out = artifact.run(**inputs)[out_name]
    np.testing.assert_allclose(py_out, py_ref, rtol=RTOL, atol=ATOL)

    jfe, jgraph, jbundle, jcoding, args, jx_ref = _jx_bundle(workload)
    jvalues, jregion = _values_for(jcoding, jgraph, pattern, gene)
    sub = jfe.apply_plan(jgraph, jcoding, jvalues, jbundle)
    assert sub.report.substituted == {jregion: variant}, \
        sub.report.fallbacks
    jx_out = np.asarray(sub(*args))
    np.testing.assert_allclose(jx_out, jx_ref, rtol=RTOL, atol=ATOL)

    # the differential claim: two frontends, one workload, one variant,
    # numerically the same artifact output
    np.testing.assert_allclose(py_out, jx_out, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("workload", sorted(PY_WORKLOADS))
def test_report_shapes_identical_across_executable_frontends(workload):
    pattern = PY_WORKLOADS[workload][4]
    fe, graph, bundle, coding, _, _ = _py_bundle(workload)
    jfe, jgraph, jbundle, jcoding, _, _ = _jx_bundle(workload)
    values, region = _values_for(coding, graph, pattern, 1)
    jvalues, jregion = _values_for(jcoding, jgraph, pattern, 1)
    r1 = fe.apply_plan(graph, coding, values, bundle).report
    r2 = jfe.apply_plan(jgraph, jcoding, jvalues, jbundle).report
    for rep in (r1, r2):
        assert isinstance(rep, SubstitutionReport)
        assert set(rep.summary()) == {"substituted", "fallbacks"}
    c1 = next(c for c in r1.choices if c.region == region)
    c2 = next(c for c in r2.choices if c.region == jregion)
    # same fields, same pattern, same chosen variant — only region naming
    # is frontend-private
    assert (c1.pattern, c1.requested, c1.chosen) == \
        (c2.pattern, c2.requested, c2.chosen) == \
        (pattern, "fused_jnp", "fused_jnp")


def test_python_ast_roles_survive_swapped_operand_order():
    """Structural role inference: `k[j][t] * q[i][t]` (k textually first)
    must still bind (q, k, v) correctly — the ast analogue of the jaxpr
    span-order bug PR 3 fixed with dataflow role inference."""
    swapped = ATTN_SRC.replace("q[i][t] * k[j][t]", "k[j][t] * q[i][t]") \
                      .replace("q[i][u] * k[j][u]", "k[j][u] * q[i][u]")
    assert "k[j][t] * q[i][t]" in swapped
    inputs = _attn_inputs()
    fe = get_frontend("python_ast")
    cfg = OffloadConfig(repeats=1,
                        options={"consts": PY_WORKLOADS["attention"][1]})
    program = fe.normalize_target(swapped, inputs, cfg)
    graph = fe.build_graph(program, inputs, cfg)
    bundle = fe.make_fitness(graph, program, inputs, cfg)
    coding = coding_from_graph(graph, exclude=bundle.claimed,
                               destinations=bundle.destinations)
    from repro.core.frontends.ast_frontend import Executor
    ref = np.asarray(Executor(program, {}, hoist_transfers=False)
                     .run(**inputs)["out"])
    values, region = _values_for(coding, graph, "softmax_attention", 1)
    art = fe.apply_plan(graph, coding, values, bundle)
    assert art.report.substituted == {region: "fused_jnp"}
    np.testing.assert_allclose(art.run(**inputs)["out"], ref,
                               rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# contract 2: module-frontend variant knobs (ExecPlan.SITE_VARIANTS)
# ---------------------------------------------------------------------------


def test_module_gene_selects_extra_variant():
    from repro.core.pattern_db import PatternDB

    fe = get_frontend("module")
    # an empty DB: nothing is block-claimed, every knob stays in the gene
    cfg = OffloadConfig(db=PatternDB([]))
    graph = fe.build_graph(get_config("recurrentgemma_2b"), None, cfg)
    bundle = fe.make_fitness(graph, get_config("recurrentgemma_2b"), None,
                             cfg)
    assert bundle.destinations == VARIANT_ALPHABET
    coding = coding_from_graph(graph, exclude=bundle.claimed,
                               destinations=bundle.destinations)
    by_region = {s.region: i for i, s in enumerate(coding.sites)}
    assert "rglru_impl" in by_region, "recurrence knob must stay in the gene"
    for gene, expect in ((0, "step"), (1, "assoc"), (2, "chunked")):
        values = [0] * coding.length
        values[by_region["rglru_impl"]] = gene
        plan = fe.apply_plan(graph, coding, tuple(values), bundle)
        assert plan.rglru_impl == expect
    if "remat" in by_region:
        values = [0] * coding.length
        values[by_region["remat"]] = 2
        assert fe.apply_plan(graph, coding, tuple(values),
                             bundle).remat == "full"
    # a binary site clamps: gene 2 selects its (only) offload impl
    values = [0] * coding.length
    values[by_region["norm_impl"]] = 2
    assert fe.apply_plan(graph, coding, tuple(values),
                         bundle).norm_impl == "fused"


@pytest.mark.parametrize("impl", ["assoc", "chunked"])
def test_module_rglru_variants_numerically_equivalent(impl):
    from repro.models import rglru
    from repro.models.plan import ExecPlan

    r = _rng()
    log_a = jnp.asarray(-np.abs(r.standard_normal((2, S, D))) * 0.2,
                        jnp.float32)
    b = jnp.asarray(r.standard_normal((2, S, D)) * 0.5, jnp.float32)
    h0 = jnp.zeros((2, D), jnp.float32)
    ref_hs, ref_hT = rglru.rglru_scan(log_a, b, h0,
                                      ExecPlan(rglru_impl="step"))
    hs, hT = rglru.rglru_scan(log_a, b, h0, ExecPlan(rglru_impl=impl,
                                                     rglru_chunk=8))
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref_hs),
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(ref_hT),
                               rtol=RTOL, atol=ATOL)


@pytest.mark.slow
def test_module_planned_variants_match_reference_forward():
    """Full-model equivalence: a plan selecting the extra rg-LRU variant
    computes the same loss as the reference plan."""
    from repro.models import build_model
    from repro.models.plan import REFERENCE_PLAN

    cfg = get_config("recurrentgemma_2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = model.demo_batch(jax.random.key(1), 2, 32)
    base = REFERENCE_PLAN.replace(compute_dtype="float32", rglru_chunk=16)
    ref, _ = model.loss(params, batch, base)
    for impl in ("assoc", "chunked"):
        out, _ = model.loss(params, batch, base.replace(rglru_impl=impl))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# contract 3: uniform report + serial==parallel on EVERY registered frontend
# ---------------------------------------------------------------------------
#
# These parametrize over frontend_names(), so registering a new frontend
# automatically extends the suite — it fails until a fixture is added here.

_IR_GRAPH_REGIONS = [
    Region("hot", "loop", uses=frozenset({"a"}), defs=frozenset({"a"}),
           offloadable=True, alternatives=("ref", "kernel"), trip_count=9),
    Region("mid", "loop", uses=frozenset({"b"}), defs=frozenset({"b"}),
           offloadable=True, alternatives=("ref", "kernel", "extra"),
           trip_count=4),
]


def _frontend_fixture(name):
    if name == "python_ast":
        src, consts, inputs_fn, _, _ = PY_WORKLOADS["rmsnorm"]
        return src, inputs_fn(), {"repeats": 1, "options": {"consts": consts}}
    if name == "jaxpr":
        fn, args = _jx_case("recurrence")
        return fn, None, {"options": {"example_args": args}}
    if name == "module":
        return get_config("recurrentgemma_2b"), None, {}
    if name == "ir":
        return RegionGraph([Region(r.name, r.kind, defs=r.defs, uses=r.uses,
                                   offloadable=r.offloadable,
                                   alternatives=r.alternatives,
                                   trip_count=r.trip_count)
                            for r in _IR_GRAPH_REGIONS], "ir", "diff-toy"), \
            None, {}
    raise AssertionError(
        f"frontend {name!r} is registered but has no differential-suite "
        f"fixture: add one to tests/test_frontend_differential.py")


def _det_fitness(values) -> Evaluation:
    t = 1.0 + 0.05 * sum(int(v) * (i + 1) for i, v in enumerate(values))
    return Evaluation(tuple(values), t, True)


def _plan(name, workers=0, seed=5):
    target, inputs, kwargs = _frontend_fixture(name)
    cfg = OffloadConfig(ga=GAConfig(population=6, generations=2, seed=seed,
                                    workers=workers),
                        fitness_fn=_det_fitness, **kwargs)
    return Offloader(cfg).plan(target, inputs)


@pytest.mark.parametrize("name", sorted(frontend_names()))
def test_every_frontend_reports_uniformly(name):
    res = _plan(name)
    rep = res.report
    assert isinstance(rep, SubstitutionReport)
    gene_sites = {s.region for s in res.coding.sites}
    regions = [c.region for c in rep.choices]
    assert len(regions) == len(set(regions)), "one choice per region"
    assert set(regions) >= gene_sites, "every gene site must be reported"
    for c in rep.choices:
        assert isinstance(c.requested, str) and isinstance(c.chosen, str)
        assert isinstance(c.why, str)
        assert c.pattern is None or isinstance(c.pattern, str)
    assert set(rep.summary()) == {"substituted", "fallbacks"}
    assert res.summary()["substituted"] == rep.substituted


@pytest.mark.parametrize("name", sorted(frontend_names()))
def test_every_frontend_serial_parallel_report_identical(name):
    r_ser = _plan(name, workers=0)
    r_par = _plan(name, workers=4)
    assert r_ser.best.bits == r_par.best.bits
    assert r_ser.report == r_par.report
    assert [h["best_time_s"] for h in r_ser.ga.history] == \
        [h["best_time_s"] for h in r_par.ga.history]


# ---------------------------------------------------------------------------
# contract 4: function-block genes — one attention-stack workload, every
# frontend (auto-extends: a new frontend must add a fixture or declare
# itself block-free below)
# ---------------------------------------------------------------------------

BS, BD = 16, 8               # block workload extent (interp-friendly)

BLOCK_SRC = """
def attn_stack(x, scale, wq, wk, wv):
    S = x.shape[0]
    D = x.shape[1]
    xn = np.zeros_like(x)
    q = np.zeros_like(x)
    k = np.zeros_like(x)
    v = np.zeros_like(x)
    out = np.zeros_like(x)
    for i in range(S):
        ss = 0.0
        for j in range(D):
            ss += x[i, j] * x[i, j]
        r = 1.0 / math.sqrt(ss / D + 1e-06)
        for j in range(D):
            xn[i, j] = x[i, j] * r * (1.0 + scale[j])
    for i in range(S):
        for j in range(D):
            sq = 0.0
            sk = 0.0
            sv = 0.0
            for t in range(D):
                sq += xn[i, t] * wq[t, j]
                sk += xn[i, t] * wk[t, j]
                sv += xn[i, t] * wv[t, j]
            q[i, j] = sq
            k[i, j] = sk
            v[i, j] = sv
    for i in range(S):
        m = -1e30
        for j in range(i + 1):
            s = 0.0
            for t in range(D):
                s += q[i, t] * k[j, t]
            s = s / math.sqrt(D)
            if s > m:
                m = s
        z = 0.0
        for j in range(i + 1):
            s = 0.0
            for t in range(D):
                s += q[i, t] * k[j, t]
            w = math.exp(s / math.sqrt(D) - m)
            z += w
            for t in range(D):
                out[i, t] += w * v[j, t]
        for t in range(D):
            out[i, t] = out[i, t] / z
    return out
"""


def _block_inputs():
    r = _rng()
    return dict(x=r.standard_normal((BS, BD)),
                scale=r.standard_normal(BD) * 0.1,
                wq=r.standard_normal((BD, BD)) / math.sqrt(BD),
                wk=r.standard_normal((BD, BD)) / math.sqrt(BD),
                wv=r.standard_normal((BD, BD)) / math.sqrt(BD))


def _jx_block_case():
    @jax.jit
    def attention(q, k, v):
        s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
        mask = jnp.tril(jnp.ones((q.shape[0], q.shape[0]), bool))
        return jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1) @ v

    def model(x, scale, wq, wk, wv):
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        xn = x * jax.lax.rsqrt(var + 1e-6) * (1.0 + scale)
        return attention(xn @ wq, xn @ wk, xn @ wv)

    i = _block_inputs()
    return model, tuple(jnp.asarray(i[n], jnp.float32)
                        for n in ("x", "scale", "wq", "wk", "wv"))


_BLOCK_BUNDLES: dict = {}

#: frontends whose planning pipeline has no function-block pass — they must
#: still plan the absence uniformly (no member-carrying gene sites)
_BLOCK_FREE = {"module", "ir"}


def _block_bundle(name):
    if name in _BLOCK_BUNDLES:
        return _BLOCK_BUNDLES[name]
    if name == "python_ast":
        inputs = _block_inputs()
        fe = get_frontend("python_ast")
        cfg = OffloadConfig(repeats=1)
        program = fe.normalize_target(BLOCK_SRC, inputs, cfg)
        graph = fe.build_graph(program, inputs, cfg)
        bundle = fe.make_fitness(graph, program, inputs, cfg)
        from repro.core.frontends.ast_frontend import Executor
        ref = np.asarray(Executor(program, {}, hoist_transfers=False)
                         .run(**inputs)["out"])
        runner = lambda art: np.asarray(art.run(**inputs)["out"])  # noqa: E731
        target = program
    elif name == "jaxpr":
        fn, args = _jx_block_case()
        fe = get_frontend("jaxpr")
        cfg = OffloadConfig(repeats=1, options={"example_args": args})
        graph = fe.build_graph(fn, None, cfg)
        bundle = fe.make_fitness(graph, fn, None, cfg)
        ref = np.asarray(fn(*args))
        runner = lambda sub: np.asarray(sub(*args))  # noqa: E731
        target = fn
    else:
        raise AssertionError(
            f"frontend {name!r} is registered but has no function-block "
            f"fixture: add one (or list it in _BLOCK_FREE) in "
            f"tests/test_frontend_differential.py")
    coding = coding_from_graph(graph, exclude=bundle.claimed,
                               destinations=bundle.destinations)
    _BLOCK_BUNDLES[name] = (fe, graph, bundle, coding, ref, runner, target)
    return _BLOCK_BUNDLES[name]


def _block_values(coding, graph, gene):
    blocks = [r for r in graph.regions if r.meta.get("block_members")]
    assert blocks, "attention stack must yield a function-block region"
    fb = blocks[0]
    values = tuple(gene if s.region == fb.name else 0 for s in coding.sites)
    return values, fb


@pytest.mark.parametrize("name", sorted(frontend_names()))
def test_block_genes_uniform_across_frontends(name):
    if name in _BLOCK_FREE:
        res = _plan(name)
        assert all(not s.members for s in res.coding.sites)
        return
    fe, graph, bundle, coding, ref, runner, target = _block_bundle(name)
    values, fb = _block_values(coding, graph, 1)
    site = next(s for s in coding.sites if s.region == fb.name)
    assert site.members == tuple(fb.meta["block_members"])
    assert len(site.members) >= 2, "a block spans several regions"
    # an active block gene claims its members on every frontend
    claimed = coding.claimed_members(values)
    assert claimed == frozenset(site.members)
    decoded = coding.decode(values)
    assert decoded[fb.name] != site.ref_impl
    for m in site.members:
        if m in decoded:
            assert decoded[m] == \
                next(s for s in coding.sites if s.region == m).ref_impl


@pytest.mark.parametrize("gene", [1, 2])
def test_block_variant_outputs_match_python_vs_jaxpr(gene):
    outs = {}
    for name in ("python_ast", "jaxpr"):
        fe, graph, bundle, coding, ref, runner, target = _block_bundle(name)
        values, fb = _block_values(coding, graph, gene)
        impl = coding.decode(values)[fb.name]
        artifact = fe.apply_plan(graph, coding, values, bundle)
        assert artifact.report.substituted.get(fb.name) == impl, \
            artifact.report.fallbacks
        out = runner(artifact)
        np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)
        outs[name] = (impl, out)
    # the differential claim, now at block granularity: both frontends
    # bound the same block implementation and computed the same numbers
    assert outs["python_ast"][0] == outs["jaxpr"][0]
    np.testing.assert_allclose(outs["python_ast"][1], outs["jaxpr"][1],
                               rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# contract 5: measured GA on the python_ast frontend picks a real variant
# ---------------------------------------------------------------------------


def test_python_ast_ga_selects_measured_variant():
    """The PR's acceptance bar: under measured wall-clock fitness the GA
    assigns a non-cpu variant destination (gpu_fused / gpu_pallas) to the
    matched site, the artifact verifies, and the report names the variant."""
    src, consts, inputs_fn, _, pattern = PY_WORKLOADS["rmsnorm"]
    res = plan_offload(src, inputs_fn(), config=OffloadConfig(
        ga=GAConfig(population=6, generations=2, seed=0), repeats=1,
        options={"consts": consts}))
    assert res.frontend == "python_ast"
    assert res.coding.destinations == VARIANT_ALPHABET
    assert any(d in ("gpu_fused", "gpu_pallas")
               for d in res.destinations.values()), res.destinations
    assert res.verification["verified"]
    assert any(c.chosen in ("fused_jnp", "pallas") and c.pattern == pattern
               for c in res.report.choices), res.report.choices
    assert res.artifact.report is res.report
    # and the interpreted path really was slower: measured speedup > 1
    assert res.speedup > 1.0
