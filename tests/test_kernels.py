"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _arr(rng, *shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 128, 2, 2, 32),
    (2, 256, 4, 2, 64),
    (1, 192, 8, 1, 16),    # MQA, ragged vs block
    (2, 64, 4, 4, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, b, s, hq, hkv, d, causal, dtype):
    if dtype == jnp.bfloat16 and d > 64:
        pytest.skip("loose-tolerance case covered at d<=64")
    q = _arr(rng, b, s, hq, d, dtype=dtype)
    k = _arr(rng, b, s, hkv, d, dtype=dtype)
    v = _arr(rng, b, s, hkv, d, dtype=dtype)
    blk = 64
    out = ops.flash_attention(q, k, v, causal=causal, blk_q=blk, blk_k=blk)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    exp = ref.flash_attention_ref(
        qf, kf, vf, causal=causal, scale=1 / np.sqrt(d), group=hq // hkv)
    exp = exp.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# rglru linear recurrence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,d,chunk,dblk", [
    (1, 128, 128, 64, 128),
    (2, 256, 256, 128, 128),
    (2, 100, 128, 64, 128),   # ragged seq (padding path)
    (1, 64, 384, 32, 128),
])
def test_rglru_sweep(rng, b, s, d, chunk, dblk):
    log_a = -jnp.abs(_arr(rng, b, s, d)) * 0.2
    bb = _arr(rng, b, s, d, scale=0.5)
    out = ops.rglru_scan(log_a, bb, chunk=chunk, d_block=dblk)
    exp = ref.rglru_scan_ref(log_a, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-5, rtol=1e-4)


def test_rglru_initial_state(rng):
    log_a = -jnp.abs(_arr(rng, 2, 64, 128)) * 0.2
    bb = _arr(rng, 2, 64, 128, scale=0.5)
    h0 = _arr(rng, 2, 128)
    out = ops.rglru_scan(log_a, bb, h0, chunk=32)
    # oracle: fold h0 into b[0]
    bb2 = bb.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)
    exp = ref.rglru_scan_ref(log_a, bb2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,h,d,chunk", [
    (1, 64, 2, 64, 16),
    (2, 128, 2, 64, 32),
    (1, 96, 4, 32, 64),    # chunk > s/1 with ragged padding
])
def test_wkv6_sweep(rng, b, s, h, d, chunk):
    r = _arr(rng, b, s, h, d, scale=0.5)
    k = _arr(rng, b, s, h, d, scale=0.5)
    v = _arr(rng, b, s, h, d, scale=0.5)
    lw = -jnp.abs(_arr(rng, b, s, h, d)) * 0.3
    u = jnp.asarray(rng.normal(size=(h, d)) * 0.1, jnp.float32)
    out = ops.wkv6(r, k, v, lw, u, chunk=chunk)
    rf = r.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    lwf = lw.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    uf = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, 1, d)
    exp = ref.wkv6_ref(rf, kf, vf, lwf, uf).reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=1e-3)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d", [(64, 128), (100, 256), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rng, n, d, dtype):
    x = _arr(rng, n, d, dtype=dtype)
    s = _arr(rng, d, scale=0.1)
    out = ops.rmsnorm(x, s)
    exp = ref.rmsnorm_ref(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# model-level flash (attend_chunked custom_vjp) vs naive — values AND grads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
def test_model_flash_custom_vjp_matches_naive(rng, causal):
    from repro.models import attention as A
    from repro.models.plan import ExecPlan
    B, S, Hq, Hkv, D = 2, 96, 4, 2, 16
    q = _arr(rng, B, S, Hq, D)
    k = _arr(rng, B, S, Hkv, D)
    v = _arr(rng, B, S, Hkv, D)
    pos = jnp.arange(S)
    plan = ExecPlan(attn_kv_chunk=32, compute_dtype="float32")

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(A.attend_naive(q, k, v, pos, pos, causal, 0, plan)))

    def loss_chunk(q, k, v):
        return jnp.sum(jnp.sin(A.attend_chunked(q, k, v, pos, pos, causal, 0, plan)))

    o1, g1 = jax.value_and_grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    o2, g2 = jax.value_and_grad(loss_chunk, argnums=(0, 1, 2))(q, k, v)
    assert abs(float(o1 - o2)) < 1e-3
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-3)
