"""Degrade property-based tests to skips when `hypothesis` is absent.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly, so a bare environment still *collects* the suite
(the example-based tests in the same files keep running) and only the
property tests skip.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only on bare environments
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement: pytest must not see the strategy params
            # as fixture requests
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Chainable no-op: st.lists(...).map(tuple) etc. all yield the
        stub, so strategy expressions at module scope still import."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _StrategyStub()
