"""Bench 2 — function-block vs loop offload (paper §4.2 ordering claim:
algorithm-level block replacement beats loop-level parallelization on the
blocks it covers; the pipeline runs blocks first, GA on the rest)."""
from __future__ import annotations

import numpy as np

from repro.core.frontends.ast_frontend import Executor, PyProgram
from repro.core.ga import GAConfig
from repro.core.planner import plan_python_offload

from benchmarks.common import DEMO_CONSTS, DEMO_SRC, demo_inputs, row, timeit


def main() -> list[str]:
    program = PyProgram(DEMO_SRC, consts=DEMO_CONSTS)
    inputs = demo_inputs()
    res = plan_python_offload(
        program, inputs, ga_cfg=GAConfig(population=8, generations=4, seed=0),
        repeats=2)

    # loop-only offload of the SAME regions the block pass claimed
    claimed = list(res.lib_calls)
    loop_impl = {r: "jit" for r in claimed}
    ref = {n: np.asarray(Executor(program, {}).run(**inputs)[n])
           for n in program.output_names}

    def run_loop_only():
        Executor(program, loop_impl).run(**inputs)

    t_loop_only = timeit(run_loop_only, repeats=2)

    base = res.baseline_time_s
    rows = [
        row("block_offload.baseline", base * 1e6, "1.00x"),
        row("block_offload.loops_as_jit", t_loop_only * 1e6,
            f"{base / t_loop_only:.2f}x (same regions, loop offload)"),
        row("block_offload.blocks_as_lib", res.block_time_s * 1e6,
            f"{base / res.block_time_s:.2f}x (pattern-DB replacement)"),
        row("block_offload.full_pipeline", res.final_time_s * 1e6,
            f"{res.speedup:.2f}x (blocks first, GA on the rest)"),
        row("block_offload.matches", len(res.block.offloads),
            ";".join(f"{b.region}:{b.pattern}@{b.score:.2f}"
                     for b in res.block.offloads)),
    ]
    # the paper's claim, measured: blocks beat loop-offload on those regions
    assert res.block_time_s < t_loop_only
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
