"""Bench 2 — function-block vs loop/span offload (paper §4.2 ordering
claim: algorithm-level block replacement beats loop-level parallelization
on the spans it covers).

Two measurements:

* jaxpr attention stack — ``Offloader.plan`` twice on the same program:
  once with block sites on (the GA may pick the whole-stack gene) and once
  with ``options={"block_sites": False}`` (loop/span genes only).  The
  ``block_vs_loop_pct`` row is the gated ratio; the bench also asserts the
  GA itself — not a hand-placed chromosome — selected the block gene.
* python demo app — the legacy ``plan_python_offload`` comparison migrated
  onto ``Offloader.plan`` with the python_ast frontend.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEMO_CONSTS, DEMO_SRC, demo_inputs, row


def _attention_workload(S: int, D: int):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def attention(q, k, v):
        scores = q @ k.T / np.sqrt(q.shape[-1])
        mask = jnp.tril(jnp.ones((q.shape[0], q.shape[0]), bool))
        scores = jnp.where(mask, scores, -1e30)
        return jax.nn.softmax(scores, axis=-1) @ v

    def model(x, scale, wq, wk, wv, wo):
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        xn = x * jax.lax.rsqrt(var + 1e-6) * (1.0 + scale)
        q = xn @ wq
        k = xn @ wk
        v = xn @ wv
        o = attention(q, k, v)
        return x + o @ wo

    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (S, D), jnp.float32)
    scale = jax.random.normal(ks[1], (D,), jnp.float32) * 0.1
    wq, wk, wv, wo = (jax.random.normal(k, (D, D), jnp.float32) / np.sqrt(D)
                      for k in ks[2:6])
    return model, (x, scale, wq, wk, wv, wo)


def _jaxpr_rows(quick: bool) -> list[str]:
    from repro.core.frontends.registry import OffloadConfig
    from repro.core.ga import GAConfig
    from repro.core.offload import Offloader

    # S=1024 keeps the block-vs-loop gap well above timing noise; quick
    # mode trims the GA budget, not the workload.
    model, args = _attention_workload(1024, 64)
    pop, gens = (6, 2) if quick else (10, 4)

    def plan(**options):
        cfg = OffloadConfig(
            frontend="jaxpr",
            ga=GAConfig(population=pop, generations=gens, seed=0),
            repeats=2,
            options={"example_args": args, "name": "attn_stack", **options})
        return Offloader(cfg).plan(model, None)

    res_block = plan()
    fnblocks = [r.name for r in res_block.graph.regions
                if r.meta.get("block_members")]
    assert fnblocks, "no function-block site detected on the attention stack"
    picked = {b: res_block.pattern.get(b, "ref") for b in fnblocks}
    ga_blocks = {b: impl for b, impl in picked.items() if impl != "ref"}
    # the acceptance bar: the GA selected the block gene under measured
    # fitness — nothing here hand-placed it
    assert ga_blocks, f"GA did not select a block gene: {picked}"

    res_loop = plan(block_sites=False)
    assert not any(r.meta.get("block_members")
                   for r in res_loop.graph.regions)

    base = res_block.baseline.time_s
    t_block = res_block.best.time_s
    t_loop = res_loop.best.time_s
    ratio = t_loop / t_block
    rows = [
        row("block_offload.attn_baseline", base * 1e6,
            "1.00x (all-ref attention stack, jaxpr)"),
        row("block_offload.attn_loop_best", t_loop * 1e6,
            f"{res_loop.baseline.time_s / t_loop:.2f}x (loop/span genes only)"),
        row("block_offload.attn_block_best", t_block * 1e6,
            f"{base / t_block:.2f}x (GA picked "
            + ";".join(f"{b}:{i}" for b, i in sorted(ga_blocks.items()))
            + ")"),
        row("block_offload.block_vs_loop_pct", ratio * 100.0,
            f"{ratio:.2f}x block gene over best loop-only plan"),
    ]
    # the paper's ordering claim, measured end-to-end through the GA
    assert t_block < t_loop, \
        f"block plan ({t_block:.4f}s) not faster than loop plan ({t_loop:.4f}s)"
    return rows


def _python_rows(quick: bool) -> list[str]:
    from repro.core.frontends.registry import OffloadConfig
    from repro.core.ga import GAConfig
    from repro.core.offload import Offloader

    inputs = demo_inputs()
    pop, gens = (6, 2) if quick else (8, 4)
    cfg = OffloadConfig(
        frontend="python_ast",
        ga=GAConfig(population=pop, generations=gens, seed=0),
        repeats=2, options={"consts": DEMO_CONSTS})
    res = Offloader(cfg).plan(DEMO_SRC, inputs)

    blocks = [b for b in res.artifact.block_sites]
    subs = dict(res.report.substituted) if res.report else {}
    base = res.baseline.time_s
    return [
        row("block_offload.demo_baseline", base * 1e6,
            "1.00x (interpreted demo app)"),
        row("block_offload.demo_best", res.best.time_s * 1e6,
            f"{res.speedup:.2f}x (GA over loop+block genes)"),
        row("block_offload.demo_substituted", len(subs),
            ";".join(f"{r}:{v}" for r, v in sorted(subs.items()))
            + (f" blocks={','.join(blocks)}" if blocks else "")),
    ]


def main(quick: bool = False) -> list[str]:
    return _jaxpr_rows(quick) + _python_rows(quick)


if __name__ == "__main__":
    print("\n".join(main()))
