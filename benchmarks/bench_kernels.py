"""Bench 5 — Pallas kernel wrappers vs jnp oracles (interpret mode on CPU;
numbers are correctness-path timings, the TPU perf story lives in the
dry-run roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import row, timeit


def main() -> list[str]:
    rng = np.random.default_rng(0)
    rows = []

    B, S, H, Hkv, D = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    t = timeit(lambda: ops.flash_attention(q, k, v, causal=True).block_until_ready())
    rows.append(row("kernels.flash_attention_interp", t * 1e6, f"S={S} H={H}"))

    la = -jnp.abs(jnp.asarray(rng.normal(size=(2, 512, 256)), jnp.float32)) * 0.2
    bb = jnp.asarray(rng.normal(size=(2, 512, 256)), jnp.float32)
    t = timeit(lambda: ops.rglru_scan(la, bb, chunk=128).block_until_ready())
    rows.append(row("kernels.rglru_scan_interp", t * 1e6, "S=512 D=256"))

    r = jnp.asarray(rng.normal(size=(1, 128, 2, 64)) * 0.5, jnp.float32)
    lw = -jnp.abs(jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)) * 0.3
    u = jnp.asarray(rng.normal(size=(2, 64)) * 0.1, jnp.float32)
    t = timeit(lambda: ops.wkv6(r, r, r, lw, u, chunk=32).block_until_ready())
    rows.append(row("kernels.wkv6_interp", t * 1e6, "S=128 H=2 D=64"))

    x = jnp.asarray(rng.normal(size=(512, 1024)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(1024,)) * 0.1, jnp.float32)
    t = timeit(lambda: ops.rmsnorm(x, s).block_until_ready())
    t_ref = timeit(lambda: jax.jit(ref.rmsnorm_ref)(x, s).block_until_ready())
    rows.append(row("kernels.rmsnorm_interp", t * 1e6,
                    f"ref_jit={t_ref*1e6:.0f}us"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
