"""Perf-trajectory gate: compare a fresh ``BENCH_PR10.json`` against the
committed baseline and fail on regression.

  PYTHONPATH=src python -m benchmarks.compare BENCH_PR10.json \
      benchmarks/baseline/BENCH_PR10.json --max-regression 0.25

Only *machine-relative* metrics are gated (same-run ratios in percent,
bounded scores like rank correlations, measurement counts) — absolute
microsecond rows depend on the host and are reported, never gated.  A
gated metric missing from the current run fails the gate too: losing a
metric is losing coverage, not passing it.
"""
from __future__ import annotations

import argparse
import json
import sys

#: gated metric -> (mode, better, margin).
#:   mode "rel": fail when current is worse than baseline by more than
#:     ``max(--max-regression, margin)`` — same-run ratio rows whose
#:     run-to-run spread may exceed the global threshold get a wider
#:     per-metric margin.
#:   mode "abs": fail when current is worse than baseline by more than
#:     ``margin`` in the row's own units — bounded scores (correlations
#:     are scaled by 1e6 in the CSV value column) and counts, where a
#:     relative threshold would misfire near zero.
GATES: dict[str, tuple[str, str, float]] = {
    # GA search economy + result quality (same machine, same run).  The
    # demo app's absolute speedup vs all-CPU swings ~2x with machine load,
    # so the gated quality number is best-vs-all-offload (same-run, both
    # sides measured back to back).
    "ga_offload.best_vs_all_on_pct":          ("abs", "higher", 20.0),
    "ga_offload.saved_frac_pct":              ("abs", "higher", 25.0),
    "ga_offload.warm_rerun_new_measurements": ("abs", "lower", 5.0),
    # surrogate trajectory: the deterministic synthetic-journal fit gain
    # is byte-stable across runs/machines (exact fitness, least squares);
    # the wall-clock fitted/static corr rows stay informational — journal
    # noise swings them too hard to gate
    "ga_offload.surrogate_fit_gain_synth":    ("abs", "higher", 0.15e6),
    "ga_offload.surrogate_kind_fitted":       ("abs", "higher", 0.5),
    # compile-overlap must keep saving warm-up wall on the jaxpr path
    "ga_offload.compile_overlap_saved_pct":   ("abs", "higher", 25.0),
    # multi-objective search: the mixed-destination workload must keep
    # yielding a Pareto front (>= 2 points: losing it means the NSGA path
    # collapsed to single-objective) whose energy-optimal point trades a
    # real share of modeled joules for latency.  Deterministic fitness and
    # modeled watts: byte-stable, tight margins
    "ga_offload.pareto_front_size":           ("abs", "higher", 2.0),
    "ga_offload.pareto_energy_gain_pct":      ("abs", "higher", 15.0),
    # mesh destinations (placement x parallelism): pure model arithmetic
    # and a fixed-seed search, byte-stable on any host.  The modeled mesh
    # cost may not silently inflate (direction "lower"), the explicit
    # 8-device proposal must keep all three data meshes, and the
    # deterministic front must keep at least one mesh point alongside the
    # single-device points (losing it means the mesh gene stopped trading
    # transfer for modeled latency)
    "ga_offload.mesh_modeled_cost_us":        ("abs", "lower", 100.0),
    "ga_offload.mesh_proposal_size":          ("abs", "higher", 0.5),
    "ga_offload.mesh_front_points":           ("abs", "higher", 18.5),
    # function-block gene must keep beating the best loop/span-only plan
    # on the attention stack (same-run ratio, both plans measured back to
    # back; the gap is ~1.3x, so a 25-point margin absorbs timing noise
    # without letting the ordering claim invert)
    "block_offload.block_vs_loop_pct":        ("abs", "higher", 25.0),
    # substitution speedup (same-run ratio; the ast interp-vs-fused gap is
    # ~30x, far outside noise — the tiny jaxpr kernel ratios are not
    # gated).  Wider margin: the interpreter side breathes with host load
    "frontends.ast_substitution.speedup_pct.fused_jnp": ("rel", "higher", 0.5),
    # planning service: the warm path must stay a store load, not a search.
    # Cold search pays ~20 simulated 2ms measurements plus GA overhead the
    # warm path avoids, so the same-run ratio sits far above 100%; a silent
    # re-search on the warm path collapses it to ~100, below the floor even
    # at the generous 75% margin (which absorbs the warm path's file-IO
    # breathing).  The coalescing count is deterministic — concurrent
    # same-fingerprint requests share one search, so requests-minus-
    # searches cannot drop
    "service.warm_load_speedup":              ("rel", "higher", 0.75),
    "service.coalescing.avoided_searches":    ("abs", "higher", 0.5),
    # observability: the disabled-path instrumentation bound (span count x
    # measured null-span cost over the plan wall) must stay under 5% — the
    # tracing layer may not tax callers who never asked for a trace.  The
    # phase spans must keep accounting for the plan wall (prepare + search
    # are offload.plan's only direct children, so this sits at ~100; a 10-
    # point margin flags structural attribution loss, not timing noise)
    "obs.trace_overhead_pct":                 ("abs", "lower", 5.0),
    "obs.plan_span_coverage_pct":             ("abs", "higher", 10.0),
}


def load_metrics(path: str) -> dict[str, float]:
    with open(path, "r", encoding="utf-8") as f:
        report = json.load(f)
    return {k: float(v) for k, v in report.get("metrics", {}).items()}


def compare(current: dict[str, float], baseline: dict[str, float],
            max_regression: float) -> list[str]:
    """Failure messages (empty = gate passes)."""
    failures: list[str] = []
    for name, (mode, better, margin) in sorted(GATES.items()):
        base = baseline.get(name)
        if base is None:
            continue                   # metric newer than the baseline
        cur = current.get(name)
        if cur is None:
            failures.append(f"{name}: present in baseline but missing from "
                            f"the current run (coverage regression)")
            continue
        sign = 1.0 if better == "higher" else -1.0
        if mode == "rel":
            tol = max(max_regression, margin)
            floor = base - sign * abs(base) * tol
            ok = sign * cur >= sign * floor
            bound = f"{floor:.1f} ({tol:.0%} of {base:.1f})"
        else:
            floor = base - sign * margin
            ok = sign * cur >= sign * floor
            bound = f"{floor:.1f} (margin {margin:g} around {base:.1f})"
        if not ok:
            failures.append(f"{name}: {cur:.1f} regressed past {bound}, "
                            f"direction={better}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh BENCH json (benchmarks.run --json)")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="relative tolerance for ratio metrics (default 0.25)")
    args = ap.parse_args()

    current = load_metrics(args.current)
    baseline = load_metrics(args.baseline)
    failures = compare(current, baseline, args.max_regression)
    gated = [n for n in GATES if n in baseline and n in current]
    print(f"compared {len(gated)} gated metrics "
          f"(of {len(current)} reported) vs {args.baseline}")
    for name in sorted(gated):
        print(f"  {name}: {current[name]:.1f} (baseline {baseline[name]:.1f})")
    if failures:
        print("\nPERF GATE FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        sys.exit(1)
    print("perf gate passed")


if __name__ == "__main__":
    main()
