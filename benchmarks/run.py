"""Benchmark driver — one bench per paper claim/table.

  PYTHONPATH=src python -m benchmarks.run [--only ga,block,transfer,...]
                                          [--quick] [--json OUT.json]

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` runs benches
that support it in smoke mode (no full GA searches) — the CI regression
gate.  ``--json`` additionally writes the rows as a machine-readable
report (the perf-trajectory artifact ``BENCH_PR10.json``; see
``benchmarks.compare`` for the gate that consumes it).  ``--metrics``
dumps the process metrics registry (everything the instrumented hot
paths counted while the benches ran) as a second JSON artifact.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import traceback


def parse_row(line: str) -> dict:
    """One CSV row -> {name, value, derived} (derived keeps any commas)."""
    name, value, derived = line.split(",", 2)
    return {"name": name, "value": float(value), "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: ga,block,transfer,frontends,kernels,"
                         "roofline,service,obs")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode for benches that support it")
    ap.add_argument("--json", default="",
                    help="also write rows to this path as a JSON report")
    ap.add_argument("--metrics", default="",
                    help="also dump the process metrics-registry snapshot "
                         "(repro.obs.metrics) to this path as JSON")
    args = ap.parse_args()

    from benchmarks import (bench_block_offload, bench_frontends,
                            bench_ga_offload, bench_kernels, bench_obs,
                            bench_roofline, bench_service, bench_transfer)
    benches = {
        "ga": bench_ga_offload.main,
        "block": bench_block_offload.main,
        "transfer": bench_transfer.main,
        "frontends": bench_frontends.main,
        "kernels": bench_kernels.main,
        "roofline": bench_roofline.main,
        "service": bench_service.main,
        "obs": bench_obs.main,
    }
    only = {s for s in args.only.split(",") if s}
    print("name,us_per_call,derived")
    failed = []
    report_rows: list[dict] = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            kwargs = {"quick": True} if args.quick and \
                "quick" in inspect.signature(fn).parameters else {}
            for line in fn(**kwargs):
                print(line)
                report_rows.append(parse_row(line))
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name}.FAILED,0,{type(e).__name__}: {e}")
    if args.json:
        report = {
            "schema": 1,
            "quick": bool(args.quick),
            "benches": sorted(only) if only else sorted(benches),
            "failed": failed,
            "rows": report_rows,
            "metrics": {r["name"]: r["value"] for r in report_rows},
        }
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(report_rows)} rows to {args.json}",
              file=sys.stderr)
    if args.metrics:
        from repro.obs import metrics as obs_metrics
        with open(args.metrics, "w", encoding="utf-8") as f:
            json.dump(obs_metrics.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote metrics snapshot to {args.metrics}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
