"""Benchmark driver — one bench per paper claim/table.

  PYTHONPATH=src python -m benchmarks.run [--only ga,block,transfer,...] [--quick]

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` runs benches
that support it in smoke mode (no GA searches) — the CI regression gate.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: ga,block,transfer,frontends,kernels,roofline")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode for benches that support it")
    args = ap.parse_args()

    from benchmarks import (bench_block_offload, bench_frontends,
                            bench_ga_offload, bench_kernels, bench_roofline,
                            bench_transfer)
    benches = {
        "ga": bench_ga_offload.main,
        "block": bench_block_offload.main,
        "transfer": bench_transfer.main,
        "frontends": bench_frontends.main,
        "kernels": bench_kernels.main,
        "roofline": bench_roofline.main,
    }
    only = {s for s in args.only.split(",") if s}
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            kwargs = {"quick": True} if args.quick and \
                "quick" in inspect.signature(fn).parameters else {}
            for line in fn(**kwargs):
                print(line)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name}.FAILED,0,{type(e).__name__}: {e}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
