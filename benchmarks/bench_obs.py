"""Observability overhead: what tracing costs the plan pipeline.

Three claims, one bench:

* the **disabled path** is near-free — ``span()`` is one module-global
  read returning the shared ``NULL_SPAN``; we measure its per-call cost
  directly, then scale by the span-event count of a real traced plan to
  bound what the instrumentation costs an *untraced* plan
  (``obs.trace_overhead_pct``, CI-gated at <= 5%);
* the **phase spans cover the plan wall** — prepare + search are the only
  direct children of ``offload.plan`` and must account for ~100% of it
  (``obs.plan_span_coverage_pct``);
* **enabled** tracing stays cheap: traced vs untraced plan wall, same
  workload, back to back (informational — wall noise, not gated).
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import row, timeit


def _toy_graph(sites: int = 3):
    from repro.core import Region, RegionGraph
    regions = [Region("outer", "loop", trip_count=50)]
    for i in range(sites):
        regions.append(Region(f"r{i}", "loop", uses=frozenset({f"v{i}"}),
                              defs=frozenset({f"v{i}"}), offloadable=True,
                              alternatives=("ref", "kernel"), trip_count=4))
    return RegionGraph(regions, "ir", "obs-toy")


def main(quick: bool = False):
    from repro.core import Evaluation, GAConfig, OffloadConfig, Offloader
    from repro.obs import trace as obs_trace

    rows = []

    # -- 1. the disabled span path, measured at the call site ---------------
    n = 50_000 if quick else 200_000

    def null_spans():
        for _ in range(n):
            with obs_trace.span("x"):
                pass

    null_cost_s = timeit(null_spans, repeats=3, warmup=1) / n
    rows.append(row("obs.null_span", null_cost_s * 1e6,
                    f"ns_per_span={null_cost_s * 1e9:.1f}"))

    # -- 2. a real plan, untraced then traced -------------------------------
    def fitness(values) -> Evaluation:
        t = 1.0 + 0.05 * sum(int(v) * (i + 1) for i, v in enumerate(values))
        return Evaluation(tuple(values), t / 1e6, True)

    ga = GAConfig(population=8, generations=3 if quick else 6, seed=0)

    def cfg(trace=None) -> OffloadConfig:
        return OffloadConfig(frontend="ir", fitness_fn=fitness, ga=ga,
                             trace=trace, seed_from_db=False)

    graph = _toy_graph()
    Offloader(cfg()).plan(graph)                 # warm imports/caches
    t0 = time.perf_counter()
    Offloader(cfg()).plan(graph)
    wall_off = time.perf_counter() - t0

    path = os.path.join(tempfile.mkdtemp(), "trace.jsonl")
    t0 = time.perf_counter()
    Offloader(cfg(trace=path)).plan(graph)
    wall_on = time.perf_counter() - t0

    spans, _ = obs_trace.read_trace(path)
    root = next(s for s in spans if s["name"] == "offload.plan")
    kids = [s for s in spans if s.get("parent") == root["id"]]
    coverage_pct = 100.0 * sum(s["dur_s"] for s in kids) / root["dur_s"]

    # the gated bound: span-event count x measured null-span cost, relative
    # to the untraced plan wall — what the instrumentation costs every
    # caller who did NOT ask for a trace
    overhead_pct = 100.0 * (len(spans) * null_cost_s) / wall_off
    rows.append(row("obs.trace_overhead_pct", overhead_pct,
                    f"spans={len(spans)} "
                    f"null_ns={null_cost_s * 1e9:.1f} "
                    f"plan_ms={wall_off * 1e3:.2f}"))
    rows.append(row("obs.plan_span_coverage_pct", coverage_pct,
                    f"children={len(kids)} root_ms={root['dur_s'] * 1e3:.2f}"))
    enabled_pct = 100.0 * (wall_on - wall_off) / wall_off
    rows.append(row("obs.tracing_enabled_overhead_pct",
                    max(0.0, enabled_pct),
                    f"traced_ms={wall_on * 1e3:.2f} "
                    f"untraced_ms={wall_off * 1e3:.2f}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main():
        print(line)
