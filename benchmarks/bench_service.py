"""Bench 7 — the persistent planning service (plan store + coalescing +
refinement).

What the daemon buys over one-shot ``Offloader.plan``:

* **cold vs warm**: the first request for a fingerprint pays for a GA
  search; a service restart answers the same request by loading the stored
  plan artifact — ``service.warm_load_speedup`` is the same-run ratio the
  CI perf gate tracks (a silent regression to re-searching on the warm
  path collapses it to ~100).
* **coalescing**: N concurrent requests for one fingerprint share a single
  in-flight search — ``service.coalescing.avoided_searches`` counts the
  searches the admission layer deduplicated (deterministic: requests
  minus searches).
* **refinement + hot-swap**: a background round resumes the GA from the
  deployed chromosome and atomically swaps in a strictly better-measured
  plan (the lifecycle row reports whether the swap happened).

Deterministic stand-in fitness throughout: the rows measure the service
machinery, not the host's wall-clock noise.  ``main(quick=True)`` shrinks
the GA budgets; every row survives.
"""
from __future__ import annotations

import threading
import time

from repro.core import (Evaluation, GAConfig, OffloadConfig, Region,
                        RegionGraph)
from repro.service import PlanService, ServiceConfig

from benchmarks.common import row


def _toy_graph(tag: str = "svc", sites: int = 6) -> RegionGraph:
    regions = [Region("outer", "loop", trip_count=50)]
    for i in range(sites):
        regions.append(Region(
            f"loop_{i}", "loop", uses=frozenset({f"v{i}"}),
            defs=frozenset({f"v{i}"}), offloadable=True,
            alternatives=("ref", "kernel"), trip_count=2 + 3 * i))
    return RegionGraph(regions, "ir", f"bench_{tag}{sites}")


def _valley_for(target: tuple, measure_s: float = 0.0):
    # minimized at a non-corner pattern: the seeded all-off/all-on corners
    # miss it, so a cold search has work to do and a refinement round has a
    # strictly better plan to find.  ``measure_s`` simulates the cost of one
    # real measurement — what the warm path's store hit avoids entirely.
    def fitness(values) -> Evaluation:
        if measure_s:
            time.sleep(measure_s)
        t = 0.5 + 0.2 * sum(int(a != b) for a, b in zip(values, target))
        return Evaluation(tuple(values), t, True)
    return fitness


def main(quick: bool = False) -> list[str]:
    import tempfile

    rows = []
    pop, gens = (8, 4) if quick else (12, 8)
    valley6 = _valley_for((1, 0, 1, 1, 0, 1), measure_s=0.002)

    # --- cold plan vs warm load across a service restart --------------------
    with tempfile.TemporaryDirectory() as d:
        cfg = OffloadConfig(frontend="ir", fitness_fn=valley6,
                            ga=GAConfig(population=pop, generations=gens,
                                        seed=0))
        t0 = time.perf_counter()
        with PlanService(d, config=cfg) as svc:
            cold = svc.plan(_toy_graph())
        dt_cold = time.perf_counter() - t0
        assert not cold.warm and svc.stats.searches == 1

        t0 = time.perf_counter()
        with PlanService(d, config=cfg) as svc2:
            warm = svc2.plan(_toy_graph())
        dt_warm = time.perf_counter() - t0
        assert warm.warm and svc2.stats.searches == 0
        assert warm.record.bits == cold.record.bits

        rows.append(row("service.cold_plan", dt_cold * 1e6,
                        f"search+persist bits={cold.record.bits} "
                        f"evals={cold.record.meta.get('evaluations')}"))
        rows.append(row("service.warm_load", dt_warm * 1e6,
                        "store hit: artifact load, no GA"))
        rows.append(row("service.warm_load_speedup",
                        100.0 * dt_cold / dt_warm,
                        "cold search vs warm store load, same machine/run"))

    # --- coalescing: concurrent same-fingerprint requests, one search -------
    with tempfile.TemporaryDirectory() as d:
        started, release = threading.Event(), threading.Event()

        def blocking(values) -> Evaluation:
            started.set()
            release.wait(timeout=60)
            return valley6(values)

        cfg = OffloadConfig(frontend="ir", fitness_fn=blocking,
                            ga=GAConfig(population=pop, generations=gens,
                                        seed=0))
        t0 = time.perf_counter()
        with PlanService(d, config=cfg) as svc:
            futs = [svc.submit(_toy_graph("co"))]
            started.wait(timeout=60)
            futs += [svc.submit(_toy_graph("co")) for _ in range(3)]
            release.set()
            for f in futs:
                f.result(timeout=120)
        dt = time.perf_counter() - t0
        avoided = svc.stats.requests - svc.stats.searches
        assert svc.stats.searches == 1 and avoided == 3
        rows.append(row("service.coalescing.avoided_searches",
                        float(avoided),
                        f"requests={svc.stats.requests} "
                        f"searches={svc.stats.searches} "
                        f"wall_us={dt * 1e6:.0f}"))

    # --- refinement lifecycle: strictly-better plan hot-swapped -------------
    with tempfile.TemporaryDirectory() as d:
        target3 = (1, 0, 1)
        cfg = OffloadConfig(frontend="ir", fitness_fn=_valley_for(target3),
                            ga=GAConfig(population=2, generations=1, seed=0))
        with PlanService(d, config=cfg,
                         service=ServiceConfig(
                             refine_generations=6,
                             refine_population=8)) as svc:
            plan = svc.plan(_toy_graph("ref", sites=3))
            t0 = time.perf_counter()
            swapped = svc.refine_once(plan.fingerprint)
            dt = time.perf_counter() - t0
            cur = svc.current(plan.fingerprint)
            assert swapped and cur.record.bits == target3
            rows.append(row("service.refinement.hot_swap", dt * 1e6,
                            f"swapped={swapped} v{plan.version}->"
                            f"v{cur.version} best={cur.record.best_time_s:g}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
