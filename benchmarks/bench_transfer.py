"""Bench 3 — CPU↔device transfer reduction (paper §3.2.1: 一括転送).

An interpreted outer loop drives an offloaded inner region; loop-invariant
arrays either re-upload every iteration (naive) or once (hoisted).  Reports
transfer counts, bytes, and wall time; plus the static planner's prediction.
"""
from __future__ import annotations

import numpy as np

from repro.core.frontends.ast_frontend import Executor, PyProgram
from repro.core.transfer_planner import plan_transfers

from benchmarks.common import row, timeit

SRC = """
def pipeline(w, xs, steps, n):
    out = np.zeros((steps, n))
    state = np.zeros((n,))
    for s in range(steps):                 # interpreted driver loop
        acc = np.zeros((n,))
        for r in range(3):                 # offloaded inner compute
            acc = acc + np.tanh(w @ (xs[s] + state)) * 0.3
        state = state * 0.9 + acc * 0.1
        out[s] = state
    return out, state
"""

CONSTS = {"steps": 30, "n": 192}


def main() -> list[str]:
    rng = np.random.default_rng(0)
    inputs = dict(w=rng.random((192, 192)) * 0.1, xs=rng.random((30, 192)))
    program = PyProgram(SRC, consts=CONSTS)
    program.check_offloadable(inputs)
    inner = [r.name for r in program.graph.loops() if r.parent is not None]
    impl = {r: "jit" for r in inner}

    ref = Executor(program, {}).run(**inputs)

    def run(hoist):
        ex = Executor(program, impl, hoist_transfers=hoist)
        env = ex.run(**inputs)
        np.testing.assert_allclose(np.asarray(env["state"]),
                                   np.asarray(ref["state"]), rtol=1e-5)
        return ex.stats

    t_naive = timeit(lambda: run(False), repeats=2)
    t_hoist = timeit(lambda: run(True), repeats=2)
    s_naive = run(False)
    s_hoist = run(True)

    plan = plan_transfers(program.graph, impl, hoist=True)
    rows = [
        row("transfer.naive_h2d_count", s_naive.h2d,
            f"{s_naive.h2d_bytes/1e6:.2f}MB uploaded"),
        row("transfer.hoisted_h2d_count", s_hoist.h2d,
            f"{s_hoist.h2d_bytes/1e6:.2f}MB uploaded"),
        row("transfer.reduction", 0,
            f"{s_naive.h2d / max(s_hoist.h2d, 1):.1f}x fewer uploads"),
        row("transfer.naive_wall", t_naive * 1e6, "1.00x"),
        row("transfer.hoisted_wall", t_hoist * 1e6,
            f"{t_naive / t_hoist:.2f}x"),
        row("transfer.planner_hoisted", plan.n_hoisted,
            f"static plan: {plan.n_hoisted} hoisted, "
            f"{plan.n_per_iteration} per-iteration"),
    ]
    assert s_hoist.h2d < s_naive.h2d
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
