"""Shared benchmark helpers + the demo application (the paper's 既存アプリ:
numeric Python with matmul / DFT / iterative loops)."""
from __future__ import annotations

import time

import numpy as np

DEMO_SRC = """
def app(a, b, x, sig_re, sig_im, n, m, k, iters, fftn):
    c = np.zeros((n, m))
    for i in range(n):           # naive matmul -> function-block offload
        for j in range(m):
            acc = 0.0
            for t in range(k):
                acc = acc + a[i, t] * b[t, j]
            c[i, j] = acc
    out_re = np.zeros((fftn,))
    out_im = np.zeros((fftn,))
    for kk in range(fftn):       # naive DFT -> fft block offload
        sr = 0.0
        si = 0.0
        for t in range(fftn):
            ang = -2.0 * math.pi * kk * t / fftn
            sr = sr + sig_re[t] * math.cos(ang) - sig_im[t] * math.sin(ang)
            si = si + sig_re[t] * math.sin(ang) + sig_im[t] * math.cos(ang)
        out_re[kk] = sr
        out_im[kk] = si
    y = np.zeros((n,))
    for it in range(iters):      # vector iteration -> GA loop offload
        y = y + np.tanh(c @ x) * 0.1
    s = 0.0
    for i in range(n):           # scalar reduction -> GA decides (stays)
        s = s + y[i] * y[i]
    return c, y, s, out_re, out_im
"""

DEMO_CONSTS = {"n": 20, "m": 20, "k": 20, "iters": 40, "fftn": 48}


def demo_inputs(seed=0):
    rng = np.random.default_rng(seed)
    return dict(a=rng.random((20, 20)), b=rng.random((20, 20)),
                x=rng.random(20), sig_re=rng.random(48), sig_im=rng.random(48))


def timeit(fn, repeats=3, warmup=1):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
