"""Bench 4 — common core across diverse frontends (paper §3.3/§4.3).

The SAME gene coding, GA engine, pattern DB, and transfer planner operate on
all three frontends; only parsing is frontend-specific.  Reports per-frontend
region extraction time, gene length, and DB match results — plus the shared
pattern DB matching the same block (attention) in both the ast and jaxpr IRs,
and the jaxpr substitution path: per-variant substituted-program timings
(verified against the reference) and, outside quick mode, a full measured
plan.  ``main(quick=True)`` is the CI smoke: it still exercises
parse -> match -> substitute -> verify for every variant, skipping only the
GA search.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import similarity as sim
from repro.core.block_offload import block_offload_pass
from repro.core.frontends import jaxpr_frontend, module_frontend
from repro.core.frontends.ast_frontend import PyProgram
from repro.core.genes import coding_from_graph
from repro.core.pattern_db import default_db
from repro.core.substitution import SubstitutionEngine

from benchmarks.common import DEMO_CONSTS, DEMO_SRC, demo_inputs, row, timeit


def _jax_app(q, k, v, w):
    def attention(q, k, v):
        s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
        mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), bool))
        return jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1) @ v

    def body(h, _):
        return jnp.tanh(h @ w), ()

    h = attention(q, k, v)
    h, _ = jax.lax.scan(body, h, None, length=4)
    return h


def main(quick: bool = False) -> list[str]:
    db = default_db()
    rows = []

    # --- frontend 1: plain Python via ast ----------------------------------
    t0 = time.perf_counter()
    program = PyProgram(DEMO_SRC, consts=DEMO_CONSTS)
    program.check_offloadable(demo_inputs())
    dt1 = time.perf_counter() - t0
    g1 = program.graph
    c1 = coding_from_graph(g1)
    b1 = block_offload_pass(g1, db)
    rows.append(row("frontends.python_ast.parse", dt1 * 1e6,
                    f"regions={len(g1.regions)} gene_len={c1.length} "
                    f"db_matches={len(b1.offloads)}"))

    # --- frontend 2: traced JAX (jaxpr) -------------------------------------
    x = jnp.zeros((16, 8), jnp.float32)
    w = jnp.zeros((8, 8), jnp.float32)
    t0 = time.perf_counter()
    g2 = jaxpr_frontend.build_graph(_jax_app, x, x, x, w)
    dt2 = time.perf_counter() - t0
    c2 = coding_from_graph(g2)
    b2 = block_offload_pass(g2, db, min_similarity=0.75)
    rows.append(row("frontends.jaxpr.parse", dt2 * 1e6,
                    f"regions={len(g2.regions)} gene_len={c2.length} "
                    f"db_matches={len(b2.offloads)}"))

    # --- frontend 3: declarative module graph -------------------------------
    t0 = time.perf_counter()
    g3 = module_frontend.build_graph(get_config("olmoe_1b_7b"))
    dt3 = time.perf_counter() - t0
    c3 = coding_from_graph(g3)
    b3 = block_offload_pass(g3, db)
    rows.append(row("frontends.module.parse", dt3 * 1e6,
                    f"regions={len(g3.regions)} gene_len={c3.length} "
                    f"db_matches={len(b3.offloads)}"))

    # --- commonality evidence: same DB record matches ast AND jaxpr ---------
    attn_rec = next(r for r in db.records if r.name == "softmax_attention")
    vec_jaxpr = g2.meta["whole_program_vector"]
    s_jaxpr = sim.similarity(vec_jaxpr, attn_rec.vectors["jaxpr"])
    rows.append(row("frontends.common_db.attention_jaxpr_sim", s_jaxpr * 100,
                    "same PatternRecord serves both frontends"))
    assert b1.offloads and b3.offloads
    # identical core objects: gene coding type, GA engine, DB instance
    assert type(c1) is type(c2) is type(c3)

    # --- jaxpr substitution: variants spliced in, verified, timed ----------
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 32)) * 0.1, jnp.float32)
    args = (q, k, v, w)     # distinct operands: catches role-order bugs
    g4 = jaxpr_frontend.build_graph(_jax_app, *args)
    jaxpr_frontend.annotate_variants(g4, db)
    engine = SubstitutionEngine(_jax_app, args, g4)
    attn = next(r.name for r in g4.offloadable()
                if r.meta.get("pattern") == "softmax_attention")
    sub_dt: dict[str, float] = {}
    for variant in ("ref", "fused_jnp", "pallas"):
        sub = engine.substitute({attn: variant})
        jitted = jax.jit(sub.fn)
        jax.block_until_ready(jitted(*args))          # compile outside timing
        dt = timeit(lambda: jax.block_until_ready(jitted(*args)))
        sub_dt[variant] = dt
        v = engine.verify(sub)
        rows.append(row(f"frontends.substitution.{variant}", dt * 1e6,
                        f"verified={v.ok} "
                        f"substituted={sub.report.substituted or '{}'}"))
        assert v.ok, f"substituted {variant} failed verification"
    for variant in ("fused_jnp", "pallas"):
        # same-run ratio (percent): the machine-portable number the CI
        # perf-trajectory gate compares — absolute us are host-specific
        rows.append(row(f"frontends.substitution.speedup_pct.{variant}",
                        100.0 * sub_dt["ref"] / sub_dt[variant],
                        "substituted vs reference, same machine/run"))

    # --- ast substitution: the same registry variants behind python loops --
    from repro.core.frontends import registry as fe_registry
    from repro.core.frontends.ast_frontend import Executor

    rms_src = """
def rms_app(x, scale, n, d):
    out = np.zeros((n, d))
    for i in range(n):
        ss = 0.0
        for t in range(d):
            ss = ss + x[i][t] * x[i][t]
        inv = 1.0 / np.sqrt(ss / d + 1e-06)
        for t in range(d):
            out[i][t] = x[i][t] * inv * (1.0 + scale[t])
    return out
"""
    consts = {"n": 64, "d": 32}
    ast_inputs = dict(x=np.asarray(rng.normal(size=(64, 32))),
                      scale=np.asarray(rng.normal(size=32)) * 0.1)
    fe = fe_registry.get_frontend("python_ast")
    from repro.core import OffloadConfig
    acfg = OffloadConfig(repeats=1, options={"consts": consts})
    ap = fe.normalize_target(rms_src, ast_inputs, acfg)
    ag = fe.build_graph(ap, ast_inputs, acfg)
    abundle = fe.make_fitness(ag, ap, ast_inputs, acfg)
    assert abundle.destinations, (
        "no registry variant bound for the ast rmsnorm site: "
        f"{abundle.context.get('variant_fallbacks')}")
    acoding = coding_from_graph(ag, exclude=abundle.claimed,
                                destinations=abundle.destinations)
    ref_env = Executor(ap, {}, hoist_transfers=False).run(**ast_inputs)
    ref_out = np.asarray(ref_env["out"])
    matched = [s.region for s in acoding.sites
               if ag.by_name(s.region).meta.get("pattern")]
    assert matched, "rmsnorm loop must match and keep its gene"
    ast_dt: dict[str, float] = {}
    for gene, name in ((0, "interp"), (1, "fused_jnp"), (2, "pallas")):
        values = tuple(gene if s.region in matched else 0
                       for s in acoding.sites)
        art = fe.apply_plan(ag, acoding, values, abundle)
        art.run(**ast_inputs)                         # compile outside timing
        dt = timeit(lambda: art.run(**ast_inputs))
        ast_dt[name] = dt
        ok = np.allclose(art.run(**ast_inputs)["out"], ref_out,
                         rtol=1e-2, atol=1e-2)
        rows.append(row(f"frontends.ast_substitution.{name}", dt * 1e6,
                        f"verified={ok} "
                        f"substituted={art.report.substituted or '{}'}"))
        assert ok, f"ast variant {name} failed verification"
    rows.append(row("frontends.ast_substitution.speedup_pct.fused_jnp",
                    100.0 * ast_dt["interp"] / ast_dt["fused_jnp"],
                    "lib-call variant vs interpreter, same machine/run"))

    if not quick:
        from repro.core import GAConfig, OffloadConfig, plan_offload
        t0 = time.perf_counter()
        res = plan_offload(_jax_app, config=OffloadConfig(
            ga=GAConfig(population=6, generations=3, seed=0),
            options={"example_args": args}, repeats=2))
        dt = time.perf_counter() - t0
        rows.append(row("frontends.jaxpr.measured_plan", dt * 1e6,
                        f"speedup={res.speedup:.2f} "
                        f"verified={res.verification['verified']} "
                        f"best={''.join(map(str, res.best.bits))}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
