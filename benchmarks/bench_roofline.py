"""Bench 6 — roofline table from the dry-run artifacts (reads
experiments/dryrun/*.json; run `python -m repro.launch.dryrun --all` first).
Emits one row per (arch x shape x mesh) cell; the EXPERIMENTS.md tables are
generated from the same records."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row


def main() -> list[str]:
    rows = []
    files = sorted(glob.glob("experiments/dryrun/*.json"))
    if not files:
        return [row("roofline.missing", 0, "run repro.launch.dryrun first")]
    n_ok = n_err = n_skip = 0
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        cell = f"{d['arch']}|{d['shape']}|{d['mesh']}"
        if d["status"] == "skip":
            n_skip += 1
            continue
        if d["status"] == "error":
            n_err += 1
            rows.append(row(f"roofline.{cell}", 0, "ERROR"))
            continue
        n_ok += 1
        r = d["roofline"]
        rows.append(row(
            f"roofline.{cell}", r["step_s"] * 1e6,
            f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"fits16g={d['memory']['fits_16gb']}"))
    rows.append(row("roofline.summary", n_ok, f"ok={n_ok} err={n_err} skip={n_skip}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
