"""Bench 1 — GA loop-offload search (paper §3.2.1/§4.2.2 mechanism claim):
the GA converges to the fastest offload pattern with far fewer measurements
than exhaustive search, and the found pattern beats both all-CPU and
all-offload.

Extended for the evaluation engine (arXiv:2002.12115 direction):
  * search wall-clock and measurements saved by cache + dedup + screening,
  * persistent measurement cache: a re-run of the same search re-measures
    nothing,
  * parallel-vs-serial evaluator speedup with CostModelFitness on the
    module-planning path.  XLA serializes LLVM compilation process-wide, so
    the parallel mode uses a spawn-based process pool (each worker rebuilds
    the fitness once in its initializer); the speedup row is measured with a
    warm pool in interleaved A/B rounds (machine drift cancels), the
    one-time spawn cost is reported separately, and the pass/fail target is
    scaled by the machine's *measured* process-parallel CPU ceiling —
    virtualized runners often cap aggregate compute well below the
    advertised core count, and the evaluator cannot outrun the hypervisor.
"""
from __future__ import annotations

import os
import shutil
import subprocess
import sys
import tempfile
import time

from repro.core.evaluator import (Evaluator, ProcessPool,
                                  register_fitness_factory)
from repro.core.frontends.ast_frontend import Executor, PyProgram
from repro.core.ga import Evaluation, GAConfig, run_ga
from repro.core.genes import coding_from_graph
from repro.core.fitness import WallClockFitness
from repro.core.offload import ga_search

from benchmarks.common import DEMO_CONSTS, DEMO_SRC, demo_inputs, row, timeit

# the module-planning comparison runs in a subprocess with these flags (they
# must be set before the backend initializes, and must not leak into other
# benches): one core per XLA compile, so serial leaves a core idle and
# engine-level parallelism is measurable rather than fighting the compiler's
# internal thread pools for the same cores
_MODULE_BENCH_XLA_FLAGS = ("--xla_cpu_parallel_codegen_split_count=1 "
                           "--xla_cpu_multi_thread_eigen=false")


# ---------------------------------------------------------------------------
# module-planning worker (spawn target: must be importable at module level)
# ---------------------------------------------------------------------------

_MODULE_ARCH = dict(arch_id="bench_dense", family="dense", n_layers=2,
                    d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
                    d_ff=256, vocab=512, mlp_act="silu", tie_embeddings=False)


def _build_module_fitness():
    """CostModelFitness over the module frontend: bits -> plan -> lower."""
    import jax
    from repro.configs.base import ArchConfig
    from repro.core.fitness import CostModelFitness
    from repro.core.frontends import module_frontend
    from repro.models import build_model
    from repro.models.plan import ExecPlan

    cfg = ArchConfig(**_MODULE_ARCH)
    model = build_model(cfg)
    params = model.param_shapes()
    graph = module_frontend.build_graph(cfg)
    batch = jax.eval_shape(lambda k: model.demo_batch(k, 4, 32),
                           jax.random.key(1))

    def lower(bits):
        plan = module_frontend.plan_from_bits(graph, bits, ExecPlan())
        return jax.jit(lambda p, b: model.loss(p, b, plan)).lower(params, batch)

    return CostModelFitness(lower=lower, n_devices=1), graph


def _module_fitness_factory():
    """Pool workers rebuild the module CostModelFitness once each (spawn
    initializer); registered so ``GAConfig.pool='bench_module_cost'`` or a
    hand-built :class:`ProcessPool` can select it by name."""
    return _build_module_fitness()[0]


register_fitness_factory("bench_module_cost", _module_fitness_factory)


# ---------------------------------------------------------------------------
# part 1: python-frontend GA with wall-clock fitness + persistent cache
# ---------------------------------------------------------------------------


def _bench_python_ga(rows: list, quick: bool = False) -> None:
    program = PyProgram(DEMO_SRC, consts=DEMO_CONSTS)
    inputs = demo_inputs()
    program.check_offloadable(inputs)
    coding = coding_from_graph(program.graph)

    # reference outputs for the PCAST check
    env0 = Executor(program, {}).run(**inputs)
    import numpy as np
    ref = {n: np.asarray(env0[n]) for n in program.output_names}

    def build(bits):
        impl = coding.decode(bits)
        def run():
            ex = Executor(program, impl)
            env = ex.run(**inputs)
            return {n: np.asarray(env[n]) for n in program.output_names}
        return run

    fitness = WallClockFitness(build=build, reference_output=ref, repeats=2)
    cache_dir = tempfile.mkdtemp(prefix="ga_bench_cache_")
    try:
        cfg = GAConfig(population=6 if quick else 10,
                       generations=4 if quick else 6, seed=0,
                       cache_dir=cache_dir)
        res = ga_search(program.graph, fitness, cfg)[1]

        all_on = fitness(coding.all_on())
        base = res.baseline.time_s
        saved_frac = res.measurements_saved / max(
            1, res.measurements_saved + res.evaluations)
        rows += [
            row("ga_offload.baseline_all_cpu", base * 1e6, "1.00x"),
            row("ga_offload.all_offload", all_on.time_s * 1e6,
                f"{base / all_on.time_s:.2f}x"),
            row("ga_offload.ga_best", res.best.time_s * 1e6,
                f"{base / res.best.time_s:.2f}x"),
            # machine-relative ratios (in percent): the rows BENCH_PR5.json
            # gates on — absolute microseconds are not comparable between
            # the baseline host and a CI runner, ratios are
            row("ga_offload.speedup_best_pct", 100.0 * base / res.best.time_s,
                "GA best vs all-CPU baseline, same machine/run"),
            row("ga_offload.best_vs_all_on_pct",
                100.0 * all_on.time_s / res.best.time_s,
                "GA best vs the all-offload pattern, same machine/run "
                "(>= ~100: the search never loses to blind full offload)"),
            row("ga_offload.saved_frac_pct", 100.0 * saved_frac,
                f"saved={res.measurements_saved} of "
                f"{res.measurements_saved + res.evaluations} requested "
                f"(cache+dedup+screening)"),
            row("ga_offload.evaluations", res.evaluations,
                f"of {2 ** coding.length} exhaustive; cache_hits={res.cache_hits}"),
            row("ga_offload.gene_length", coding.length,
                f"best={''.join(map(str, res.best.bits))}"),
            row("ga_offload.search_wall_s", res.wall_s * 1e6,
                f"eval={res.eval_wall_s:.2f}s of {res.wall_s:.2f}s; "
                f"saved={res.measurements_saved} "
                f"(cache={res.cache_hits} dup_avoided={res.duplicates_avoided})"),
            row("ga_offload.surrogate_rank_corr",
                res.surrogate_rank_corr * 1e6,
                f"spearman(surrogate, measured)={res.surrogate_rank_corr:.3f}"
                f" over {res.evaluations} measurements; sets screen_top_k"
                f" from data"),
        ]
        assert res.best.time_s <= all_on.time_s * 1.05  # GA >= all-offload

        # warm re-run: the persistent cache should do (nearly) all the work
        res2 = ga_search(program.graph, fitness, cfg)[1]
        rows.append(row(
            "ga_offload.warm_rerun_new_measurements", res2.evaluations,
            f"persistent_hits={res2.persistent_hits} "
            f"wall={res2.wall_s:.2f}s vs cold {res.wall_s:.2f}s"))
        assert res2.persistent_hits > 0
        assert res2.evaluations < res.evaluations

        # journal-fitted surrogate: regression over the two searches'
        # measurement journal vs the hand formula, then a third search that
        # prefers whichever model the journal says ranks better
        from repro.core.offload import search_fingerprint
        from repro.core.surrogate import fit_surrogate
        fp = search_fingerprint(program.graph, coding)
        fit = fit_surrogate(program.graph, coding, cache_dir, fp,
                            min_records=cfg.surrogate_min_records)
        assert fit is not None, "journal too small to fit a surrogate"
        rows.append(row(
            "ga_offload.surrogate_fitted_rank_corr", fit.rank_corr * 1e6,
            f"journal fit over {fit.n_records} records: spearman "
            f"{fit.rank_corr:.3f} vs static {fit.static_rank_corr:.3f}"))
        res3 = ga_search(program.graph, fitness,
                          GAConfig(population=cfg.population,
                                   generations=cfg.generations,
                                   seed=1, cache_dir=cache_dir))[1]
        rows.append(row(
            "ga_offload.surrogate_kind_fitted",
            1.0 if res3.surrogate_kind == "fitted" else 0.0,
            f"third search ranked offspring with the "
            f"{res3.surrogate_kind} surrogate "
            f"(measured corr {res3.surrogate_rank_corr:.3f})"))
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)


# ---------------------------------------------------------------------------
# part 1a: journal-fitted surrogate on a deterministic synthetic journal
# ---------------------------------------------------------------------------


def _bench_surrogate_fit_synth(rows: list) -> None:
    """Deterministic fit-vs-hand-formula comparison (the gateable number):
    a synthetic journal whose measured times carry per-site effects the
    static transfer-cost formula cannot see (one region's offload is slow,
    another's is very fast).  Fitness is exact, the fit is least squares —
    byte-identical across runs and machines, unlike the wall-clock rows."""
    import tempfile as _tempfile

    import numpy as np

    from repro.core.evaluator import Evaluator, transfer_cost_surrogate
    from repro.core.genes import coding_from_graph as _coding
    from repro.core.ir import Region, RegionGraph
    from repro.core.surrogate import fit_surrogate

    regions = [
        Region(f"r{i}", "loop", uses=frozenset({f"v{i}"}),
               defs=frozenset({f"v{i}"}), offloadable=True,
               alternatives=("ref", "kernel"), trip_count=2 + i)
        for i in range(5)]
    graph = RegionGraph(regions, "ir", "bench_synth")
    coding = _coding(graph)
    w = (0.05, 0.9, -0.1, -0.6, -0.05)

    def fit_fn(bits):
        t = 1.0 + sum(wi * b for wi, b in zip(w, bits))
        return Evaluation(tuple(bits), t, True)

    d = _tempfile.mkdtemp(prefix="ga_bench_synth_")
    try:
        ev = Evaluator(fit_fn, cache_dir=d, fingerprint="synth")
        rng = np.random.default_rng(0)
        ev.evaluate_batch([tuple(int(x) for x in rng.integers(0, 2, 5))
                           for _ in range(40)])
        fit = fit_surrogate(graph, coding, d, "synth",
                            prior=transfer_cost_surrogate(graph, coding),
                            min_records=10)
        assert fit is not None and fit.beats_static
        rows.append(row(
            "ga_offload.surrogate_fit_gain_synth",
            (fit.rank_corr - fit.static_rank_corr) * 1e6,
            f"deterministic journal: fitted spearman {fit.rank_corr:.3f} "
            f"vs static {fit.static_rank_corr:.3f} over "
            f"{fit.n_records} records"))
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------
# part 1a': multi-objective Pareto search on a mixed-destination workload
# ---------------------------------------------------------------------------


def _bench_pareto(rows: list) -> None:
    """NSGA multi-objective search (latency × energy × transfer) over the
    extended cpu/gpu/fpga_stub alphabet: deterministic fitness + modeled
    watts, so the front shape and the energy-vs-latency trade-off are
    byte-stable across machines — the gateable Pareto numbers.  GPU genes
    cut wall-clock but burn 250 W, CPU is slow at 65 W, the stub adds
    modeled seconds at 30 W: a mixed-destination front must exist even on
    CPU-only CI."""
    from repro.core import OffloadConfig, Offloader
    from repro.core import objectives as objmod
    from repro.core.ga import dominates
    from repro.core.genes import EXTENDED_ALPHABET
    from repro.core.ir import Region, RegionGraph

    regions = [
        Region(f"r{i}", "loop", uses=frozenset({f"v{i}"}),
               defs=frozenset({f"v{i}"}), offloadable=True,
               alternatives=("ref", "kernel"), trip_count=2 + i)
        for i in range(5)]
    graph = RegionGraph(regions, "ir", "bench_pareto")

    def speedup(values) -> Evaluation:
        t = 1.0 - 0.12 * sum(int(v) == 1 for v in values)
        return Evaluation(tuple(values), t, True)

    res = Offloader(OffloadConfig(
        frontend="ir", fitness_fn=speedup, destinations=EXTENDED_ALPHABET,
        ga=GAConfig(population=10, generations=4, seed=0,
                    objectives=objmod.OBJECTIVES))).plan(graph)

    front = res.front_summary()
    assert len(front) >= 2, "mixed-destination workload must yield a front"
    pts = [objmod.objective_values(ev, res.graph, res.coding)
           for ev in res.front]
    for i, a in enumerate(pts):
        for j, b in enumerate(pts):
            assert i == j or not dominates(a, b), "front not Pareto-optimal"

    lat = min(front, key=lambda p: (p["latency_s"], p["energy_j"]))
    en = min(front, key=lambda p: (p["energy_j"], p["latency_s"]))
    energy_gain = 100.0 * (lat["energy_j"] / en["energy_j"] - 1.0)
    latency_cost = 100.0 * (en["latency_s"] / lat["latency_s"] - 1.0)
    assert energy_gain > 0 and latency_cost > 0, \
        "energy-optimal must trade latency for joules"
    rows += [
        row("ga_offload.pareto_front_size", len(front),
            f"non-dominated patterns over {objmod.OBJECTIVES}; "
            f"latency-opt={''.join(map(str, lat['bits']))} "
            f"energy-opt={''.join(map(str, en['bits']))}"),
        row("ga_offload.pareto_energy_gain_pct", energy_gain,
            f"latency point burns {lat['energy_j']:.1f} J vs "
            f"{en['energy_j']:.1f} J at the energy point, which pays "
            f"{latency_cost:.0f}% latency for it (modeled watts, "
            f"deterministic)"),
    ]


# ---------------------------------------------------------------------------
# part 1a'': mesh destinations — placement x parallelism in the alphabet
# ---------------------------------------------------------------------------


def _bench_mesh(rows: list) -> None:
    """Mesh destinations in the search: a deterministic multi-objective GA
    over an explicit cpu/gpu/mesh alphabet.  On single-device CI a mesh
    gene is cost-only — it charges the modeled per-shard transfer +
    collective cost, prices energy at n devices, and divides the transfer
    objective by its shard count — so the mesh rows are pure model
    arithmetic, byte-stable on any host.  Genuine shard_map execution is
    covered by the forced-8-device test, not gated here."""
    from repro.core import OffloadConfig, Offloader
    from repro.core import objectives as objmod
    from repro.core.genes import with_mesh_destinations
    from repro.core.ir import Region, RegionGraph
    from repro.core.transfer_planner import modeled_mesh_cost_s

    # the model itself: 4 MB each way, 4 trips, on a 4-way data mesh
    cost_us = modeled_mesh_cost_s(4e6, 4e6, trips=4, axis="data", n=4) * 1e6
    # proposal arithmetic is host-independent when device_count is explicit;
    # on this (possibly single-device) host the proposal must shrink to fit
    prop8 = with_mesh_destinations(("cpu", "gpu"), device_count=8)
    prop_here = with_mesh_destinations(("cpu", "gpu"))
    rows += [
        row("ga_offload.mesh_modeled_cost_us", cost_us,
            "modeled_mesh_cost_s(4MB, 4MB, trips=4, data, n=4): per-shard "
            "links + ring collective + per-device launch (deterministic)"),
        row("ga_offload.mesh_proposal_size", len(prop8),
            f"with_mesh_destinations(cpu/gpu, device_count=8)={prop8[2:]}; "
            f"this host proposes {len(prop_here) - 2} mesh genes"),
    ]
    assert len(prop8) == 5 and prop8[2:] == (
        "mesh:data:2:batch", "mesh:data:4:batch", "mesh:data:8:batch")

    mesh = "mesh:data:4:batch"
    alphabet = ("cpu", "gpu", mesh)
    regions = [
        Region(f"r{i}", "loop", uses=frozenset({f"v{i}"}),
               defs=frozenset({f"v{i}"}), offloadable=True,
               alternatives=("ref", "kernel"), trip_count=2 + i)
        for i in range(5)]
    graph = RegionGraph(regions, "ir", "bench_mesh")

    def speedup(values) -> Evaluation:
        # any offload helps measured time equally; the mesh gene then pays
        # its modeled cost on top (slower) but ships 1/4 the bytes and a
        # collective (transfer objective) — a genuine three-way trade-off
        t = 1.0 - 0.12 * sum(int(v) != 0 for v in values)
        return Evaluation(tuple(values), t, True)

    res = Offloader(OffloadConfig(
        frontend="ir", fitness_fn=speedup, destinations=alphabet,
        ga=GAConfig(population=12, generations=5, seed=0,
                    objectives=objmod.OBJECTIVES))).plan(graph)

    front = res.front_summary()
    mesh_idx = alphabet.index(mesh)
    mesh_pts = [p for p in front if mesh_idx in p["bits"]]
    single_pts = [p for p in front if mesh_idx not in p["bits"]]
    assert mesh_pts and single_pts, \
        "front must hold mesh and single-device points"
    rows.append(row(
        "ga_offload.mesh_front_points", len(mesh_pts),
        f"{len(mesh_pts)} mesh / {len(single_pts)} single-device points on "
        f"a {len(front)}-point front over {objmod.OBJECTIVES} "
        f"(cost-only mesh: modeled latency up, transfer bytes / n)"))


# ---------------------------------------------------------------------------
# part 1b: measured jaxpr search with compile-parallel/time-serial warm-ups
# ---------------------------------------------------------------------------


def _bench_jaxpr_overlap(rows: list) -> None:
    """The substitution-engine path the compile-overlap phase targets: each
    chromosome's warm-up is one ``engine.substitute()`` + ``jax.jit``
    compile (GIL-releasing), so different chromosomes' compiles overlap
    ahead of the strictly serial timing loop.  EvalStats reports the
    savings; the timing loop itself never interleaves with compilation."""
    import time as _time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import GAConfig, OffloadConfig, plan_offload

    def _jax_app(q, k, v, w):
        def attention(q, k, v):
            s = q @ k.T / jnp.sqrt(q.shape[-1] * 1.0)
            mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), bool))
            return jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1) @ v

        def body(h, _):
            return jnp.tanh(h @ w), ()

        h, _ = jax.lax.scan(body, attention(q, k, v), None, length=4)
        return h

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 32)) * 0.1, jnp.float32)

    t0 = _time.perf_counter()
    res = plan_offload(_jax_app, config=OffloadConfig(
        ga=GAConfig(population=6, generations=3, seed=0),
        options={"example_args": (q, k, v, w)}, repeats=2))
    dt = _time.perf_counter() - t0
    saved = res.savings["compile_overlap_saved_s"]
    eval_wall = max(res.savings["eval_wall_s"], 1e-9)
    rows += [
        row("ga_offload.jaxpr_overlap_search_s", dt * 1e6,
            f"measured jaxpr plan, compile-parallel warm-ups; "
            f"verified={res.verification['verified']}"),
        row("ga_offload.compile_overlap_saved_pct",
            100.0 * saved / (eval_wall + saved),
            f"estimated warm-up wall saved: {saved:.2f}s on top of "
            f"{eval_wall:.2f}s eval wall (sum of prepare durations minus "
            f"overlapped phase wall — contention waits count as savings "
            f"ceiling, the timing loop stays serial)"),
    ]
    assert res.verification["verified"], "overlapped jaxpr plan must verify"
    if (os.cpu_count() or 1) > 1:
        # a single-core host disables the overlap phase entirely; anywhere
        # else, overlapping real compiles must save wall-clock
        assert saved > 0.0, "compile overlap saved nothing on a multi-core host"


# ---------------------------------------------------------------------------
# part 2: module-planning path — parallel vs serial CostModelFitness
# ---------------------------------------------------------------------------


_BURN_SRC = """
import time
t0 = time.perf_counter(); n = 0
while time.perf_counter() - t0 < 3.0:
    for _ in range(10000): n += 1
print(n)
"""


def _parallel_headroom() -> float:
    """Aggregate throughput of 2 concurrent CPU burners over 1: the
    machine's *actual* 2-way process-parallel speedup ceiling.  Virtualized
    CI boxes often advertise N cores but cap aggregate compute below N — the
    evaluator can't beat the hypervisor, so the speedup assertion is scaled
    by this measured ceiling."""
    def run_burners(n: int) -> float:
        procs = [subprocess.Popen([sys.executable, "-c", _BURN_SRC],
                                  stdout=subprocess.PIPE, text=True)
                 for _ in range(n)]
        total = 0
        for p in procs:
            out, _ = p.communicate(timeout=60)
            total += int(out.strip())
        return total / 3.0
    one = run_burners(1)
    two = run_burners(2)
    return two / one


def _module_parallel_main() -> list[str]:
    """Runs inside the subprocess launched by `_bench_module_parallel`."""
    rows: list[str] = []
    headroom = _parallel_headroom()
    fitness, graph = _build_module_fitness()
    coding = coding_from_graph(graph)
    # warm the parent's backend/first-compile path too, so round 1's serial
    # leg isn't inflated by one-time init that the pool workers already paid
    fitness(coding.all_off())

    # spawn-based workers via the reusable evaluator.ProcessPool helper
    # (one-time spawn cost timed separately); warm() makes every worker pay
    # its first-compile cost (LLVM/backend init) before the timed rounds
    t0 = time.perf_counter()
    n_workers = min(3, (os.cpu_count() or 2) + 1)  # slight oversubscription
    pool = ProcessPool("bench_module_cost", workers=n_workers)
    pool.warm([coding.all_off(), coding.all_on()])
    t_spawn = time.perf_counter() - t0

    try:
        # --- evaluation speedup: interleaved A/B rounds -------------------
        # the same 12-chromosome batch is measured serially (in-process) and
        # through the pool back-to-back each round, so slow machine drift
        # cancels; workers hold no cross-call cache, both sides do the same
        # compiles.  Distinct chromosomes every round: nothing is cached.
        nbits = coding.length
        rng_batches = [
            [tuple(int(c) for c in f"{(r * 12 + i) % 2 ** nbits:0{nbits}b}")
             for i in range(12)]
            for r in range(1, 4)
        ]
        ratios, t_ser_tot, t_par_tot = [], 0.0, 0.0
        for batch in rng_batches:
            t0 = time.perf_counter()
            Evaluator(fitness).evaluate_batch(batch)
            t_ser = time.perf_counter() - t0
            t0 = time.perf_counter()
            Evaluator(None, **pool.evaluator_kwargs()).evaluate_batch(batch)
            t_par = time.perf_counter() - t0
            ratios.append(t_ser / t_par)
            t_ser_tot += t_ser
            t_par_tot += t_par
        speedup = sorted(ratios)[len(ratios) // 2]  # median round ratio

        # --- fixed-seed reproducibility: full GA, serial vs parallel ------
        cfg = GAConfig(population=12, generations=3, seed=0)
        t0 = time.perf_counter()
        res_ser = run_ga(coding.length, fitness, cfg)
        t_ga_ser = time.perf_counter() - t0
        ev = Evaluator(None, **pool.evaluator_kwargs())
        t0 = time.perf_counter()
        res_par = run_ga(coding.length, None, cfg, evaluator=ev)
        t_ga_par = time.perf_counter() - t0
    finally:
        pool.close()

    rows += [
        row("ga_offload.module_eval_serial_s", t_ser_tot * 1e6,
            f"{12 * len(rng_batches)} measurements over "
            f"{len(rng_batches)} rounds"),
        row("ga_offload.module_eval_parallel_s", t_par_tot * 1e6,
            f"warm {n_workers}-proc pool; median-round "
            f"speedup={speedup:.2f}x "
            f"(rounds: {' '.join(f'{r:.2f}' for r in ratios)})"),
        row("ga_offload.module_parallel_headroom", headroom * 1e6,
            f"machine 2-proc CPU ceiling {headroom:.2f}x; evaluator at "
            f"{speedup / headroom:.0%} of ceiling"),
        row("ga_offload.module_pool_spawn_s", t_spawn * 1e6,
            "one-time spawn+init cost, amortized across searches"),
        row("ga_offload.module_ga_wall_s", t_ga_ser * 1e6,
            f"serial GA {res_ser.evaluations} measurements; parallel "
            f"{t_ga_par:.2f}s ({t_ga_ser/t_ga_par:.2f}x)"),
        row("ga_offload.module_best_match",
            int(res_ser.best.bits == res_par.best.bits),
            f"serial={''.join(map(str, res_ser.best.bits))} "
            f"parallel={''.join(map(str, res_par.best.bits))}"),
    ]
    assert res_ser.best.bits == res_par.best.bits  # fixed-seed reproducibility
    # target 1.5x where the hardware can deliver it; on throttled/virtual
    # boxes require >=85% of the measured CPU ceiling instead, and on a
    # machine with no parallel headroom at all there is nothing to assert.
    # The ceiling is probed minutes before the rounds and hypervisor
    # allocation drifts, so the gate takes the best round (a throttled phase
    # can only depress a round's ratio); the median is what gets reported.
    if headroom >= 1.15:
        target = min(1.5, 0.85 * headroom)
        best_round = max(ratios)
        assert best_round >= target, \
            f"parallel evaluator too slow: best round {best_round:.2f}x " \
            f"(median {speedup:.2f}x) < {target:.2f}x " \
            f"(machine ceiling {headroom:.2f}x)"
    return rows


def _bench_module_parallel(rows: list) -> None:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                        + _MODULE_BENCH_XLA_FLAGS).strip()
    env["OMP_NUM_THREADS"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_ga_offload",
         "--module-parallel"],
        env=env, cwd=root, capture_output=True, text=True, timeout=900)
    out_rows = [ln for ln in res.stdout.splitlines()
                if ln.startswith("ga_offload.module")]
    assert res.returncode == 0 and out_rows, \
        (res.stdout[-2000:], res.stderr[-3000:])
    rows += out_rows


def main(quick: bool = False) -> list[str]:
    """``quick=True`` is the CI smoke: the python-frontend GA at reduced
    budget (cache, dedup, compile overlap, fitted surrogate all still
    exercised), skipping the multi-minute module process-pool A/B."""
    rows: list[str] = []
    _bench_python_ga(rows, quick=quick)
    _bench_surrogate_fit_synth(rows)
    _bench_pareto(rows)
    _bench_mesh(rows)
    _bench_jaxpr_overlap(rows)
    if not quick:
        _bench_module_parallel(rows)
    return rows


if __name__ == "__main__":
    if "--module-parallel" in sys.argv:
        print("\n".join(_module_parallel_main()))
    else:
        print("\n".join(main()))
