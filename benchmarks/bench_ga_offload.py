"""Bench 1 — GA loop-offload search (paper §3.2.1/§4.2.2 mechanism claim):
the GA converges to the fastest offload pattern with far fewer measurements
than exhaustive search, and the found pattern beats both all-CPU and
all-offload."""
from __future__ import annotations

from repro.core.frontends.ast_frontend import Executor, PyProgram
from repro.core.ga import Evaluation, GAConfig, run_ga
from repro.core.genes import coding_from_graph
from repro.core.fitness import WallClockFitness

from benchmarks.common import DEMO_CONSTS, DEMO_SRC, demo_inputs, row, timeit


def main() -> list[str]:
    program = PyProgram(DEMO_SRC, consts=DEMO_CONSTS)
    inputs = demo_inputs()
    program.check_offloadable(inputs)
    coding = coding_from_graph(program.graph)

    # reference outputs for the PCAST check
    env0 = Executor(program, {}).run(**inputs)
    import numpy as np
    ref = {n: np.asarray(env0[n]) for n in program.output_names}

    def build(bits):
        impl = coding.decode(bits)
        def run():
            ex = Executor(program, impl)
            env = ex.run(**inputs)
            return {n: np.asarray(env[n]) for n in program.output_names}
        return run

    fitness = WallClockFitness(build=build, reference_output=ref, repeats=2)
    res = run_ga(coding.length, fitness,
                 GAConfig(population=10, generations=6, seed=0))

    all_on = fitness(coding.all_on())
    base = res.baseline.time_s
    rows = [
        row("ga_offload.baseline_all_cpu", base * 1e6, "1.00x"),
        row("ga_offload.all_offload", all_on.time_s * 1e6,
            f"{base / all_on.time_s:.2f}x"),
        row("ga_offload.ga_best", res.best.time_s * 1e6,
            f"{base / res.best.time_s:.2f}x"),
        row("ga_offload.evaluations", res.evaluations,
            f"of {2 ** coding.length} exhaustive; cache_hits={res.cache_hits}"),
        row("ga_offload.gene_length", coding.length,
            f"best={''.join(map(str, res.best.bits))}"),
    ]
    assert res.best.time_s <= all_on.time_s * 1.05  # GA >= all-offload
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
