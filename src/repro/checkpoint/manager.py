"""Step-indexed checkpointing with atomic commits, async save, keep-last-k,
and reshard-on-restore.

Layout:  <dir>/step_<n>/  {manifest.json, arr_<i>.npy ...}
A checkpoint directory is written under a ``.tmp`` name and atomically
renamed on completion — a crash mid-save never corrupts the latest valid
checkpoint (the restart scans for the newest *committed* step).

``restore`` rebuilds leaves host-side then ``jax.device_put``s with the
*requested* shardings — which is also the elastic-rescale path: a checkpoint
written on a 512-chip mesh restores onto any other mesh by passing that
mesh's shardings (see runtime/fault_tolerance.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(tree: Any, path: str) -> None:
    os.makedirs(path, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        np.save(os.path.join(path, f"arr_{i}.npy"), arr, allow_pickle=False)
        manifest["leaves"].append({"path": p, "file": f"arr_{i}.npy",
                                   "dtype": str(arr.dtype), "shape": list(arr.shape)})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def load_pytree(template: Any, path: str, shardings: Any = None) -> Any:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(template)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, leaf, shd in zip(paths, leaves, shard_leaves):
        e = by_path[p]
        arr = np.load(os.path.join(path, e["file"]), allow_pickle=False)
        want = np.dtype(leaf.dtype) if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want, copy=False)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # --- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        # snapshot to host BEFORE the async thread (donated buffers may die)
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self.wait()

        def _do():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            save_pytree(host_tree, tmp)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)      # atomic commit
            self._gc()

        if self.async_save and not blocking:
            self._pending = threading.Thread(target=_do, daemon=True)
            self._pending.start()
        else:
            _do()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --- restore ------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[int, Any]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {self.dir}")
        tree = load_pytree(template, os.path.join(self.dir, f"step_{step}"),
                           shardings)
        return step, tree
