"""LLaVA-NeXT (mistral-7b backbone) VLM; anyres vision frontend stubbed.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  ``input_specs()`` supplies precomputed
patch embeddings (anyres: up to 5 tiles x 24x24 = 2880 patches of CLIP-dim
1024); the 2-layer MLP projector into d_model IS implemented.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llava_next_mistral_7b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab=32_000,
    attn_kind="full",
    mlp_act="silu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    vision_patches=2880,   # 5 anyres tiles x 576 patches
    vision_dim=1024,       # CLIP ViT-L/14 feature dim
)
