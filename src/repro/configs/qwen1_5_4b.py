"""Qwen1.5 4B dense (QKV bias).

[hf:Qwen/Qwen1.5-0.5B family; hf] — 40L d_model=2560 20H (kv=20) d_ff=6912
vocab=151936, QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1_5_4b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151_936,
    attn_kind="full",
    qkv_bias=True,
    mlp_act="silu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)
