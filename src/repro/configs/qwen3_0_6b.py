"""Qwen3 0.6B dense (qk_norm, GQA).

[hf:Qwen/Qwen3-8B family; hf] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936, qk_norm.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3_0_6b",
    family="dense",
    source="hf:Qwen/Qwen3-8B; hf",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab=151_936,
    attn_kind="full",
    qk_norm=True,
    mlp_act="silu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
