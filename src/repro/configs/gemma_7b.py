"""Gemma 7B dense.

[arXiv:2403.08295; hf] — 28L d_model=3072 16H (kv=16) d_ff=24576
vocab=256000, GeGLU, head_dim=256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma_7b",
    family="dense",
    source="arXiv:2403.08295; hf",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24_576,
    vocab=256_000,
    attn_kind="full",
    mlp_act="gelu",  # GeGLU
    rope_theta=10_000.0,
    tie_embeddings=True,
    scale_embeddings=True,
    logit_softcap=0.0,
)
