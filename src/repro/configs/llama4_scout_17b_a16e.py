"""Llama-4 Scout 17B-active / 16-expert MoE.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1, early fusion.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="llama4_scout_17b_a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202_048,
    attn_kind="full",
    mlp_act="silu",
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared_experts=1),
    moe_every=1,
    rope_theta=500_000.0,
    tie_embeddings=False,
)
