"""TinyLlama 1.1B dense (llama2 arch, small).

[arXiv:2401.02385; hf] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="tinyllama_1_1b",
    family="dense",
    source="arXiv:2401.02385; hf",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab=32_000,
    attn_kind="full",
    mlp_act="silu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)
