"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay.

[arXiv:2404.05892; hf] — 32L d_model=2560 d_ff=8960 vocab=65536, head_dim=64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6_3b",
    family="ssm",
    source="arXiv:2404.05892; hf",
    n_layers=32,
    d_model=2560,
    n_heads=40,             # 2560 / 64 wkv heads
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65_536,
    attn_kind="none",
    mlp_act="relu_sq",      # rwkv channel-mix uses squared relu
    rwkv_head_dim=64,
    tie_embeddings=False,
)
