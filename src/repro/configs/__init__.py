from repro.configs.base import (
    ALL_SHAPES,
    ARCH_IDS,
    SHAPES_BY_NAME,
    ArchConfig,
    MoEConfig,
    ShapeSpec,
    all_configs,
    get_config,
)

__all__ = [
    "ALL_SHAPES",
    "ARCH_IDS",
    "SHAPES_BY_NAME",
    "ArchConfig",
    "MoEConfig",
    "ShapeSpec",
    "all_configs",
    "get_config",
]
