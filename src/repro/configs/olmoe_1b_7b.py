"""OLMoE 1B-active / 7B-total MoE.

[arXiv:2409.02060; hf] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="olmoe_1b_7b",
    family="moe",
    source="arXiv:2409.02060; hf",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50_304,
    attn_kind="full",
    qk_norm=True,  # OLMoE uses QK-Norm
    mlp_act="silu",
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    moe_every=1,
    rope_theta=10_000.0,
    tie_embeddings=False,
)
