"""Whisper-small encoder-decoder (audio backbone; conv frontend stubbed).

[arXiv:2212.04356; unverified] — 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865.  ``input_specs()`` supplies precomputed frame embeddings for the
encoder (the conv frontend is a STUB per the assignment).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper_small",
    family="encdec",
    source="arXiv:2212.04356; unverified",
    n_layers=12,            # decoder layers
    n_encoder_layers=12,
    encoder_seq=1500,       # 30 s of audio at 50 Hz after the conv stub
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51_865,
    attn_kind="full",
    mlp_act="gelu",
    rope_theta=0.0,         # whisper uses learned/sinusoidal positions, not RoPE
    tie_embeddings=True,
)
