"""Architecture + shape configuration system.

Every assigned architecture is described by one :class:`ArchConfig` in its own
``configs/<id>.py`` file.  Configs are plain frozen dataclasses so they can be
hashed, diffed and serialized; the registry maps ``--arch <id>`` strings to
them.  ``reduced()`` returns the small same-family config used by the CPU
smoke tests; the full config is only ever lowered via ShapeDtypeStructs in the
dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Shape specs (shared by every LM-family architecture)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One benchmark cell: an input shape + which step function it lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES: tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0          # expert hidden size (may differ from dense d_ff)
    capacity_factor: float = 1.25
    n_shared_experts: int = 0     # llama4-style shared expert (always-on)
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclass(frozen=True)
class ArchConfig:
    # identity -------------------------------------------------------------
    arch_id: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    source: str = ""              # provenance note from the assignment table

    # trunk ------------------------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab: int = 0

    # attention flavour ------------------------------------------------------
    attn_kind: str = "full"       # full | local | none (pure recurrence)
    local_window: int = 2048      # for attn_kind == "local"
    qk_norm: bool = False         # qwen3-style RMSNorm on q and k
    qkv_bias: bool = False        # qwen1.5-style bias on qkv projections
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0    # gemma-style final-logit softcap (0 = off)

    # MLP flavour --------------------------------------------------------------
    mlp_act: str = "silu"         # silu (SwiGLU) | gelu (GeGLU)

    # MoE ----------------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    moe_every: int = 1            # MoE in every k-th layer (1 = all layers)

    # hybrid / recurrent -----------------------------------------------------
    block_pattern: tuple[str, ...] = ()   # e.g. ("rglru","rglru","local_attn")
    d_rnn: int = 0                # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4         # RG-LRU temporal conv width

    # rwkv ---------------------------------------------------------------------
    rwkv_head_dim: int = 64

    # enc-dec -------------------------------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 0          # fixed encoder context (whisper: 1500 frames)

    # vlm ------------------------------------------------------------------------
    vision_patches: int = 0       # stub patch-embedding count (llava anyres)
    vision_dim: int = 0           # raw vision feature dim before projector

    # embeddings ------------------------------------------------------------------
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # gemma multiplies embeddings by sqrt(d)

    # norm --------------------------------------------------------------------
    norm_eps: float = 1e-6

    # --- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_rnn_resolved(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def subquadratic(self) -> bool:
        """True when a 500k-token decode is feasible (no full-attn KV scaling)."""
        return self.family in ("hybrid", "ssm")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def supports_shape(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k" and not self.subquadratic:
            return False
        return True

    def skip_reason(self, shape: ShapeSpec) -> str:
        if shape.name == "long_500k" and not self.subquadratic:
            return "pure full-attention arch: 500k decode needs sub-quadratic attention (see DESIGN.md)"
        return ""

    # --- parameter counting (for roofline MODEL_FLOPS = 6 N D) ----------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            bias = (n_q + 2 * n_kv) if self.qkv_bias else 0
            return d * n_q + 2 * d * n_kv + n_q * d + bias

        def dense_mlp(dff: int) -> int:
            return 3 * d * dff  # gated (up, gate, down)

        def rglru_params() -> int:
            dr = self.d_rnn_resolved
            # in/out proj (x2 branches), conv, gates (block-diag approximated dense/heads)
            return 2 * d * dr + dr * d + self.conv1d_width * dr + 2 * dr * (dr // max(self.n_heads, 1)) + 2 * dr

        def rwkv_layer() -> int:
            # time-mix: r,k,v,w,g,o projections + lora for w + channel-mix
            tm = 5 * d * d + 2 * d * 64 + d * d
            cm = 2 * d * int(self.d_ff)
            return tm + cm

        total = embed
        active = embed
        for li in range(self.n_layers):
            if self.family == "ssm":
                p = rwkv_layer()
                total += p
                active += p
                continue
            blk = self.block_pattern[li % len(self.block_pattern)] if self.block_pattern else "attn"
            if blk == "rglru":
                p = rglru_params() + dense_mlp(self.d_ff)
                total += p
                active += p
                continue
            total += attn_params()
            active += attn_params()
            if self.moe is not None and (li % self.moe_every == 0):
                e = self.moe
                per_exp = dense_mlp(e.d_ff_expert or self.d_ff)
                total += e.n_experts * per_exp + d * e.n_experts
                active += (e.top_k + e.n_shared_experts) * per_exp + d * e.n_experts
                if e.n_shared_experts:
                    total += e.n_shared_experts * per_exp
            else:
                total += dense_mlp(self.d_ff)
                active += dense_mlp(self.d_ff)
        for _ in range(self.n_encoder_layers):
            p = attn_params() + dense_mlp(self.d_ff)
            # decoder layers also carry cross-attention
            total += p
            active += p
        if self.n_encoder_layers:  # decoder cross-attn blocks
            ca = self.n_layers * attn_params()
            total += ca
            active += ca
        if self.vision_patches:
            proj = self.vision_dim * d + d * d  # 2-layer projector
            total += proj
            active += proj
        return active if active_only else total

    # --- smoke-test reduction ---------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2 if not self.block_pattern else len(self.block_pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) or 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=64
            )
        if self.block_pattern:
            kw["n_layers"] = len(self.block_pattern)
        if self.d_rnn:
            kw["d_rnn"] = 64
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
            kw["encoder_seq"] = 16
        if self.vision_patches:
            kw["vision_patches"] = 8
            kw["vision_dim"] = 32
        if self.family == "ssm":
            kw["rwkv_head_dim"] = 16
        kw["local_window"] = min(self.local_window, 32)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS: tuple[str, ...] = (
    "llama4_scout_17b_a16e",
    "olmoe_1b_7b",
    "gemma_7b",
    "tinyllama_1_1b",
    "qwen1_5_4b",
    "qwen3_0_6b",
    "whisper_small",
    "recurrentgemma_2b",
    "llava_next_mistral_7b",
    "rwkv6_3b",
)

_ALIASES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "gemma-7b": "gemma_7b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen3-0.6b": "qwen3_0_6b",
    "whisper-small": "whisper_small",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "rwkv6-3b": "rwkv6_3b",
}


def get_config(arch_id: str) -> ArchConfig:
    canon = _ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "_")
    if canon not in ARCH_IDS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{canon}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
