"""RecurrentGemma 2B (Griffin): RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427; hf] — 26L d_model=2560 10H (GQA kv=1 => MQA) d_ff=7680
vocab=256000, d_rnn lru_width=2560, local window 2048.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="recurrentgemma_2b",
    family="hybrid",
    source="arXiv:2402.19427; hf",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,           # MQA
    head_dim=256,
    d_ff=7680,
    vocab=256_000,
    attn_kind="local",
    local_window=2048,
    mlp_act="gelu",
    block_pattern=("rglru", "rglru", "local_attn"),
    d_rnn=2560,
    conv1d_width=4,
    rope_theta=10_000.0,
    tie_embeddings=True,
    scale_embeddings=True,
)
