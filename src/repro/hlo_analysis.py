"""HLO-text cost analyzer with while-loop trip-count multipliers.

``compiled.cost_analysis()`` counts a while body ONCE, which under-counts a
scan-over-layers transformer by ~n_layers and misses per-layer collectives —
useless for roofline work.  This module parses ``compiled.as_text()``
(post-SPMD-partitioning, post-fusion HLO) into a computation call graph and
computes per-device totals with correct multipliers:

  * while ops carry ``backend_config={"known_trip_count":{"n":"22"}}`` (XLA
    annotates scans); fallback: the ``constant(n)``/compare in the condition;
  * fusion internals contribute FLOPs (dots) but not HBM bytes (they live in
    registers/VMEM — counting only top-level op operands/results matches
    actual traffic better than XLA's per-op accounting);
  * dynamic-slice / dynamic-update-slice count the *slice* bytes, not the
    whole operand (a one-token KV-cache update costs one token);
  * collectives get ring-model link bytes with their true replica-group size.

This is the "profile" every §Perf iteration reads.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((?P<params>.*)\)\s*->")
_INSTR_HEAD = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OP_CALL = re.compile(r"\s*([\w\-]+)\(")


def _split_instr(line: str):
    """'%x = TYPE op(args), attrs' -> (name, type_str, op, tail) or None.

    Handles tuple types containing '/*index=k*/' comments (which contain '='
    and break naive regexes) via balanced-paren scanning.
    """
    m = _INSTR_HEAD.match(line)
    if not m:
        return None
    name = m.group(2)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        type_str, rest2 = rest[:end], rest[end:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest2 = rest[:sp], rest[sp:]
    om = _OP_CALL.match(rest2)
    if not om:
        return None
    return name, type_str, om.group(1), rest2[om.end():]
_TYPE = re.compile(r"(?P<dtype>[a-z]\d*[a-z]?\d*(?:e\d+m\d+(?:fn)?)?)\[(?P<dims>[\d,]*)\]")
_PARAM = re.compile(r"%?([\w.\-]+):\s*(\(?[^,()]+(?:\([^)]*\))?\)?(?:\[[\d,]*\])?(?:\{[\d,]*\})?)")
_TRIP = re.compile(r"known_trip_count\D*?(\d+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1 = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(type_str: str) -> list[tuple[str, list[int]]]:
    """Parse 'f32[8,64]{1,0}' or tuple '(f32[2], s32[])' into [(dtype,[dims])]."""
    out = []
    for m in _TYPE.finditer(type_str):
        dims = [int(d) for d in m.group("dims").split(",") if d]
        out.append((m.group("dtype"), dims))
    if not out and "[]" in type_str:
        dt = type_str.strip().strip("()").split("[")[0]
        out.append((dt, []))
    return out


def _nbytes(shapes: list[tuple[str, list[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    link_bytes: float = 0.0
    collectives: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    lines: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)     # var -> [(dtype, dims)]
    cost: Optional[OpCost] = None                   # own (non-child) cost
    children: list = field(default_factory=list)    # (comp_name, mult, kind)


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = cur.name
                for pm in _PARAM.finditer(m.group("params")):
                    cur.symbols[pm.group(1)] = _shape_list(pm.group(2))
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.lines.append(line)
        si = _split_instr(line)
        if si:
            cur.symbols[si[0]] = _shape_list(si[1])
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        if ids:
            return len(ids)
    return default


def _ring_bytes(op: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return result_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return result_bytes * (g - 1)
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)   # collective-permute


def _line_cost(comp: Computation, line: str, n_devices: int,
               in_fusion: bool) -> tuple[OpCost, list]:
    """Cost of one instruction + child computation references."""
    cost = OpCost()
    children: list = []
    si = _split_instr(line)
    if si is None:
        return cost, children
    _, type_str, op, args_part = si
    result_shapes = _shape_list(type_str)
    result_bytes = _nbytes(result_shapes)
    # operand shape lookup (names before any attribute junk)
    operand_names = []
    paren = args_part.split("),")[0] if ")," in args_part else args_part.rstrip(")")
    for om in _OPERANDS.finditer(paren):
        operand_names.append(om.group(1))
    operand_bytes = sum(_nbytes(comp.symbols.get(o, [])) for o in operand_names)

    # --- child computations -------------------------------------------------
    if op == "while":
        trip = 1
        tm = _TRIP.search(line)
        if tm:
            trip = int(tm.group(1))
        bm = _WHILE_BODY.search(line)
        cm = _WHILE_COND.search(line)
        if bm:
            children.append((bm.group(1), trip, "while_body"))
        if cm:
            children.append((cm.group(1), trip, "while_cond"))
        return cost, children
    if op in ("fusion",):
        fm = _CALLS.search(line)
        if fm:
            children.append((fm.group(1), 1, "fusion"))
        cost.bytes += result_bytes + operand_bytes
        return cost, children
    if op in ("call", "custom-call", "async-start"):
        fm = _CALLS.search(line)
        if fm:
            children.append((fm.group(1), 1, "call"))
        cost.bytes += result_bytes + operand_bytes
        return cost, children
    if op == "conditional":
        bm = _BRANCHES.search(line)
        if bm:
            for b in bm.group(1).split(","):
                b = b.strip().lstrip("%")
                if b:
                    children.append((b, 1, "branch"))
        return cost, children

    # --- collectives --------------------------------------------------------
    if any(op.startswith(c) for c in _COLLECTIVES):
        if op.endswith("-done"):
            return cost, children
        base = next(c for c in _COLLECTIVES if op.startswith(c))
        g = _group_size(line, n_devices)
        lb = _ring_bytes(base, result_bytes, g)
        cost.link_bytes += lb
        cost.bytes += result_bytes + operand_bytes
        cost.collectives.append((base, result_bytes, g, lb))
        return cost, children

    # --- flops --------------------------------------------------------------
    if op == "dot":
        contract = 1
        cmatch = _CONTRACT.search(line)
        lhs = comp.symbols.get(operand_names[0], []) if operand_names else []
        if cmatch and lhs:
            dims = lhs[0][1]
            for idx in cmatch.group(1).split(","):
                if idx.strip() and int(idx) < len(dims):
                    contract *= dims[int(idx)]
        n_result = 1
        for _, dims in result_shapes:
            for d in dims:
                n_result *= d
        cost.flops += 2.0 * n_result * contract
        if not in_fusion:
            cost.bytes += result_bytes + operand_bytes
        return cost, children
    if op == "convolution":
        # approximation: 2 * result * kernel_spatial * in_channels
        kern = comp.symbols.get(operand_names[1], []) if len(operand_names) > 1 else []
        kn = 1
        if kern:
            for d in kern[0][1]:
                kn *= d
        n_result = 1
        for _, dims in result_shapes:
            for d in dims:
                n_result *= d
        out_ch = result_shapes[0][1][-1] if result_shapes and result_shapes[0][1] else 1
        cost.flops += 2.0 * n_result * max(kn // max(out_ch, 1), 1)
        if not in_fusion:
            cost.bytes += result_bytes + operand_bytes
        return cost, children

    # --- memory-special ops ---------------------------------------------------
    if in_fusion:
        return cost, children   # fusion internals: registers, no HBM traffic
    if op in ("dynamic-slice", "gather"):
        cost.bytes += 2 * result_bytes   # read slice + write result
        return cost, children
    if op == "dynamic-update-slice":
        upd = _nbytes(comp.symbols.get(operand_names[1], [])) \
            if len(operand_names) > 1 else result_bytes
        cost.bytes += 2 * upd            # read + write the updated window
        return cost, children
    if op in ("scatter",):
        upd = _nbytes(comp.symbols.get(operand_names[-1], [])) \
            if operand_names else result_bytes
        cost.bytes += operand_bytes + upd
        return cost, children
    if op in ("parameter", "constant", "iota", "get-tuple-element", "tuple",
              "bitcast", "after-all", "partition-id", "replica-id"):
        return cost, children
    if op == "copy":
        cost.bytes += 2 * result_bytes
        return cost, children
    # generic elementwise / reduce / transpose / broadcast ...
    cost.bytes += result_bytes + operand_bytes
    return cost, children


@dataclass
class HloCost:
    flops: float
    bytes: float
    link_bytes: float
    collectives: list            # (op, result_bytes, group, link_bytes, mult)
    by_computation: dict

    def collective_histogram(self) -> dict:
        h: dict = {}
        for op, rb, g, lb, mult in self.collectives:
            k = f"{op}@g{g}"
            e = h.setdefault(k, {"count": 0, "link_bytes": 0.0})
            e["count"] += mult
            e["link_bytes"] += lb * mult
        return h


def analyze_hlo(text: str, n_devices: int) -> HloCost:
    comps = parse_computations(text)
    entry = comps.get("__entry__")
    if entry is None:
        return HloCost(0, 0, 0, [], {})

    # cost each computation's own lines once
    own: dict[str, tuple[OpCost, list]] = {}
    fused_named: set = set()
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        for line in comp.lines:
            for cm in _CALLS.finditer(line):
                if "fusion(" in line:
                    fused_named.add(cm.group(1))

    def comp_cost(name: str, in_fusion: bool) -> tuple[OpCost, list]:
        comp = comps[name]
        total = OpCost()
        children: list = []
        for line in comp.lines:
            c, ch = _line_cost(comp, line, n_devices, in_fusion)
            total.flops += c.flops
            total.bytes += c.bytes
            total.link_bytes += c.link_bytes
            total.collectives.extend(c.collectives)
            children.extend(ch)
        return total, children

    # multiplicity propagation (memoized on (comp, in_fusion))
    totals = OpCost()
    coll_out: list = []
    by_comp: dict = {}
    seen_stack: set = set()

    def visit(name: str, mult: float, in_fusion: bool):
        if name not in comps or name in seen_stack:
            return
        seen_stack.add(name)
        cost, children = comp_cost(name, in_fusion)
        totals.flops += cost.flops * mult
        totals.bytes += cost.bytes * mult
        totals.link_bytes += cost.link_bytes * mult
        for c in cost.collectives:
            coll_out.append((*c, mult))
        e = by_comp.setdefault(name, {"flops": 0.0, "bytes": 0.0,
                                      "link_bytes": 0.0, "mult": 0.0})
        e["flops"] += cost.flops * mult
        e["bytes"] += cost.bytes * mult
        e["link_bytes"] += cost.link_bytes * mult
        e["mult"] += mult
        for child, m, kind in children:
            visit(child, mult * m, in_fusion or kind == "fusion")
        seen_stack.discard(name)

    visit(entry.name, 1.0, False)
    return HloCost(totals.flops, totals.bytes, totals.link_bytes,
                   coll_out, by_comp)
