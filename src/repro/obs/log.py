"""Stdlib-logging setup for the launchers: one ``repro`` logger tree.

Library modules call :func:`get_logger` and log freely — with no handler
installed the records propagate to the root logger's ``lastResort``
handler (WARNING+ only), so tests and importers stay quiet.  CLIs that
want to *see* INFO output (``launch/train.py``, ``launch/dryrun.py``)
call :func:`setup` once at entry; verbosity comes from the argument or
the ``REPRO_LOG_LEVEL`` environment variable.
"""
from __future__ import annotations

import logging
import os
import sys
from typing import Optional, Union

__all__ = ["get_logger", "setup"]

_ROOT = "repro"


def get_logger(name: str = "") -> logging.Logger:
    """A logger under the ``repro.`` namespace (``get_logger("launch.train")
    -> repro.launch.train``); pass a dotted module ``__name__`` verbatim —
    already-qualified names are kept."""
    if not name:
        return logging.getLogger(_ROOT)
    if name == _ROOT or name.startswith(_ROOT + "."):
        return logging.getLogger(name)
    return logging.getLogger(f"{_ROOT}.{name}")


def setup(level: Union[int, str, None] = None,
          stream=None, fmt: Optional[str] = None) -> logging.Logger:
    """Attach one stream handler to the ``repro`` root (idempotent) and
    set its level — ``level`` arg > ``REPRO_LOG_LEVEL`` env > INFO."""
    if level is None:
        level = os.environ.get("REPRO_LOG_LEVEL", "INFO")
    if isinstance(level, str):
        level = getattr(logging, level.upper(), logging.INFO)
    root = logging.getLogger(_ROOT)
    root.setLevel(level)
    if not root.handlers:
        handler = logging.StreamHandler(stream or sys.stderr)
        handler.setFormatter(logging.Formatter(
            fmt or "%(asctime)s %(levelname).1s %(name)s: %(message)s",
            datefmt="%H:%M:%S"))
        root.addHandler(handler)
        root.propagate = False
    else:
        for h in root.handlers:
            h.setLevel(logging.NOTSET)
    return root
