"""Thread-safe context-manager spans with a near-zero-cost disabled path.

One process-global :class:`Tracer` (installed with :func:`enable` /
:func:`maybe_tracing`) assigns every span an id + parent and persists it
as one JSONL record through the shared :class:`repro.core.journal.Journal`
flock helper — the same storage cell every other on-disk record stream in
the system uses, so a trace file tolerates concurrent writers and torn
tails like the measurement journals do.

Design points the hot paths rely on:

* **disabled path**: :func:`span` reads one module global and returns the
  shared :data:`NULL_SPAN` singleton — no allocation, no clock read, no
  branch in the instrumented code.  ``benchmarks/bench_obs.py`` measures
  this cost and CI gates it (``obs.trace_overhead_pct``).
* **per-thread nesting**: each thread keeps its own span stack
  (``threading.local``), so concurrently-planning threads don't parent
  into each other.  Cross-thread work (the Evaluator's compile pool)
  passes ``parent=`` explicitly — the dispatching thread captures its
  span id and hands it to the worker.
* **buffered writes**: finished spans accumulate in memory and flush to
  the journal every ``flush_every`` records (and on :meth:`Tracer.close`),
  so tracing a thousand-chromosome search doesn't pay a thousand flock
  round-trips.
* **metrics ride along**: :meth:`Tracer.close` appends one
  ``{"kind": "metrics", "snapshot": ...}`` record with the process
  metrics registry, so ``launch/obsreport.py`` renders timeline *and*
  counters from a single file.

Span record schema (``kind == "span"``)::

    {"kind": "span", "trace": "t-...", "id": 3, "parent": 1,
     "name": "plan.search", "t0": <perf_counter at entry>,
     "dur_s": 0.42, "ts": <epoch at entry>, "attrs": {...}}

``t0`` is ``time.perf_counter()`` — comparable only within the process
that wrote the trace; renderers use offsets from the root span.
"""
from __future__ import annotations

import contextlib
import itertools
import os
import threading
import time
import uuid
from typing import Any, Dict, Iterator, Optional, Union

from repro.core.journal import Journal
from repro.obs import metrics as _metrics

__all__ = ["Span", "NullSpan", "NULL_SPAN", "Tracer", "span",
           "current_span_id", "enable", "disable", "active_tracer",
           "maybe_tracing", "read_trace"]


class Span:
    """A live span; use as a context manager.  ``set(**attrs)`` attaches
    structured attributes (JSON-serializable values) at any point before
    exit."""

    __slots__ = ("tracer", "name", "id", "parent", "t0", "ts",
                 "dur_s", "attrs")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent: Optional[int], attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.id = span_id
        self.parent = parent
        self.attrs = attrs
        self.dur_s: Optional[float] = None
        self.ts = time.time()
        self.t0 = time.perf_counter()

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.dur_s = time.perf_counter() - self.t0
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.tracer._finish(self)
        return False


class NullSpan:
    """The disabled-path stand-in: every operation is a no-op.  A single
    shared instance (:data:`NULL_SPAN`) is returned by :func:`span` when
    no tracer is installed, so the instrumented code allocates nothing."""

    __slots__ = ()
    id = None
    parent = None
    name = ""
    dur_s = None

    def set(self, **attrs: Any) -> "NullSpan":
        return self

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_SPAN = NullSpan()


class Tracer:
    """Span factory + JSONL sink for one trace file.

    Thread-safe: span ids come from one atomic counter, each thread nests
    on its own stack, and the flush buffer is guarded by a lock.  A tracer
    must be :meth:`close`\\ d (or used via :func:`maybe_tracing`) to
    guarantee the tail of the buffer reaches disk.
    """

    def __init__(self, path: str, trace_id: Optional[str] = None,
                 flush_every: int = 64):
        self.path = path
        self.trace_id = trace_id or f"t-{uuid.uuid4().hex[:12]}"
        self.flush_every = max(1, int(flush_every))
        self._journal = Journal(path)
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._buf: list = []
        self._buf_lock = threading.Lock()
        self._closed = False
        self.span_count = 0

    # -- span lifecycle -----------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, parent: Optional[int] = None,
             **attrs: Any) -> Span:
        stack = self._stack()
        if parent is None and stack:
            parent = stack[-1].id
        s = Span(self, name, next(self._ids), parent, attrs)
        stack.append(s)
        return s

    def current_span_id(self) -> Optional[int]:
        stack = getattr(self._local, "stack", None)
        return stack[-1].id if stack else None

    def _finish(self, s: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack and s in stack:       # tolerate exits out of LIFO order
            stack.remove(s)
        rec = {"kind": "span", "trace": self.trace_id, "id": s.id,
               "parent": s.parent, "name": s.name, "t0": s.t0,
               "dur_s": s.dur_s, "ts": s.ts, "attrs": s.attrs}
        with self._buf_lock:
            self.span_count += 1
            self._buf.append(rec)
            full = len(self._buf) >= self.flush_every
        if full:
            self.flush()

    # -- persistence --------------------------------------------------------

    def flush(self) -> None:
        with self._buf_lock:
            buf, self._buf = self._buf, []
        if buf:
            self._journal.append(buf)

    def close(self) -> None:
        """Flush the buffer and append the process metrics snapshot so a
        single trace file carries timeline + counters.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        self.flush()
        self._journal.append([{"kind": "metrics", "trace": self.trace_id,
                               "ts": time.time(),
                               "snapshot": _metrics.snapshot()}])


# ---------------------------------------------------------------------------
# the module-global tracer (the disabled path is one global read)
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def span(name: str, parent: Optional[int] = None,
         **attrs: Any) -> Union[Span, NullSpan]:
    """A span under the installed tracer, or :data:`NULL_SPAN` when
    tracing is disabled — the only call instrumented code makes."""
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return t.span(name, parent=parent, **attrs)


def current_span_id() -> Optional[int]:
    """This thread's innermost live span id (None when disabled or at the
    root) — pass it as ``parent=`` when handing work to another thread."""
    t = _TRACER
    return None if t is None else t.current_span_id()


def active_tracer() -> Optional[Tracer]:
    return _TRACER


def enable(path: str, trace_id: Optional[str] = None,
           flush_every: int = 64) -> Tracer:
    """Install a process-global tracer writing to ``path``.  Replaces (and
    closes) any previously installed tracer."""
    global _TRACER
    old, _TRACER = _TRACER, None
    if old is not None:
        old.close()
    t = Tracer(path, trace_id=trace_id, flush_every=flush_every)
    _TRACER = t
    return t


def disable() -> None:
    """Close and uninstall the global tracer (no-op when none)."""
    global _TRACER
    old, _TRACER = _TRACER, None
    if old is not None:
        old.close()


@contextlib.contextmanager
def maybe_tracing(path: Optional[str]) -> Iterator[Optional[Tracer]]:
    """Install a tracer for the duration iff ``path`` is set and no tracer
    is already active — the idempotent guard every `Offloader` phase uses,
    so ``plan`` (which calls ``prepare`` and ``search``, each also
    guarded) opens exactly one trace file per top-level call."""
    if not path or _TRACER is not None:
        yield _TRACER
        return
    t = enable(path)
    try:
        yield t
    finally:
        if _TRACER is t:
            disable()
        else:                          # someone re-enabled underneath us
            t.close()


def read_trace(path: str) -> tuple:
    """Load a trace file: ``(spans, metrics_snapshot_or_None)``.  Tolerant
    of torn lines (journal semantics) and foreign records."""
    spans: list = []
    snap = None
    for rec in Journal(path).records():
        kind = rec.get("kind")
        if kind == "span":
            spans.append(rec)
        elif kind == "metrics":
            snap = rec.get("snapshot", snap)
    return spans, snap
