"""Observability layer: tracing spans, metrics registry, logging setup.

Zero-dependency (stdlib + ``repro.core.journal`` only) so every layer —
core, frontends, kernels, service, runtime, launch — can import it
without cycles.  See docs/api.md ("Observability") for naming
conventions and the obsreport CLI.
"""
from repro.obs import metrics
from repro.obs.log import get_logger, setup as setup_logging
from repro.obs.metrics import (REGISTRY, counter, gauge, histogram,
                               render_prometheus, snapshot)
from repro.obs.trace import (NULL_SPAN, Tracer, active_tracer,
                             current_span_id, disable, enable,
                             maybe_tracing, read_trace, span)

__all__ = [
    "metrics", "REGISTRY", "counter", "gauge", "histogram",
    "snapshot", "render_prometheus",
    "span", "current_span_id", "maybe_tracing", "enable", "disable",
    "active_tracer", "Tracer", "NULL_SPAN", "read_trace",
    "get_logger", "setup_logging",
]
