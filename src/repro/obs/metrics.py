"""Process-wide metrics registry: counters, gauges, histograms.

Zero-dependency (stdlib only).  One module-level :data:`REGISTRY` holds
every metric family; call sites grab a handle once and mutate it —
handles are cheap to re-resolve, so hot paths may also call
``counter(...)`` per event without setup.

Naming convention (see docs/api.md): dotted lowercase families
(``eval.cache_hits``, ``service.admission``), labels for the dimensions
a single family fans out over (``counter("service.admission",
outcome="live-hit")``).  Histograms record seconds unless the name says
otherwise.

Two export formats:

* :func:`snapshot` — a plain-JSON dict (round-trips through
  ``json.dumps``), embedded in trace files by ``obs.trace.Tracer.close``
  and dumped by ``launch/obsreport.py`` and the bench ``--metrics``
  artifact.
* :func:`render_prometheus` — Prometheus text exposition (``# TYPE``
  lines, ``name{label="v"} value``, histogram ``_bucket``/``_sum``/
  ``_count`` series) for scraping a long-lived service.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "counter", "gauge", "histogram", "snapshot", "render_prometheus",
           "reset"]

#: default histogram buckets (seconds): 100us .. 30s covers everything from
#: a null-span probe to a cold GA search.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 5e-4, 1e-3, 5e-3, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("value", "_lock")
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def as_dict(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Point-in-time value (set/inc/dec)."""

    __slots__ = ("value", "_lock")
    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value -= amount

    def as_dict(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Bucketed distribution with sum/count/min/max.

    Buckets are cumulative upper bounds (Prometheus ``le`` semantics);
    observations above the last bound land only in the implicit +Inf
    bucket (= ``count``).
    """

    __slots__ = ("buckets", "bucket_counts", "count", "sum",
                 "min", "max", "_lock")
    kind = "histogram"

    def __init__(self, buckets: Optional[Tuple[float, ...]] = None) -> None:
        self.buckets = tuple(sorted(buckets or DEFAULT_BUCKETS))
        self.bucket_counts = [0] * len(self.buckets)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            for i, bound in enumerate(self.buckets):
                if v <= bound:
                    self.bucket_counts[i] += 1

    def as_dict(self) -> dict:
        d = {"count": self.count, "sum": self.sum,
             "buckets": {f"{b:g}": c for b, c
                         in zip(self.buckets, self.bucket_counts)}}
        if self.count:
            d["min"] = self.min
            d["max"] = self.max
            d["mean"] = self.sum / self.count
        return d


class MetricsRegistry:
    """Thread-safe family store: ``(name, sorted-label-tuple) -> metric``.

    A family name is bound to one metric kind on first use; asking for the
    same name with a different kind raises — mixed-kind families cannot be
    rendered in either export format.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, LabelKey], object] = {}
        self._kinds: Dict[str, str] = {}

    def _get(self, cls, name: str, labels: Dict[str, str], **kw):
        key = (name, _label_key(labels))
        with self._lock:
            kind = self._kinds.setdefault(name, cls.kind)
            if kind != cls.kind:
                raise ValueError(
                    f"metric {name!r} already registered as {kind}, "
                    f"requested {cls.kind}")
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(**kw)
            return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: Optional[Tuple[float, ...]] = None,
                  **labels: str) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets)

    def _families(self) -> Iterator[Tuple[str, str, list]]:
        with self._lock:
            items = sorted(self._metrics.items())
            kinds = dict(self._kinds)
        by_name: Dict[str, list] = {}
        for (name, lk), metric in items:
            by_name.setdefault(name, []).append((lk, metric))
        for name in sorted(by_name):
            yield name, kinds[name], by_name[name]

    def snapshot(self) -> dict:
        """Plain-JSON dump: ``{name: {"kind":..., "series":[...]}}``."""
        out: Dict[str, dict] = {}
        for name, kind, series in self._families():
            out[name] = {"kind": kind, "series": [
                {"labels": dict(lk), **metric.as_dict()}
                for lk, metric in series]}
        return out

    def render_prometheus(self) -> str:
        lines: list = []

        def fmt(name: str, lk: LabelKey, value: float,
                extra: Optional[Tuple[str, str]] = None) -> str:
            pairs = list(lk) + ([extra] if extra else [])
            labels = ",".join(f'{k}="{v}"' for k, v in pairs)
            body = f"{{{labels}}}" if labels else ""
            return f"{name}{body} {value:g}"

        for name, kind, series in self._families():
            pname = name.replace(".", "_").replace("-", "_")
            lines.append(f"# TYPE {pname} {kind}")
            for lk, metric in series:
                if kind == "histogram":
                    cum = 0
                    for bound, c in zip(metric.buckets,
                                        metric.bucket_counts):
                        cum = c
                        lines.append(fmt(f"{pname}_bucket", lk, cum,
                                         ("le", f"{bound:g}")))
                    lines.append(fmt(f"{pname}_bucket", lk, metric.count,
                                     ("le", "+Inf")))
                    lines.append(fmt(f"{pname}_sum", lk, metric.sum))
                    lines.append(fmt(f"{pname}_count", lk, metric.count))
                else:
                    lines.append(fmt(pname, lk, metric.value))
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._kinds.clear()


#: the process-wide registry every instrumented module writes to.
REGISTRY = MetricsRegistry()


def counter(name: str, **labels: str) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels: str) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, buckets: Optional[Tuple[float, ...]] = None,
              **labels: str) -> Histogram:
    return REGISTRY.histogram(name, buckets=buckets, **labels)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def render_prometheus() -> str:
    return REGISTRY.render_prometheus()


def reset() -> None:
    REGISTRY.reset()
