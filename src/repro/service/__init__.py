"""Persistent offload-planning service (the daemon layer over the search
stack): versioned plan store, request coalescing, background GA refinement
with atomic hot-swap.  See ``docs/api.md`` ("The planning service")."""
from repro.service.service import (PlanService, ServedPlan, ServiceConfig,
                                   ServiceStats)
from repro.service.store import (PlanMismatchError, PlanRecord, PlanStore,
                                 env_matches, environment_fingerprint,
                                 record_from_result)

__all__ = ["PlanService", "ServedPlan", "ServiceConfig", "ServiceStats",
           "PlanMismatchError", "PlanRecord", "PlanStore",
           "env_matches", "environment_fingerprint", "record_from_result"]
