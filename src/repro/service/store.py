"""PlanStore: versioned, persistent offload-plan artifacts keyed by
``search_fingerprint``.

The paper's environment-adaptive framing is that code is committed once and
the *environment* keeps adapting it — so a winning offload pattern must
outlive the process that searched for it.  The store is a single
``plan_store.jsonl`` journal (the shared flock/fsync code path from
:mod:`repro.core.journal` — the same one the measurement journals use), one
record per deployed plan *version*:

* the **chromosome** (``bits``) plus the gene-site region names and the
  destination alphabet it was coded against — enough to re-apply the plan
  through any frontend, and enough to *refuse* to (a stored plan only fits
  a program whose coding matches);
* the **measured evidence** (best / baseline seconds, verified flag) the
  refinement loop compares against before hot-swapping;
* an optional self-contained **payload** — for the module frontend the
  whole :class:`~repro.models.plan.ExecPlan` as plain JSON, so
  ``rehydrate`` (and ``Server.from_store``) can reconstruct the artifact
  with *zero* frontend work: no graph build, no search, no measurement.

Versions only grow: ``put`` assigns ``head_version + 1`` under the journal
lock, rollback re-appends an older version's content as a *new* version
(history is never rewritten), and compaction keeps the newest
``history_depth`` versions per fingerprint.  Appends are fsync'd — losing a
measurement re-measures, but losing a deployed plan would re-search, so the
store alone pays for durability.
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import os

from repro.core.journal import Journal, newest_per_key
from repro.core.offload import OffloadResult, Offloader, PlanContext

__all__ = ["PlanRecord", "PlanStore", "PlanMismatchError",
           "environment_fingerprint", "env_matches", "record_from_result"]

PLAN_STORE_FILE = "plan_store.jsonl"


def environment_fingerprint() -> dict:
    """The hardware/runtime identity a plan's measurements are valid on:
    device kind/count, host cpu count, jax version.  Plans embed measured
    times from one machine; a warm load elsewhere must re-verify instead of
    blindly serving them (cross-host plan-reuse fix).  Returns ``{}`` when
    jax is unavailable — and an empty env always *mismatches*, because an
    unknown environment is exactly the unsafe case."""
    try:
        import jax
        devs = jax.devices()
        return {
            "device_kind": devs[0].device_kind if devs else "",
            "device_count": len(devs),
            "cpu_count": int(os.cpu_count() or 0),
            "jax_version": jax.__version__,
        }
    except Exception:  # noqa: BLE001 — no jax / no backend: unknown env
        return {}


def env_matches(recorded: dict, current: Optional[dict] = None) -> bool:
    """True when a stored plan's environment fingerprint matches the host we
    are about to serve it on.  A record with no env (pre-PR 9, or captured
    where jax was absent) never matches — those are the blind-reuse records
    this check exists to catch."""
    if not recorded:
        return False
    cur = environment_fingerprint() if current is None else current
    if not cur:
        return False
    keys = ("device_kind", "device_count", "cpu_count", "jax_version")
    return all(recorded.get(k) == cur.get(k) for k in keys)


class PlanMismatchError(ValueError):
    """A stored plan does not fit the program it was asked to drive: the
    fingerprint, gene sites, or destination alphabet disagree."""


@dataclass(frozen=True)
class PlanRecord:
    """One deployed plan version — the store's JSONL schema, 1:1."""

    fingerprint: str                  # search_fingerprint of the program
    frontend: str
    version: int                      # 1-based, monotone per fingerprint
    bits: tuple                       # winning chromosome
    sites: tuple                      # gene region names, gene order
    destinations: tuple               # alphabet the bits index into
    pattern: dict                     # region -> implementation (decoded)
    best_time_s: float                # measured winner (inf if unmeasured)
    baseline_time_s: float            # measured all-reference program
    verified: bool                    # measured + output-verified search
    source: str = ""                  # graph.source_name, for humans
    payload: dict = field(default_factory=dict)   # self-contained artifact
                                      # bits, e.g. {"exec_plan": {...}}
    meta: dict = field(default_factory=dict)      # provenance (free-form)
    ts: float = 0.0                   # append time (epoch seconds)
    env: dict = field(default_factory=dict)       # environment fingerprint
                                      # the measurements were taken on
                                      # (environment_fingerprint()); empty
                                      # = unknown host, treated as mismatch
    front: tuple = ()                 # Pareto front of the producing search:
                                      # dicts of {bits, latency_s, energy_j,
                                      # transfer_bytes} per non-dominated
                                      # pattern — lets the service swap
                                      # operating points without a search

    @property
    def speedup(self) -> float:
        if not (math.isfinite(self.best_time_s) and self.best_time_s > 0
                and math.isfinite(self.baseline_time_s)):
            return float("nan")
        return self.baseline_time_s / self.best_time_s

    def mesh_destinations(self) -> dict:
        """region -> :class:`~repro.core.genes.MeshDestination` for every
        gene the stored winner placed on a mesh.  Destinations are wire
        names (Destination v2), so mesh placements round-trip through the
        JSONL schema with no extra fields — this just parses them back."""
        from repro.core.genes import MeshDestination, get_destination

        out = {}
        for region, v in zip(self.sites, self.bits):
            idx = int(v)
            if 0 <= idx < len(self.destinations):
                dest = get_destination(self.destinations[idx])
                if isinstance(dest, MeshDestination):
                    out[region] = dest
        return out

    def to_json(self) -> dict:
        rec = dataclasses.asdict(self)
        rec["bits"] = [int(v) for v in self.bits]
        rec["sites"] = list(self.sites)
        rec["destinations"] = list(self.destinations)
        rec["best_time_s"] = self.best_time_s \
            if math.isfinite(self.best_time_s) else None
        rec["baseline_time_s"] = self.baseline_time_s \
            if math.isfinite(self.baseline_time_s) else None
        rec["front"] = [dict(p, bits=[int(v) for v in p.get("bits", ())])
                        for p in self.front]
        return rec

    @classmethod
    def from_json(cls, rec: dict) -> "PlanRecord":
        def _t(v):
            return float("inf") if v is None else float(v)
        return cls(
            fingerprint=str(rec["fingerprint"]),
            frontend=str(rec.get("frontend", "")),
            version=int(rec.get("version", 1)),
            bits=tuple(int(v) for v in rec.get("bits", ())),
            sites=tuple(rec.get("sites", ())),
            destinations=tuple(rec.get("destinations", ())),
            pattern=dict(rec.get("pattern") or {}),
            best_time_s=_t(rec.get("best_time_s")),
            baseline_time_s=_t(rec.get("baseline_time_s")),
            verified=bool(rec.get("verified", False)),
            source=str(rec.get("source", "")),
            payload=dict(rec.get("payload") or {}),
            meta=dict(rec.get("meta") or {}),
            ts=float(rec.get("ts") or 0.0),
            env=dict(rec.get("env") or {}),
            front=tuple(dict(p, bits=tuple(int(v)
                                           for v in p.get("bits", ())))
                        for p in rec.get("front") or ()))


def _json_safe(value: Any) -> Any:
    if isinstance(value, (str, int, float, bool)) or value is None:
        return value
    return str(value)


def record_from_result(res: OffloadResult, fingerprint: str,
                       meta: Optional[dict] = None) -> PlanRecord:
    """Distill an :class:`OffloadResult` into a storable plan record.

    The artifact itself is only embedded when it is self-contained plain
    data (the module frontend's :class:`ExecPlan`); live artifacts
    (``SubstitutedCallable``, ``PyOffloadArtifact``) hold compiled closures
    and are re-derived from the bits on load instead.
    """
    from repro.models.plan import ExecPlan

    payload: dict = {}
    if isinstance(res.artifact, ExecPlan):
        # only the primitive knobs travel; structural class constants that
        # leak in as annotated fields (the OFFLOAD_SITES table) are part of
        # the code's ABI and must come from the class on rehydration
        payload["exec_plan"] = {
            k: v for k, v in dataclasses.asdict(res.artifact).items()
            if isinstance(v, (str, int, float, bool)) or v is None}
    return PlanRecord(
        fingerprint=fingerprint,
        frontend=res.frontend,
        version=0,                      # assigned by PlanStore.put
        bits=tuple(int(v) for v in res.best.bits),
        sites=tuple(s.region for s in res.coding.sites),
        destinations=tuple(res.coding.destinations),
        pattern={str(k): _json_safe(v) for k, v in res.pattern.items()},
        best_time_s=float(res.best.time_s),
        baseline_time_s=float(res.baseline.time_s),
        verified=bool(res.verification.get("verified", False)),
        source=res.graph.source_name,
        payload=payload,
        meta=dict(meta or {}),
        env=environment_fingerprint(),
        front=tuple(res.front_summary()))


class PlanStore:
    """Versioned plan persistence over one fsync'd journal."""

    def __init__(self, store_dir: str, history_depth: int = 8,
                 max_records: int = 512):
        os.makedirs(store_dir, exist_ok=True)
        self.dir = store_dir
        self.history_depth = max(1, int(history_depth))
        self.max_records = max(1, int(max_records))
        self._journal = Journal(os.path.join(store_dir, PLAN_STORE_FILE),
                                fsync=True)

    # -- reads ---------------------------------------------------------------

    def _records(self) -> list[PlanRecord]:
        out = []
        for rec in self._journal.records():
            try:
                out.append(PlanRecord.from_json(rec))
            except (KeyError, TypeError, ValueError):
                continue  # foreign line
        return out

    def fingerprints(self) -> tuple[str, ...]:
        seen: dict[str, None] = {}
        for rec in self._records():
            seen.setdefault(rec.fingerprint, None)
        return tuple(seen)

    def history(self, fingerprint: str) -> list[PlanRecord]:
        """Every surviving version for a fingerprint, oldest -> newest."""
        recs = [r for r in self._records() if r.fingerprint == fingerprint]
        recs.sort(key=lambda r: r.version)
        return recs

    def load(self, fingerprint: str) -> Optional[PlanRecord]:
        """Newest stored version for a fingerprint, or None (cold)."""
        hist = self.history(fingerprint)
        return hist[-1] if hist else None

    # -- writes --------------------------------------------------------------

    def put(self, record: PlanRecord) -> PlanRecord:
        """Append as a new version (``head + 1``, assigned under the journal
        lock so concurrent writers can't mint the same version)."""
        with self._journal.lock():
            head = 0
            for rec in self._journal.records():
                if rec.get("fingerprint") == record.fingerprint:
                    head = max(head, int(rec.get("version", 0)))
            record = dataclasses.replace(record, version=head + 1,
                                         ts=time.time())
            self._journal.append([record.to_json()], locked=False)
        self._journal.compact(
            lambda recs: newest_per_key(
                recs, key=lambda r: r.get("fingerprint"),
                per_key=self.history_depth, max_records=self.max_records),
            threshold=2 * self.max_records)
        return record

    def evict_stale(self, max_age_s: float, now: Optional[float] = None,
                    keep: Any = ()) -> tuple[str, ...]:
        """TTL sweep: drop every fingerprint whose *newest* stored version
        is older than ``now - max_age_s`` (the whole history goes with it —
        a retired program's stale v1 is as dead as its stale v5).
        Fingerprints in ``keep`` (the service passes its deployed and
        in-flight ones) are never evicted.  Runs read + rewrite under the
        journal lock so a concurrent ``put`` can't vanish mid-sweep.
        Returns the evicted fingerprints."""
        now = time.time() if now is None else float(now)
        cutoff = now - float(max_age_s)
        keep = set(keep)
        with self._journal.lock():
            recs = self._journal.records()
            newest: dict[str, float] = {}
            for rec in recs:
                fp = rec.get("fingerprint")
                if fp:
                    newest[fp] = max(newest.get(fp, 0.0),
                                     float(rec.get("ts") or 0.0))
            stale = {fp for fp, ts in newest.items()
                     if fp not in keep and ts < cutoff}
            if not stale:
                return ()
            self._journal.rewrite(
                [r for r in recs if r.get("fingerprint") not in stale],
                locked=False)
        return tuple(sorted(stale))

    def rollback(self, fingerprint: str) -> PlanRecord:
        """Re-deploy the previous surviving version by appending its content
        as a *new* head version (history is append-only — rolling back is a
        forward move)."""
        hist = self.history(fingerprint)
        if len(hist) < 2:
            raise LookupError(
                f"no earlier version to roll back to for {fingerprint!r}")
        prev = hist[-2]
        return self.put(dataclasses.replace(
            prev, meta={**prev.meta, "rolled_back_from": hist[-1].version}))

    # -- artifact rehydration (the thin fast path) ---------------------------

    def check(self, record: PlanRecord, ctx: PlanContext) -> None:
        """A stored plan only fits a program whose search coding matches."""
        if record.fingerprint != ctx.fingerprint:
            raise PlanMismatchError(
                f"stored plan is for fingerprint {record.fingerprint!r}, "
                f"target prepared as {ctx.fingerprint!r}")
        if record.sites != ctx.sites \
                or record.destinations != ctx.coding.destinations:
            raise PlanMismatchError(
                "stored plan's gene sites/destinations do not match the "
                "prepared target (same fingerprint but incompatible coding "
                "— stale store?)")

    def rehydrate(self, record: PlanRecord, target: Any = None,
                  inputs: Optional[dict] = None,
                  config: Any = None) -> Any:
        """Reconstruct the plan's artifact without any search.

        Self-contained payloads (``exec_plan``) come straight off the JSON —
        zero frontend work.  Everything else replays the search-free half of
        the pipeline: ``Offloader.prepare(target)`` (which must fingerprint
        identically, checked) then ``Offloader.apply`` with the stored bits.
        """
        from repro.models.plan import ExecPlan

        if "exec_plan" in record.payload:
            return ExecPlan(**record.payload["exec_plan"])
        if target is None:
            raise ValueError(
                "stored plan has no self-contained payload; pass the "
                "original target (and inputs/config) to rebuild its artifact")
        off = Offloader(config) if config is not None else Offloader()
        ctx = off.prepare(target, inputs)
        self.check(record, ctx)
        return off.apply(ctx, record.bits)
