"""PlanService: the persistent offload-planning daemon.

The library call ``Offloader.plan`` is one-shot: search, return, forget.
The paper's deployment story needs the opposite shape — many clients, one
long-lived planner whose plans persist and keep improving while serving
(ROADMAP: "Offload planning as a persistent service").  This module is that
daemon, three layers on top of the search stack:

**Admission + coalescing.**  ``submit(target)`` runs only the search-free
half of the pipeline (``Offloader.prepare``) to learn the request's
``search_fingerprint``, then routes: an already-deployed fingerprint is
served instantly; a fingerprint with a search in flight *joins* that search
(one future fans out to every waiter — the Evaluator's in-flight dedup
lifted a layer, from chromosomes to whole programs); a cold fingerprint is
admitted to the worker pool, where a plan-store hit becomes a warm artifact
load (no GA) and only a genuinely unknown program pays for a search.
Distinct fingerprints plan concurrently under the worker budget.

**Persistence.**  Every search's winner is written to the
:class:`~repro.service.store.PlanStore` under the service directory; the
GA's measurement journals, surrogate fits and seed bank live in a cache
directory beside it (the service forces ``GAConfig.cache_dir`` there), so a
restarted service warm-loads yesterday's plans and a refinement search
re-reads yesterday's measurements.

**Background refinement + hot-swap.**  ``refine_once(fingerprint)`` resumes
the GA on a deployed program — seeded with the deployed chromosome, keyed to
the same measurement journal (persisted measurements replay for free, the
journal-fitted surrogate screens) — and, only when the new winner measures
*strictly* better than the deployed plan's recorded time, atomically
hot-swaps it: the served plan is one immutable :class:`ServedPlan` published
by a single reference assignment, so a concurrent reader sees the old plan
or the new plan, never a torn mix, and the previous plan is retained for
:meth:`PlanService.rollback`.  ``start_refinement`` runs that loop on a
daemon thread across all deployed fingerprints.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.frontends.registry import OffloadConfig
from repro.core.offload import Offloader, PlanContext
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.service.store import (PlanRecord, PlanStore, _json_safe,
                                 env_matches, record_from_result)

__all__ = ["PlanService", "ServedPlan", "ServiceConfig", "ServiceStats"]


@dataclass
class ServiceConfig:
    """Service-level knobs (per-request planning knobs stay in
    :class:`OffloadConfig`)."""

    workers: int = 2                  # concurrent searches, distinct
                                      # fingerprints only — same-fingerprint
                                      # requests always coalesce
    history_depth: int = 8            # store versions kept per fingerprint
    refine_interval_s: float = 30.0   # background loop sleep between sweeps
    refine_generations: Optional[int] = None   # GA generations per
                                      # refinement round (None = request's)
    refine_population: Optional[int] = None    # population override, ditto
    plan_ttl_s: Optional[float] = None  # plan-store TTL: the refinement
                                      # loop sweeps evict_stale(plan_ttl_s)
                                      # once per round (deployed/in-flight
                                      # fingerprints always spared); None
                                      # disables the sweep
    busy_hz: float = 1.0              # traffic threshold for
                                      # select_for_traffic: at/above it the
                                      # latency-optimal operating point is
                                      # deployed, below it energy-optimal


@dataclass
class ServiceStats:
    """Request accounting: how much planning work the service avoided."""

    requests: int = 0        # submit() calls
    live_hits: int = 0       # served from the in-memory deployed table
    coalesced: int = 0       # joined another request's in-flight admission
    warm_loads: int = 0      # plan-store hit: artifact load, no GA search
    searches: int = 0        # full GA searches actually run
    refinements: int = 0     # refinement rounds completed
    swaps: int = 0           # refinements that hot-swapped a better plan
    rollbacks: int = 0
    evictions: int = 0       # fingerprints dropped by the TTL sweep
    env_mismatches: int = 0  # warm loads refused because the stored plan
                             # was measured on different hardware (the
                             # cross-host reuse fix: re-measured instead)
    repoints: int = 0        # operating-point swaps served straight from
                             # the stored Pareto front (no search)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class ServedPlan:
    """One immutable deployed plan.  Hot-swap publishes a *new* instance by
    a single reference assignment — readers that grabbed this one keep a
    consistent (record, artifact) pair forever, which is the no-torn-plan
    guarantee."""

    fingerprint: str
    record: PlanRecord               # the persisted version backing this
    artifact: Any                    # frontend deliverable, ready to run
    warm: bool                       # True = loaded from store, no search

    @property
    def version(self) -> int:
        return self.record.version

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        a = self.artifact
        if callable(a):
            return a(*args, **kwargs)
        if hasattr(a, "run"):
            return a.run(*args, **kwargs)
        raise TypeError(
            f"artifact {type(a).__name__} is not directly runnable; read "
            f".artifact (e.g. hand an ExecPlan to runtime.serve.Server)")


@dataclass
class _Entry:
    """Mutable service-side state for one deployed fingerprint.  Only
    ``current`` is read on the hot path (single reference, atomically
    swapped); everything else is refinement bookkeeping."""

    current: ServedPlan
    ctx: PlanContext
    offloader: Offloader
    previous: Optional[ServedPlan] = None    # rollback target
    rounds: int = 0                          # refinement rounds run


class PlanService:
    """The planning daemon.  See module docstring for the three layers."""

    def __init__(self, store_dir: str,
                 config: Optional[OffloadConfig] = None,
                 service: Optional[ServiceConfig] = None):
        self.service_config = service or ServiceConfig()
        self.store = PlanStore(store_dir,
                               history_depth=self.service_config.history_depth)
        self.cache_dir = os.path.join(store_dir, "cache")
        self.config = self._with_cache(config or OffloadConfig())
        self.stats = ServiceStats()
        self._lock = threading.Lock()
        self._inflight: dict[str, Future] = {}
        self._entries: dict[str, _Entry] = {}
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, int(self.service_config.workers)),
            thread_name_prefix="plan-service")
        self._refine_stop = threading.Event()
        self._refine_thread: Optional[threading.Thread] = None

    def _with_cache(self, cfg: OffloadConfig) -> OffloadConfig:
        """Pin the GA's journals under the service directory so measurement
        history, surrogate fits, the seed bank and the plan store share one
        persistent home (a request's explicit cache_dir wins)."""
        if cfg.ga.cache_dir:
            return cfg
        return dataclasses.replace(
            cfg, ga=dataclasses.replace(cfg.ga, cache_dir=self.cache_dir))

    # -- admission + coalescing ----------------------------------------------

    def submit(self, target: Any, inputs: Optional[dict] = None,
               config: Optional[OffloadConfig] = None) -> "Future[ServedPlan]":
        """Admit a planning request; returns a future resolving to the
        deployed plan.  Prepare (no search) runs inline to fingerprint the
        request; the expensive path runs on the worker pool at most once per
        fingerprint regardless of how many clients ask."""
        cfg = self._with_cache(config) if config is not None else self.config
        off = Offloader(cfg)
        ctx = off.prepare(target, inputs)
        with self._lock:
            self.stats.requests += 1
            entry = self._entries.get(ctx.fingerprint)
            if entry is not None:
                self.stats.live_hits += 1
                fut: Future = Future()
                fut.set_result(entry.current)
                outcome = "live-hit"
            else:
                pending = self._inflight.get(ctx.fingerprint)
                if pending is not None:
                    self.stats.coalesced += 1
                    fut = pending
                    outcome = "coalesced"
                else:
                    fut = Future()
                    self._inflight[ctx.fingerprint] = fut
                    outcome = "cold"
        obs_metrics.counter("service.admission", outcome=outcome).inc()
        if outcome == "cold":
            self._pool.submit(self._admit, off, ctx, fut)
        return fut

    def plan(self, target: Any, inputs: Optional[dict] = None,
             config: Optional[OffloadConfig] = None) -> ServedPlan:
        """Blocking convenience wrapper around :meth:`submit`."""
        return self.submit(target, inputs, config).result()

    def _admit(self, off: Offloader, ctx: PlanContext, fut: Future) -> None:
        try:
            plan = self._load_or_search(off, ctx)
        except BaseException as e:  # noqa: BLE001 — fan the failure out to
            with self._lock:        # every coalesced waiter, then forget the
                self._inflight.pop(ctx.fingerprint, None)   # fingerprint so
            fut.set_exception(e)    # a later request can retry
            return
        with self._lock:
            self._entries[ctx.fingerprint] = _Entry(
                current=plan, ctx=ctx, offloader=off)
            self._inflight.pop(ctx.fingerprint, None)
        fut.set_result(plan)

    def _load_or_search(self, off: Offloader, ctx: PlanContext) -> ServedPlan:
        with obs_trace.maybe_tracing(ctx.config.trace), \
                obs_trace.span("service.admit", frontend=ctx.frontend,
                               fingerprint=ctx.fingerprint) as sp:
            rec = self.store.load(ctx.fingerprint)
            fits = (rec is not None and rec.sites == ctx.sites
                    and rec.destinations == ctx.coding.destinations)
            if fits and env_matches(rec.env):
                # warm path: stored plan fits this program AND was measured
                # on this hardware — pure artifact load
                if "exec_plan" in rec.payload:
                    artifact = self.store.rehydrate(rec)
                else:
                    artifact = off.apply(ctx, rec.bits)
                with self._lock:
                    self.stats.warm_loads += 1
                obs_metrics.counter("service.warm_loads").inc()
                sp.set(path="warm-load", version=rec.version)
                return ServedPlan(ctx.fingerprint, rec, artifact, warm=True)
            seeds: list[tuple] = []
            origin = "cold-search"
            if fits:
                # cross-host reuse fix: the chromosome fits but the record's
                # measurements came from different hardware (or an unknown
                # one) — its times are not evidence here.  Re-verify by
                # re-measuring, seeded with the foreign winner so a plan
                # that *does* transfer is found in generation 0
                with self._lock:
                    self.stats.env_mismatches += 1
                obs_metrics.counter("service.env_mismatch").inc()
                sp.set(env_mismatch=True)
                origin = "env-remeasure"
                seeds = [rec.bits]
            res = off.search(ctx, extra_seeds=seeds)
            with self._lock:
                self.stats.searches += 1
            obs_metrics.counter("service.searches").inc()
            stored = self.store.put(record_from_result(
                res, ctx.fingerprint,
                meta={"origin": origin,
                      "evaluations": res.ga.evaluations}))
            sp.set(path=origin, version=stored.version)
            return ServedPlan(ctx.fingerprint, stored, res.artifact,
                              warm=False)

    # -- serving -------------------------------------------------------------

    def current(self, fingerprint: str) -> ServedPlan:
        """The deployed plan (an immutable snapshot — safe to use across a
        concurrent hot-swap)."""
        with self._lock:
            entry = self._entries.get(fingerprint)
        if entry is None:
            raise LookupError(f"fingerprint {fingerprint!r} is not deployed "
                              f"in this service (submit a target first)")
        return entry.current

    def endpoint(self, fingerprint: str) -> Callable[..., Any]:
        """A callable bound to the fingerprint, not the plan: every call
        snapshots ``current`` once, so calls always run a complete plan and
        pick up a hot-swap on their next invocation."""
        self.current(fingerprint)          # fail fast on unknown fingerprint

        def call(*args: Any, **kwargs: Any) -> Any:
            return self.current(fingerprint)(*args, **kwargs)

        return call

    def fingerprints(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(self._entries)

    # -- operating points (the Pareto front, served) -------------------------

    #: objective name -> per-point field in PlanRecord.front
    _FRONT_FIELDS = {"latency": "latency_s", "energy": "energy_j",
                     "transfer": "transfer_bytes"}

    def select_operating_point(self, fingerprint: str,
                               objective: str = "latency") -> ServedPlan:
        """Deploy the stored Pareto-front point optimal on one axis —
        **without a new search**: the front was measured when the plan was,
        so swapping between its points is a pure artifact re-apply plus a
        store append (ties break toward lower latency; a record with no
        front, e.g. from a single-objective search, keeps the current
        plan).  The swap publishes like a refinement hot-swap: immutable
        plan, single reference assignment, previous retained for rollback.
        """
        with self._lock:
            entry = self._entries.get(fingerprint)
        if entry is None:
            raise LookupError(f"fingerprint {fingerprint!r} is not deployed")
        try:
            axis = self._FRONT_FIELDS[objective]
        except KeyError:
            raise ValueError(
                f"unknown objective {objective!r}; known: "
                f"{tuple(self._FRONT_FIELDS)}") from None
        rec = entry.current.record
        front = [p for p in rec.front if p.get("bits")]
        if not front:
            return entry.current
        inf = float("inf")
        point = min(front, key=lambda p: (float(p.get(axis, inf)),
                                          float(p.get("latency_s", inf))))
        bits = tuple(int(v) for v in point["bits"])
        if bits == tuple(rec.bits):
            return entry.current           # already at that operating point
        artifact = entry.offloader.apply(entry.ctx, bits)
        payload: dict = {}
        from repro.models.plan import ExecPlan
        if isinstance(artifact, ExecPlan):
            payload["exec_plan"] = {
                k: v for k, v in dataclasses.asdict(artifact).items()
                if isinstance(v, (str, int, float, bool)) or v is None}
        pattern = {str(k): _json_safe(v)
                   for k, v in entry.ctx.coding.decode(bits).items()}
        stored = self.store.put(dataclasses.replace(
            rec, bits=bits, pattern=pattern, payload=payload,
            best_time_s=float(point.get("latency_s", inf)),
            meta={**rec.meta, "origin": "operating-point",
                  "objective": objective, "repointed_from": rec.version}))
        new_plan = ServedPlan(fingerprint, stored, artifact, warm=True)
        with self._lock:
            entry.previous = entry.current
            entry.current = new_plan
            self.stats.repoints += 1
        obs_metrics.counter("service.repoints", objective=objective).inc()
        return new_plan

    def select_for_traffic(self, fingerprint: str, traffic_hz: float,
                           busy_hz: Optional[float] = None) -> ServedPlan:
        """Traffic-level policy over :meth:`select_operating_point`: under
        load (>= ``busy_hz`` requests/s, default from ServiceConfig) serve
        the latency-optimal front point; idle, the energy-optimal one.
        Feed it :meth:`repro.runtime.serve.Server.traffic_hz`."""
        thr = self.service_config.busy_hz if busy_hz is None \
            else float(busy_hz)
        objective = "latency" if float(traffic_hz) >= thr else "energy"
        return self.select_operating_point(fingerprint, objective)

    # -- store hygiene -------------------------------------------------------

    def evict_stale(self, max_age_s: float,
                    now: Optional[float] = None) -> tuple[str, ...]:
        """TTL sweep over the plan store: drop every fingerprint whose
        newest stored version is older than ``max_age_s`` seconds.  Plans
        that are currently deployed or mid-admission are never evicted
        (they are the ``keep`` set) — the sweep retires fingerprints no
        live client can be holding.  Returns the evicted fingerprints."""
        with self._lock:
            keep = set(self._entries) | set(self._inflight)
        evicted = self.store.evict_stale(max_age_s, now=now, keep=keep)
        if evicted:
            with self._lock:
                self.stats.evictions += len(evicted)
            obs_metrics.counter("service.evictions").inc(len(evicted))
        return evicted

    # -- background refinement + hot-swap ------------------------------------

    def refine_once(self, fingerprint: str) -> bool:
        """Resume the GA on a deployed fingerprint and hot-swap the result
        iff it measured strictly better than the deployed plan.

        The search is seeded with the deployed chromosome and keyed to the
        same measurement journal (``cache_dir`` is pinned), so persisted
        measurements replay for free and the journal-fitted surrogate can
        screen.  Returns True when a swap happened.
        """
        with self._lock:
            entry = self._entries.get(fingerprint)
        if entry is None:
            raise LookupError(f"fingerprint {fingerprint!r} is not deployed")
        svc = self.service_config
        entry.rounds += 1
        ga = entry.ctx.config.ga
        overrides: dict = {
            # a fresh seed per round: refinement explores, it doesn't replay
            "seed": ga.seed + entry.rounds,
        }
        if svc.refine_generations is not None:
            overrides["generations"] = int(svc.refine_generations)
        if svc.refine_population is not None:
            overrides["population"] = int(svc.refine_population)
        res = entry.offloader.search(
            entry.ctx, ga=dataclasses.replace(ga, **overrides),
            extra_seeds=[entry.current.record.bits])
        with self._lock:
            self.stats.refinements += 1
        obs_metrics.counter("service.refinements").inc()
        deployed = entry.current.record
        better = (res.best.valid
                  and res.best.time_s < deployed.best_time_s
                  and tuple(int(v) for v in res.best.bits) != deployed.bits)
        if not better:
            return False
        stored = self.store.put(record_from_result(
            res, fingerprint,
            meta={"origin": "refinement", "round": entry.rounds,
                  "replaced_version": deployed.version,
                  "evaluations": res.ga.evaluations}))
        new_plan = ServedPlan(fingerprint, stored, res.artifact, warm=False)
        with self._lock:
            entry.previous = entry.current
            entry.current = new_plan       # the atomic hot-swap: one
            self.stats.swaps += 1          # reference assignment publishes
        obs_metrics.counter("service.swaps").inc()
        return True                        # a complete immutable plan

    def rollback(self, fingerprint: str) -> ServedPlan:
        """Re-deploy the plan the last hot-swap replaced (and append it to
        the store as the new head version, so restarts agree)."""
        with self._lock:
            entry = self._entries.get(fingerprint)
            prev = entry.previous if entry is not None else None
        if entry is None:
            raise LookupError(f"fingerprint {fingerprint!r} is not deployed")
        if prev is None:
            raise LookupError(f"no previous plan retained for "
                              f"{fingerprint!r} — nothing to roll back to")
        stored = self.store.put(dataclasses.replace(
            prev.record,
            meta={**prev.record.meta,
                  "rolled_back_from": entry.current.version}))
        restored = ServedPlan(fingerprint, stored, prev.artifact,
                              warm=prev.warm)
        with self._lock:
            entry.previous = entry.current
            entry.current = restored
            self.stats.rollbacks += 1
        obs_metrics.counter("service.rollbacks").inc()
        return restored

    def start_refinement(self, interval_s: Optional[float] = None) -> None:
        """Run :meth:`refine_once` over all deployed fingerprints on a
        daemon thread, sleeping ``interval_s`` between sweeps."""
        sleep_s = self.service_config.refine_interval_s \
            if interval_s is None else float(interval_s)
        if self._refine_thread is not None and self._refine_thread.is_alive():
            return
        self._refine_stop.clear()

        def loop() -> None:
            while not self._refine_stop.is_set():
                for fp in self.fingerprints():
                    if self._refine_stop.is_set():
                        return
                    try:
                        self.refine_once(fp)
                    except Exception:  # noqa: BLE001 — one fingerprint's
                        continue       # bad round must not kill the loop
                if self.service_config.plan_ttl_s is not None:
                    # periodic TTL sweep (the evict_stale wiring): deployed
                    # and in-flight fingerprints are spared by the method
                    try:
                        self.evict_stale(self.service_config.plan_ttl_s)
                    except Exception:  # noqa: BLE001 — hygiene must not
                        pass           # kill refinement
                self._refine_stop.wait(sleep_s)

        self._refine_thread = threading.Thread(
            target=loop, name="plan-refine", daemon=True)
        self._refine_thread.start()

    def stop_refinement(self, timeout_s: float = 10.0) -> None:
        self._refine_stop.set()
        t = self._refine_thread
        if t is not None:
            t.join(timeout=timeout_s)
        self._refine_thread = None

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        self.stop_refinement()
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "PlanService":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
