"""Pallas TPU flash attention (causal / full, GQA via index-mapped KV heads).

TPU-native adaptation of the paper's "function-block offload" target: the
pattern DB replaces the softmax-attention block with this kernel on TPU
(the chunked-jnp twin `models/attention.attend_chunked` is the portable
fallback the dry-run lowers).

Tiling: grid = (B*Hq, nQ, nK) with the KV axis sequential ("arbitrary");
online-softmax stats (m, l) and the output accumulator live in VMEM scratch
that persists across the KV axis.  Causal blocks strictly above the diagonal
are skipped with `pl.when` — on real TPU this prunes ~half the MXU work,
which the pure-XLA fallback cannot do (see DESIGN.md §Hardware-adaptation).

Block sizes must divide the (padded) sequence lengths; `ops.flash_attention`
pads and picks MXU-aligned blocks (multiples of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, blk_q: int, blk_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks entirely above the diagonal
    live = (ki * blk_k <= qi * blk_q + blk_q - 1) if causal else (ki >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (blk_q, D)
        k = k_ref[0].astype(jnp.float32)          # (blk_k, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (blk_q, blk_k)
        if causal:
            rows = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]                        # (blk_q, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                     # (blk_q, blk_k)
        corr = jnp.exp(m_prev - m_new)             # (blk_q, 1)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)    # (blk_q, D)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-37)).astype(o_ref.dtype)


def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool, scale: float, blk_q: int = 128,
                       blk_k: int = 128, group: int = 1,
                       interpret: bool = True) -> jax.Array:
    """q: (B*Hq, Sq, D); k, v: (B*Hkv, Sk, D); Hq = Hkv * group.

    Returns (B*Hq, Sq, D).  Sequence lengths must be multiples of the block
    sizes (ops.py pads).
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % blk_q == 0 and sk % blk_k == 0, (sq, sk, blk_q, blk_k)
    nq, nk = sq // blk_q, sk // blk_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, blk_q=blk_q, blk_k=blk_k)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, qi, ki, g=group: (b // g, ki, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, qi, ki, g=group: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
