"""Accelerated kernels + the implementation-variant registry.

``ops`` holds the jit'd Pallas kernel wrappers, ``ref`` the pure-jnp
oracles, and ``registry`` maps pattern-DB entries to executable variants
(``fused_jnp`` / ``pallas``) for the jaxpr substitution engine.  Kernel
modules import lazily through the registry's bind functions, so importing
this package stays cheap.
"""
from repro.kernels.registry import (CallSite, KernelRegistry, Variant,
                                    VariantUnavailable, auto_variant_order,
                                    default_registry)

__all__ = [
    "CallSite", "KernelRegistry", "Variant", "VariantUnavailable",
    "auto_variant_order", "default_registry",
]
