"""Pallas TPU kernel for the RWKV-6 WKV recurrence (data-dependent decay).

Per head with state S in R^{DxD}:
    y_t = r_t^T (S_{t-1} + (u * k_t) outer v_t)
    S_t = diag(w_t) S_{t-1} + k_t outer v_t

Grid: (B*H, time_chunks), time sequential; the DxD state persists in VMEM
scratch.  Within a chunk (length C) the recurrence is evaluated in closed
form with log-space decay ratios (all exponents <= 0, numerically safe):

    cs_t   = cumsum(log w) (inclusive),  cs'_t = cs_t - log w_t (exclusive)
    inter  = (r_t * exp(cs'_t)) @ S_in
    intra  = tril_{-1}[ (r_t * exp(cs'_t)) (k_s * exp(-cs_s))^T ] @ V
    bonus  = (r_t . u . k_t) v_t
    S_out  = exp(cs_C) S_in + (K * exp(cs_C - cs))^T V

D=64 and C=64 give MXU-shaped (64,64) matmuls; head dim must equal the
block D (ops.py asserts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)    # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)  # (C, D), <= 0
    u = u_ref[0].astype(jnp.float32)    # (1, D)
    s_in = s_scr[...]                   # (D, D)

    cs = jnp.cumsum(lw, axis=0)         # inclusive
    cs_prev = cs - lw                   # exclusive
    r_dec = r * jnp.exp(cs_prev)        # (C, D)
    k_dec = k * jnp.exp(-cs)            # (C, D)

    y_inter = jax.lax.dot_general(
        r_dec, s_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    scores = jax.lax.dot_general(
        r_dec, k_dec, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(cols < rows, scores, 0.0)   # strictly lower triangle
    y_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_diag = jnp.sum(r * u * k, axis=-1, keepdims=True) * v
    o_ref[0] = (y_inter + y_intra + y_diag).astype(o_ref.dtype)

    cs_last = cs[-1:, :]                # (1, D)
    k_tail = k * jnp.exp(cs_last - cs)  # (C, D)
    s_new = jnp.exp(cs_last[0])[:, None] * s_in + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = s_new


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
         u: jax.Array, *, chunk: int = 64, interpret: bool = True) -> jax.Array:
    """r/k/v/log_w: (BH, S, D); u: (BH, 1, D).  Returns y: (BH, S, D) fp32."""
    bh, s, d = r.shape
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    specs = [pl.BlockSpec((1, chunk, d), lambda b, ci: (b, ci, 0))] * 4
    specs.append(pl.BlockSpec((1, 1, d), lambda b, ci: (b, 0, 0)))
    return pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=specs,
        out_specs=pl.BlockSpec((1, chunk, d), lambda b, ci: (b, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(r, k, v, log_w, u)
