"""Implementation-variant registry: pattern-DB entries -> executable variants.

The paper's final step replaces matched functional blocks with library
implementations and measures the *converted* program.  This registry is the
library side of that step: each pattern-DB record name maps to an ordered set
of :class:`Variant`s — ``fused_jnp`` (a fused jax.numpy rewrite) and
``pallas`` (the Pallas kernel wrappers in :mod:`repro.kernels.ops`) — that
the jaxpr substitution engine (:mod:`repro.core.substitution`) can splice
into a traced program in place of the matched region.

A variant *binds* to a concrete call site: ``Variant.bind(site)`` inspects
the site's abstract values (shapes, dtypes, scan structure, which outputs
are actually used) and either returns an adapter callable whose outputs
match the site's output avals, or raises :class:`VariantUnavailable` with
the reason.  Binding is the availability predicate — anything a variant
cannot prove it handles from the avals falls back to the reference path,
and anything it handles *wrongly* (e.g. a non-causal attention matched to
the causal kernels) is caught by the per-measurement verifier, which is the
paper's PCAST flow doing its job.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp


class VariantUnavailable(Exception):
    """A variant's availability predicate rejected the call site."""


@dataclass(frozen=True)
class CallSite:
    """What a variant binds against: one matched region, concretized.

    ``kind`` is how the region appears in the jaxpr — a ``span`` of simple
    equations, a single closed ``call`` (pjit), or a ``scan``.  ``in_avals``
    / ``out_avals`` follow the jaxpr equation/span order (for scans:
    ``[consts..., carry..., xs...]`` in, ``[carry..., ys...]`` out).
    ``out_used[i]`` is False when output ``i`` is dropped by the program —
    a variant that cannot produce an *unused* output may still bind.
    """

    pattern: str
    kind: str                          # "span" | "call" | "scan"
    in_avals: tuple
    out_avals: tuple
    out_used: tuple
    params: Mapping = field(default_factory=dict)   # scan: num_consts,
                                                    # num_carry, reverse
    backend: str = "cpu"
    eqns: tuple = ()                   # span sites: the intercepted
                                       # equations, for structural operand-
                                       # role inference (jaxpr input order
                                       # is first-use order, NOT call order)
    in_vars: tuple = ()                # span sites: vars aligned w/ in_avals


@dataclass(frozen=True)
class Variant:
    """One executable implementation of a pattern."""

    pattern: str                       # pattern-DB record name
    name: str                          # "fused_jnp" | "pallas" | custom
    bind: Callable[[CallSite], Callable[..., tuple]]
    description: str = ""

    def available(self, site: CallSite) -> bool:
        try:
            self.bind(site)
            return True
        except VariantUnavailable:
            return False


class KernelRegistry:
    """Ordered pattern -> variants store (registration order is preserved;
    it defines the gene-alphabet implementation order ``("ref",) + names``)."""

    def __init__(self) -> None:
        self._by_pattern: dict[str, dict[str, Variant]] = {}

    def register(self, variant: Variant, replace: bool = False) -> None:
        slot = self._by_pattern.setdefault(variant.pattern, {})
        if variant.name in slot and not replace:
            raise ValueError(f"variant {variant.pattern}:{variant.name} "
                             f"already registered")
        slot[variant.name] = variant

    def patterns(self) -> tuple[str, ...]:
        return tuple(self._by_pattern)

    def variants_for(self, pattern: str) -> tuple[Variant, ...]:
        return tuple(self._by_pattern.get(pattern, {}).values())

    def variant_names(self, pattern: str) -> tuple[str, ...]:
        return tuple(self._by_pattern.get(pattern, {}))

    def get(self, pattern: str, name: str) -> Variant:
        try:
            return self._by_pattern[pattern][name]
        except KeyError:
            raise KeyError(
                f"unknown variant {pattern}:{name}; registered for "
                f"{pattern!r}: {self.variant_names(pattern)}") from None


# ---------------------------------------------------------------------------
# binding helpers
# ---------------------------------------------------------------------------


def _require(cond: bool, why: str) -> None:
    if not cond:
        raise VariantUnavailable(why)


def _floats(avals) -> bool:
    return all(jnp.issubdtype(a.dtype, jnp.floating) for a in avals)


def _cast(x: jax.Array, aval) -> jax.Array:
    return x.astype(aval.dtype) if x.dtype != aval.dtype else x


# ---------------------------------------------------------------------------
# softmax_attention: causal attention block (span or closed call)
# ---------------------------------------------------------------------------


def _attention_roles(site: CallSite) -> tuple:
    """Indices of (q, k, v) among the site inputs.

    A span's inputs arrive in trace first-use order — ``q @ k.T`` traces
    ``transpose(k)`` before touching ``q``, so positional binding would
    swap the operands.  Trace each dot_general operand back to the unique
    span input it derives from: the first dot's lhs is q, its rhs is k,
    the last dot's rhs is v.  Closed calls keep the function's signature
    order (the name-matched ``attention(q, k, v)`` convention).
    """
    if site.kind != "span" or not site.eqns:
        return (0, 1, 2)
    dots = [e for e in site.eqns if e.primitive.name == "dot_general"]
    _require(len(dots) >= 2, "attention span needs score and output matmuls")
    producer = {o: e for e in site.eqns for o in e.outvars}
    inputs = set(site.in_vars)

    def sole_root(v, what: str):
        out, stack, seen = set(), [v], set()
        while stack:
            x = stack.pop()
            if not hasattr(x, "count") or x in seen:
                continue
            seen.add(x)
            if x in inputs:
                out.add(x)
            elif x in producer:
                stack.extend(producer[x].invars)
        _require(len(out) == 1, f"cannot identify the {what} operand")
        return next(iter(out))

    qv = sole_root(dots[0].invars[0], "q")
    kv = sole_root(dots[0].invars[1], "k")
    vv = sole_root(dots[-1].invars[1], "v")
    _require(len({qv, kv, vv}) == 3, "attention operands are entangled")
    index = {var: i for i, var in enumerate(site.in_vars)}
    return (index[qv], index[kv], index[vv])


def _attention_site(site: CallSite):
    _require(site.kind in ("span", "call"),
             f"attention binds span/call sites, not {site.kind}")
    _require(len(site.in_avals) == 3, "attention needs exactly (q, k, v)")
    _require(sum(site.out_used) == 1 and len(site.out_avals) >= 1,
             "attention produces one used output")
    roles = _attention_roles(site)
    q, k, v = (site.in_avals[i] for i in roles)
    _require(_floats((q, k, v)), "attention needs floating inputs")
    _require(q.ndim == k.ndim == v.ndim, "q/k/v rank mismatch")
    _require(q.ndim in (2, 4), "attention supports (S,D) or (B,S,H,D)")
    _require(k.shape == v.shape, "k/v shape mismatch")
    _require(q.shape[-1] == k.shape[-1], "q/k head-dim mismatch")
    _require(q.shape[-1] <= 512, "head dim too large for the kernels")
    out = site.out_avals[list(site.out_used).index(True)]
    _require(out.shape == q.shape[:-1] + (v.shape[-1],),
             "output shape is not attention-like")
    if q.ndim == 4:
        _require(q.shape[2] % k.shape[2] == 0, "Hq must be a multiple of Hkv")
        _require(q.shape[0] == k.shape[0], "batch mismatch")
    return q, k, v, out, roles


def _bind_attention_fused(site: CallSite):
    from repro.kernels import ref

    q_av, k_av, v_av, out_av, roles = _attention_site(site)
    scale = 1.0 / math.sqrt(q_av.shape[-1])

    if q_av.ndim == 2:
        def fn(*xs):
            q, k, v = (xs[i] for i in roles)
            o = ref.flash_attention_ref(q[None], k[None], v[None],
                                        causal=True, scale=scale)[0]
            return (_cast(o, out_av),)
    else:
        b, _, hq, d = q_av.shape
        hkv = k_av.shape[2]

        def fn(*xs):
            q, k, v = (xs[i] for i in roles)
            qf = q.transpose(0, 2, 1, 3).reshape(b * hq, q.shape[1], d)
            kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, k.shape[1], d)
            vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, v.shape[1], d)
            o = ref.flash_attention_ref(qf, kf, vf, causal=True, scale=scale,
                                        group=hq // hkv)
            o = o.reshape(b, hq, q.shape[1], d).transpose(0, 2, 1, 3)
            return (_cast(o, out_av),)
    return fn


def _bind_attention_pallas(site: CallSite):
    from repro.kernels import ops

    q_av, k_av, v_av, out_av, roles = _attention_site(site)
    _require(q_av.shape[-1] >= 2, "pallas flash needs head dim >= 2")

    if q_av.ndim == 2:
        def fn(*xs):
            q, k, v = (xs[i] for i in roles)
            o = ops.flash_attention(q[None, :, None, :], k[None, :, None, :],
                                    v[None, :, None, :], causal=True)
            return (_cast(o[0, :, 0, :], out_av),)
    else:
        def fn(*xs):
            q, k, v = (xs[i] for i in roles)
            return (_cast(ops.flash_attention(q, k, v, causal=True), out_av),)
    return fn


# ---------------------------------------------------------------------------
# rmsnorm: (x, scale) -> normalized x, (1 + scale) weighting
# ---------------------------------------------------------------------------


def _rmsnorm_site(site: CallSite):
    _require(site.kind in ("span", "call"),
             f"rmsnorm binds span/call sites, not {site.kind}")
    _require(len(site.in_avals) == 2, "rmsnorm needs exactly (x, scale)")
    _require(sum(site.out_used) == 1, "rmsnorm produces one used output")
    a, b = site.in_avals
    x_av, s_av = (a, b) if a.ndim >= b.ndim else (b, a)
    swapped = x_av is b
    _require(_floats((x_av, s_av)), "rmsnorm needs floating inputs")
    _require(s_av.ndim == 1 and x_av.ndim >= 1, "scale must be rank 1")
    _require(x_av.shape[-1] == s_av.shape[0], "scale must match last dim")
    out = site.out_avals[list(site.out_used).index(True)]
    _require(out.shape == x_av.shape, "output must be x-shaped")
    return x_av, s_av, out, swapped


def _bind_rmsnorm_fused(site: CallSite):
    from repro.kernels import ref

    _, _, out_av, swapped = _rmsnorm_site(site)

    def fn(a, b):
        x, s = (b, a) if swapped else (a, b)
        return (_cast(ref.rmsnorm_ref(x, s), out_av),)
    return fn


def _bind_rmsnorm_pallas(site: CallSite):
    from repro.kernels import ops

    x_av, _, out_av, swapped = _rmsnorm_site(site)
    _require(x_av.ndim >= 2, "pallas rmsnorm needs a row dimension")

    def fn(a, b):
        x, s = (b, a) if swapped else (a, b)
        return (_cast(ops.rmsnorm(x, s), out_av),)
    return fn


# ---------------------------------------------------------------------------
# linear_recurrence: scan of h = exp(log_a) * h + b, ys = h
# ---------------------------------------------------------------------------


def _recurrence_site(site: CallSite):
    _require(site.kind == "scan", "linear_recurrence binds scan sites")
    _require(site.params.get("num_consts") == 0
             and site.params.get("num_carry") == 1,
             "expected scan(carry, (log_a, b))")
    _require(not site.params.get("reverse"), "reverse scan unsupported")
    _require(len(site.in_avals) == 3, "expected (h0, log_a, b)")
    _require(len(site.out_avals) == 2, "expected (h_final, ys) outputs")
    h0, la, b = site.in_avals
    _require(_floats((h0, la, b)), "needs floating inputs")
    _require(la.shape == b.shape and la.ndim in (2, 3),
             "xs must be equal-shaped (S,D) or (S,B,D)")
    _require(h0.shape == la.shape[1:], "carry must match one timestep")
    ys = site.out_avals[1]
    _require(ys.shape == la.shape, "ys must be xs-shaped")
    return h0, la, b, site.out_avals


def _recurrence_fn(site: CallSite, kernel: Callable):
    """Shared adapter: time-major scan xs -> the (B,S,D) kernels and back.

    ``kernel(log_a, b, h0) -> hs`` over batch-major (B,S,D); the final carry
    is served from ``hs[:, -1]`` (valid because the pattern's ys *is* the
    carry), so a downstream use of the scan's carry output still works.
    """
    h0_av, la_av, _, out_avals = _recurrence_site(site)
    batched = la_av.ndim == 3          # (S,B,D) time-major

    def fn(h0, la, b):
        if batched:
            la_b, b_b, h0_b = (la.transpose(1, 0, 2), b.transpose(1, 0, 2), h0)
        else:
            la_b, b_b, h0_b = la[None], b[None], h0[None]
        hs = kernel(la_b, b_b, h0_b)
        carry = hs[:, -1] if batched else hs[0, -1]
        ys = hs.transpose(1, 0, 2) if batched else hs[0]
        return (_cast(carry, out_avals[0]) if site.out_used[0] else None,
                _cast(ys, out_avals[1]) if site.out_used[1] else None)
    return fn


def _bind_recurrence_fused(site: CallSite):
    from repro.kernels import ref

    def kernel(la, b, h0):
        b = b.astype(jnp.float32)          # the scan math is f32 anyway
        b = b.at[:, 0].add(jnp.exp(la[:, 0].astype(jnp.float32)) * h0)
        return ref.rglru_scan_ref(la, b)
    return _recurrence_fn(site, kernel)


def _bind_recurrence_pallas(site: CallSite):
    from repro.kernels import ops

    def kernel(la, b, h0):
        return ops.rglru_scan(la.astype(jnp.float32),
                              b.astype(jnp.float32),
                              h0.astype(jnp.float32))
    return _recurrence_fn(site, kernel)


# ---------------------------------------------------------------------------
# wkv_recurrence: scan of the RWKV6 state update with bonus u
# ---------------------------------------------------------------------------


def _wkv_site(site: CallSite):
    _require(site.kind == "scan", "wkv_recurrence binds scan sites")
    _require(site.params.get("num_consts") == 1
             and site.params.get("num_carry") == 1,
             "expected scan(u; state, (r, k, v, log_w))")
    _require(not site.params.get("reverse"), "reverse scan unsupported")
    _require(len(site.in_avals) == 6, "expected (u, s0, r, k, v, log_w)")
    _require(len(site.out_avals) == 2, "expected (s_final, ys) outputs")
    u, s0, r, k, v, lw = site.in_avals
    _require(_floats(site.in_avals), "needs floating inputs")
    _require(r.ndim == 2 and r.shape == k.shape == v.shape == lw.shape,
             "xs must be equal-shaped (S,D)")
    d = r.shape[1]
    _require(u.shape == (d,) and s0.shape == (d, d),
             "bonus (D,) and state (D,D) expected")
    _require(not site.out_used[0],
             "the kernels do not produce the final state")
    ys = site.out_avals[1]
    _require(ys.shape == r.shape, "ys must be (S,D)")
    return site.out_avals


def _bind_wkv_fused(site: CallSite):
    from repro.kernels import ref

    out_avals = _wkv_site(site)

    def fn(u, s0, r, k, v, lw):
        ys = ref.wkv6_ref(r[None], k[None], v[None], lw[None], u[None, None])
        return (None, _cast(ys[0], out_avals[1]))
    return fn


def _bind_wkv_pallas(site: CallSite):
    from repro.kernels import ops

    out_avals = _wkv_site(site)

    def fn(u, s0, r, k, v, lw):
        ys = ops.wkv6(r[None, :, None, :], k[None, :, None, :],
                      v[None, :, None, :], lw[None, :, None, :], u[None])
        return (None, _cast(ys[0, :, 0, :], out_avals[1]))
    return fn


# ---------------------------------------------------------------------------
# block-level variants (function-block offload, arXiv 2004.09883): one
# variant replaces a *merged multi-region span* — the whole algorithm, not a
# single loop.  Block sites arrive with ``kind == "block"`` and carry the
# concatenated top-level equations of every member region, so the binders
# infer operand roles by dataflow exactly like the span binders do.
# ---------------------------------------------------------------------------


def _input_roots(site: CallSite):
    """Dataflow helper: map any site-internal var to the set of site inputs
    it derives from (non-raising twin of ``_attention_roles``'s sole_root)."""
    producer = {o: e for e in site.eqns for o in e.outvars}
    inputs = set(site.in_vars)

    def roots(v) -> frozenset:
        out, stack, seen = set(), [v], set()
        while stack:
            x = stack.pop()
            if not hasattr(x, "count") or x in seen:
                continue
            seen.add(x)
            if x in inputs:
                out.add(x)
            elif x in producer:
                stack.extend(producer[x].invars)
        return frozenset(out)
    return roots


def _sole_rhs_dots(site: CallSite) -> list:
    """Top-level dot_generals whose rhs traces back to exactly ONE site
    input: the weight matmuls of a block (score/combine matmuls mix several
    inputs on the rhs and drop out).  Returns [(eqn, rhs_input_var), ...]
    in equation order — which is the program's weight-application order."""
    roots = _input_roots(site)
    out = []
    for e in site.eqns:
        if e.primitive.name != "dot_general":
            continue
        rr = roots(e.invars[1])
        if len(rr) == 1:
            out.append((e, next(iter(rr))))
    return out


def _scan_params(eqns, name: str, key: str):
    """Find a primitive param anywhere in a block, including inside the
    closed sub-jaxprs of member pjit calls."""
    for e in eqns:
        if e.primitive.name == name:
            return e.params.get(key)
        for v in e.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                found = _scan_params(sub.eqns, name, key)
                if found is not None:
                    return found
    return None


# --- attention_stack: rmsnorm + q/k/v projections + causal attention -------


def _attention_stack_site(site: CallSite):
    _require(site.kind == "block",
             f"attention_stack binds merged block sites, not {site.kind}")
    _require(len(site.in_avals) == 5, "expected (x, scale, wq, wk, wv)")
    _require(sum(site.out_used) == 1,
             "attention stack produces one used output")
    _require(_floats(site.in_avals), "needs floating inputs")
    if site.eqns:
        projs = _sole_rhs_dots(site)
        _require(len(projs) == 3,
                 "expected exactly the q/k/v projection matmuls")
        index = {v: i for i, v in enumerate(site.in_vars)}
        w_idx = tuple(index[r] for _, r in projs)  # (wq, wk, wv)
        one_d = [i for i, a in enumerate(site.in_avals) if a.ndim == 1]
        _require(len(one_d) == 1, "expected one rank-1 rmsnorm scale")
        rest = set(range(5)) - set(w_idx) - {one_d[0]}
        _require(len(rest) == 1, "cannot identify the residual-stream input")
        x_i = rest.pop()
        roles = (x_i, one_d[0]) + w_idx            # (x, scale, wq, wk, wv)
    else:
        # no equations (the python_ast frontend): the site builder already
        # ordered the operands positionally; the shape checks below reject
        # a wrong assignment
        roles = (0, 1, 2, 3, 4)
    x_av, s_av, wq_av, wk_av, wv_av = (site.in_avals[i] for i in roles)
    _require(x_av.ndim == 2, "(S, d) residual stream expected")
    _require(s_av.ndim == 1, "expected one rank-1 rmsnorm scale")
    _require(wq_av.shape == wk_av.shape == wv_av.shape and wq_av.ndim == 2,
             "q/k/v projection weight shapes disagree")
    _require(wq_av.shape[0] == x_av.shape[1], "projection d_model mismatch")
    _require(s_av.shape[0] == x_av.shape[1], "scale must match d_model")
    dh = wq_av.shape[1]
    _require(2 <= dh <= 512, "head dim outside kernel range")
    out_av = site.out_avals[list(site.out_used).index(True)]
    _require(out_av.shape == (x_av.shape[0], dh),
             "output is not attention-shaped")
    return x_av, out_av, roles


def _bind_attention_stack_chunked(site: CallSite):
    from repro.kernels import ref
    from repro.models.attention import attend_chunked
    from repro.models.plan import ExecPlan

    x_av, out_av, roles = _attention_stack_site(site)
    s = x_av.shape[0]
    plan = ExecPlan(attn_impl="chunked", attn_kv_chunk=128,
                    compute_dtype=str(x_av.dtype))
    pos = jnp.arange(s, dtype=jnp.int32)

    def fn(*xs):
        x, sc, wq, wk, wv = (xs[i] for i in roles)
        xn = ref.rmsnorm_ref(x, sc)
        q, k, v = xn @ wq, xn @ wk, xn @ wv
        o = attend_chunked(q[None, :, None, :], k[None, :, None, :],
                           v[None, :, None, :], pos, pos, True, 0, plan)
        return (_cast(o[0, :, 0, :], out_av),)
    return fn


def _bind_attention_stack_fused(site: CallSite):
    from repro.kernels import ref

    x_av, out_av, roles = _attention_stack_site(site)
    scale = 1.0 / math.sqrt(out_av.shape[-1])

    def fn(*xs):
        x, sc, wq, wk, wv = (xs[i] for i in roles)
        xn = ref.rmsnorm_ref(x, sc)
        q, k, v = xn @ wq, xn @ wk, xn @ wv
        o = ref.flash_attention_ref(q[None], k[None], v[None],
                                    causal=True, scale=scale)[0]
        return (_cast(o, out_av),)
    return fn


# --- moe_dispatch: router + top-k dispatch + batched expert FFN ------------


def _moe_site(site: CallSite):
    _require(site.kind == "block",
             f"moe_dispatch binds merged block sites, not {site.kind}")
    _require(bool(site.eqns), "block site carries no equations")
    _require(len(site.in_avals) == 5,
             "expected (x, w_router, w_gate, w_up, w_down)")
    _require(sum(site.out_used) == 1, "moe dispatch produces one used output")
    _require(_floats(site.in_avals), "needs floating inputs")
    rank3 = [i for i, a in enumerate(site.in_avals) if a.ndim == 3]
    _require(len(rank3) == 3, "expected three (E,·,·) expert weight stacks")
    index = {v: i for i, v in enumerate(site.in_vars)}
    # expert weights in application order: gate, up, down
    w_order = [index[r] for _, r in _sole_rhs_dots(site)
               if index[r] in rank3]
    _require(len(w_order) == 3, "cannot order the expert weight matmuls")
    wg_i, wu_i, wd_i = w_order
    rank2 = [i for i, a in enumerate(site.in_avals) if a.ndim == 2]
    _require(len(rank2) == 2, "expected tokens (T,d) and router (d,E)")
    top_k = _scan_params(site.eqns, "top_k", "k")
    _require(top_k is not None, "no top-k routing found in the block")
    # the router weight has E columns; tokens have d columns
    wg_av = site.in_avals[wg_i]
    n_experts, d = wg_av.shape[0], wg_av.shape[1]
    a2, b2 = (site.in_avals[i] for i in rank2)
    if a2.shape[1] == n_experts and b2.shape[1] == d:
        wr_i, x_i = rank2
    else:
        _require(b2.shape[1] == n_experts and a2.shape[1] == d,
                 "cannot tell router weight from token matrix")
        x_i, wr_i = rank2
    roles = (x_i, wr_i, wg_i, wu_i, wd_i)
    x_av = site.in_avals[x_i]
    _require(site.in_avals[wu_i].shape == wg_av.shape,
             "gate/up expert shapes disagree")
    _require(site.in_avals[wd_i].shape == (n_experts, wg_av.shape[2], d),
             "down projection shape mismatch")
    out_av = site.out_avals[list(site.out_used).index(True)]
    _require(out_av.shape == x_av.shape, "moe output must be token-shaped")
    return x_av, out_av, roles, n_experts, int(top_k)


def _bind_moe_scatter(site: CallSite):
    from repro.configs.base import ArchConfig, MoEConfig
    from repro.models.moe import moe_scatter
    from repro.models.plan import ExecPlan

    x_av, out_av, roles, n_experts, top_k = _moe_site(site)
    ff = site.in_avals[roles[2]].shape[2]
    # capacity_factor = E makes the dispatch dropless (cap = T*k), so the
    # scatter route is numerically the dense one-hot reference
    cfg = ArchConfig("block_moe", "moe", d_model=x_av.shape[1],
                     moe=MoEConfig(n_experts=n_experts, top_k=top_k,
                                   d_ff_expert=ff,
                                   capacity_factor=float(n_experts)))
    plan = ExecPlan(moe_impl="scatter_ep", compute_dtype=str(x_av.dtype))

    def fn(*xs):
        x, wr, wg, wu, wd = (xs[i] for i in roles)
        params = {"w_router": wr, "w_gate": wg, "w_up": wu, "w_down": wd}
        out, _aux = moe_scatter(x, params, cfg, plan)
        return (_cast(out, out_av),)
    return fn


# ---------------------------------------------------------------------------
# the default registry
# ---------------------------------------------------------------------------

_DEFAULT: Optional[KernelRegistry] = None


def default_registry() -> KernelRegistry:
    """The shipped variants; built once (registration order defines the
    ``("ref", "fused_jnp", "pallas")`` gene-implementation order)."""
    global _DEFAULT
    if _DEFAULT is not None:
        return _DEFAULT
    reg = KernelRegistry()
    for pattern, fused, pallas in (
        ("softmax_attention", _bind_attention_fused, _bind_attention_pallas),
        ("rmsnorm", _bind_rmsnorm_fused, _bind_rmsnorm_pallas),
        ("linear_recurrence", _bind_recurrence_fused, _bind_recurrence_pallas),
        ("wkv_recurrence", _bind_wkv_fused, _bind_wkv_pallas),
    ):
        reg.register(Variant(pattern, "fused_jnp", fused,
                             "fused jax.numpy rewrite"))
        reg.register(Variant(pattern, "pallas", pallas,
                             "Pallas kernel (repro.kernels.ops)"))
    # block-level patterns: whole-algorithm replacements over merged spans.
    # Registration order is the gene implementation order, so the flash-style
    # chunked route sits at impl_index 1 (the primary accelerated slot).
    reg.register(Variant("attention_stack", "block_chunked",
                         _bind_attention_stack_chunked,
                         "rmsnorm + QKV + flash attention via "
                         "models/attention.attend_chunked"))
    reg.register(Variant("attention_stack", "block_fused",
                         _bind_attention_stack_fused,
                         "rmsnorm + QKV + naive causal attention"))
    reg.register(Variant("moe_dispatch", "block_scatter",
                         _bind_moe_scatter,
                         "capacity-limited scatter dispatch via "
                         "models/moe.moe_scatter"))
    _DEFAULT = reg
    return reg


def auto_variant_order(backend: str) -> tuple[str, ...]:
    """Preference order for the legacy ``"kernel"`` (auto) implementation:
    the Pallas kernels on real TPU, the fused rewrites elsewhere (Pallas
    interpret mode is a correctness path, not a fast one)."""
    return ("pallas", "fused_jnp") if backend == "tpu" \
        else ("fused_jnp", "pallas")
