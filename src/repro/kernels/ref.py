"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately the simplest possible formulations — materialized
softmax, per-step scans — independent of the model code, so kernel tests
cross-check three implementations (kernel / model-fused / oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, scale: float, group: int = 1) -> jax.Array:
    """q: (BHq, Sq, D); k/v: (BHkv, Sk, D); Hq = Hkv*group (interleaved)."""
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rglru_scan_ref(log_a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = exp(log_a_t) h_{t-1} + b_t, h_0 = 0.  (B,S,D) -> (B,S,D) f32."""
    def step(h, ab):
        la, bt = ab
        h = jnp.exp(la.astype(jnp.float32)) * h + bt.astype(jnp.float32)
        return h, h
    h0 = jnp.zeros((log_a.shape[0], log_a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (log_a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
             u: jax.Array) -> jax.Array:
    """Step-scan oracle.  r/k/v/log_w: (BH,S,D); u: (BH,1,D) -> y (BH,S,D) f32."""
    rf, kf, vf, lwf = (a.astype(jnp.float32) for a in (r, k, v, log_w))
    uf = u.astype(jnp.float32)[:, 0]  # (BH, D)

    def step(s, rkvw):
        rt, kt, vt, lwt = rkvw  # (BH,D)
        kv = kt[:, :, None] * vt[:, None, :]               # (BH,D,D)
        at = s + uf[:, :, None] * kv
        y = jnp.einsum("bk,bkv->bv", rt, at)
        s = jnp.exp(lwt)[:, :, None] * s + kv
        return s, y

    s0 = jnp.zeros((r.shape[0], r.shape[2], v.shape[2]), jnp.float32)
    _, ys = jax.lax.scan(step, s0, tuple(a.transpose(1, 0, 2) for a in (rf, kf, vf, lwf)))
    return ys.transpose(1, 0, 2)


def rmsnorm_ref(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
