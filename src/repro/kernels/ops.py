"""jit'd public wrappers for the Pallas kernels.

Handles padding to block multiples, GQA head flattening, backend detection
(interpret mode everywhere except real TPU), and initial-state folding.
These wrappers are what the pattern DB registers as replacement
implementations for the matched function blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import rglru_scan as _rg
from repro.kernels import rmsnorm as _rn
from repro.kernels import wkv6 as _wk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, blk_q: int = 128, blk_k: int = 128) -> jax.Array:
    """q: (B,Sq,Hq,D); k/v: (B,Sk,Hkv,D) -> (B,Sq,Hq,D)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    scale = 1.0 / np.sqrt(d)
    # flatten heads: q -> (B*Hkv*G, Sq, D) so kv index = bh // group
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, -1, d)
    bq = min(blk_q, max(1, sq))
    bk = min(blk_k, kf.shape[1])
    qf, pad_q = _pad_to(qf, 1, bq)
    kf, _ = _pad_to(kf, 1, bk)
    vf, _ = _pad_to(vf, 1, bk)
    # NOTE padded KV columns would contaminate non-causal softmax; mask by
    # giving padded keys -inf via a causal-style trick is not available here,
    # so we require Sk % blk_k == 0 for non-causal use (asserted).
    if not causal:
        assert k.shape[1] % bk == 0, "non-causal flash requires Sk % blk_k == 0"
    out = _fa.flash_attention_bh(qf, kf, vf, causal=causal, scale=scale,
                                 blk_q=bq, blk_k=bk, group=group,
                                 interpret=_interpret())
    if pad_q:
        out = out[:, :sq]
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "d_block"))
def rglru_scan(log_a: jax.Array, b: jax.Array, h0: jax.Array | None = None, *,
               chunk: int = 256, d_block: int = 128) -> jax.Array:
    """(B,S,D) coeffs -> (B,S,D) states; optional initial state h0 (B,D)."""
    bsz, s, d = log_a.shape
    if h0 is not None:  # fold h0 into b[0]
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0].astype(jnp.float32)) * h0)
    c = min(chunk, s)
    db = min(d_block, d)
    la_p, pad_s = _pad_to(log_a, 1, c)
    b_p, _ = _pad_to(b, 1, c)
    if d % db != 0:
        db = d  # fall back to one channel block
    out = _rg.rglru_scan(la_p, b_p, chunk=c, d_block=db, interpret=_interpret())
    return out[:, :s]


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
         u: jax.Array, *, chunk: int = 64) -> jax.Array:
    """r/k/v/log_w: (B,S,H,D); u: (H,D) -> y (B,S,H,D) f32."""
    b, s, h, d = r.shape
    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    rf, kf, vf, lwf = map(flat, (r, k, v, log_w))
    uf = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, 1, d)
    c = min(chunk, s)
    # pad time to chunk multiple with log_w=0, k=0 (state-neutral)
    rf, pad = _pad_to(rf, 1, c)
    kf, _ = _pad_to(kf, 1, c)
    vf, _ = _pad_to(vf, 1, c)
    lwf, _ = _pad_to(lwf, 1, c)
    out = _wk.wkv6(rf, kf, vf, lwf, uf, chunk=c, interpret=_interpret())
    out = out[:, :s]
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("eps", "blk_rows"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            blk_rows: int = 256) -> jax.Array:
    """x: (..., d); scale: (d,)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    n = x2.shape[0]
    blk = min(blk_rows, n)
    while n % blk != 0:
        blk //= 2
    out = _rn.rmsnorm(x2, scale, eps=eps, blk_rows=max(blk, 1), interpret=_interpret())
    return out.reshape(shape)
