"""Pallas TPU fused RMSNorm: one pass over rows, f32 statistics.

Grid over row blocks; each invocation loads a (blk_rows, d) tile into VMEM,
computes rsqrt(mean(x^2)+eps) and writes x * inv * (1 + scale) — a single
fused loop instead of the reference's separate square/mean/rsqrt/mul ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)             # (blk, d)
    inv = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * inv * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            blk_rows: int = 256, interpret: bool = True) -> jax.Array:
    """x: (N, d); scale: (d,)."""
    n, d = x.shape
    blk = min(blk_rows, n)
    assert n % blk == 0, (n, blk)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",))
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
        **kwargs,
    )(x, scale)
