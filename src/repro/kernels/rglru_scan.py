"""Pallas TPU kernel for the RG-LRU linear recurrence  h_t = a_t h_{t-1} + b_t.

Grid: (batch, channel_blocks, time_chunks) with the time axis sequential.
The carried state h lives in VMEM scratch across time chunks; within a chunk
the inclusive scan runs as a log2(chunk) doubling pass over VPU lanes —
no per-step HBM round trips, unlike the lax.scan reference.

Channel blocks are lane-aligned (multiples of 128); chunk length must divide
the sequence (ops.py pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_scan(log_a: jax.Array, b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Inclusive scan of h_t = exp(log_a_t) h_{t-1} + b_t within one chunk.

    Doubling pass: after round r each row t combines inputs (t-2^r, t].
    Identity element is (log_a=0, b=0).
    """
    c = log_a.shape[0]
    la, bb = log_a, b
    shift = 1
    while shift < c:
        la_s = jnp.pad(la, ((shift, 0), (0, 0)))[:c]
        bb_s = jnp.pad(bb, ((shift, 0), (0, 0)))[:c]
        bb = jnp.exp(la) * bb_s + bb
        la = la + la_s
        shift *= 2
    return la, bb  # cumulative (log_a products, scanned b with h0=0)


def _rglru_kernel(log_a_ref, b_ref, out_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    la = log_a_ref[0].astype(jnp.float32)   # (chunk, d_blk)
    bb = b_ref[0].astype(jnp.float32)
    la_cum, b_cum = _chunk_scan(la, bb)
    h = jnp.exp(la_cum) * h_scr[...] + b_cum  # (chunk, d_blk): all states
    out_ref[0] = h.astype(out_ref.dtype)
    h_scr[...] = h[-1:, :]


def rglru_scan(log_a: jax.Array, b: jax.Array, *, chunk: int = 256,
               d_block: int = 128, interpret: bool = True) -> jax.Array:
    """log_a, b: (B, S, D) -> states h: (B, S, D).  h0 = 0 (ops.py folds a
    nonzero initial state into b[0])."""
    bsz, s, d = log_a.shape
    assert s % chunk == 0 and d % d_block == 0, (s, d, chunk, d_block)
    grid = (bsz, d // d_block, s // chunk)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b_, di, ci: (b_, ci, di)),
            pl.BlockSpec((1, chunk, d_block), lambda b_, di, ci: (b_, ci, di)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda b_, di, ci: (b_, ci, di)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, d_block), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(log_a, b)
