"""Fault tolerance: checkpoint/restart supervision, straggler detection, and
elastic rescale (reshard a checkpoint onto a different mesh).

At thousand-node scale the failure model is: a host dies mid-step (the step
raises), a host slows down (straggler), or capacity changes (elastic).  The
supervisor handles all three:

  * crash      -> restore latest committed checkpoint, rebuild the step, resume;
  * straggler  -> per-step wall-time EWMA; a step slower than
                  ``mean + k*std`` (and a multiplicative floor) flags the
                  step; the runner's policy hook decides (log / re-mesh);
  * elastic    -> :func:`reshard` loads a checkpoint with the *new* mesh's
                  shardings — host-side leaves, device_put with new specs —
                  so training continues on fewer/more chips.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------


@dataclass
class StragglerMonitor:
    """EWMA step-time tracker with z-score + ratio flagging."""

    alpha: float = 0.1
    z_threshold: float = 4.0
    ratio_threshold: float = 2.0
    warmup: int = 3
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # prime the EWMA; never flag during warmup (includes compile)
            self.mean = dt if self.n == 1 else (self.mean + dt) / 2
            return False
        is_straggler = False
        std = math.sqrt(max(self.var, 1e-12))
        if dt > self.mean * self.ratio_threshold and \
                dt > self.mean + self.z_threshold * std:
            is_straggler = True
            self.flagged.append((step, dt, self.mean))
        else:
            # only fold non-outlier samples into the estimate
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


# ---------------------------------------------------------------------------
# supervised training with checkpoint/restart
# ---------------------------------------------------------------------------


@dataclass
class RunReport:
    steps_done: int
    restarts: int
    stragglers: list
    losses: list


class Supervisor:
    """Runs a step function under failure supervision.

    ``step_fn(state, batch) -> (state, metrics)`` may raise (injected or
    real); the supervisor restores the latest committed checkpoint and
    replays from there.  Checkpoints every ``ckpt_every`` steps (async).
    """

    def __init__(self, ckpt: CheckpointManager, ckpt_every: int = 10,
                 max_restarts: int = 10,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.monitor = StragglerMonitor()
        self.on_straggler = on_straggler

    def run(self, state: Any, batch_fn: Callable[[int], dict],
            step_fn: Callable, n_steps: int,
            start_step: int = 0,
            failure_injector: Optional[Callable[[int], bool]] = None,
            state_shardings: Any = None) -> tuple[Any, RunReport]:
        restarts = 0
        losses: list = []
        step = start_step
        # initial checkpoint so step-0 failures can restart
        self.ckpt.save(step, state, blocking=True)
        while step < n_steps:
            try:
                if failure_injector is not None and failure_injector(step):
                    raise RuntimeError(f"injected node failure at step {step}")
                t0 = time.perf_counter()
                batch = batch_fn(step)
                state, metrics = step_fn(state, batch)
                loss = metrics.get("loss")
                if loss is not None:
                    loss = float(np.asarray(loss))
                    if not math.isfinite(loss):
                        raise FloatingPointError(f"non-finite loss at step {step}")
                    losses.append(loss)
                dt = time.perf_counter() - t0
                if self.monitor.observe(step, dt) and self.on_straggler:
                    self.on_straggler(step, dt)
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except Exception:  # noqa: BLE001 — any failure triggers restart
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                step, state = self.ckpt.restore(
                    state, shardings=state_shardings)
        self.ckpt.wait()
        return state, RunReport(step - start_step, restarts,
                                list(self.monitor.flagged), losses)


# ---------------------------------------------------------------------------
# elastic rescale
# ---------------------------------------------------------------------------


def reshard(ckpt: CheckpointManager, template: Any, new_shardings: Any,
            step: Optional[int] = None) -> tuple[int, Any]:
    """Load a checkpoint onto a different mesh: the manifest holds full
    (unsharded) arrays, so restoring under the new mesh's shardings performs
    the elastic re-partition."""
    return ckpt.restore(template, step=step, shardings=new_shardings)
