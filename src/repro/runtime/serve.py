"""Serving loop: batched prefill + autoregressive decode with KV caches.

``Server`` owns params + plan; ``generate`` pads a request batch to the
static shapes, prefills, then decodes greedily or with temperature sampling.
The decode loop donates the state so caches update in place.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.models.plan import ExecPlan


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0


class Server:
    def __init__(self, model: Model, params, plan: ExecPlan,
                 cfg: Optional[ServeConfig] = None):
        self.model = model
        self.params = params
        self.plan = plan
        self.cfg = cfg or ServeConfig()
        self._decode = jax.jit(
            lambda p, tok, st: model.decode(p, tok, st, plan),
            donate_argnums=(2,))
        self._prefill = {}

    def _prefill_fn(self, cache_capacity: int):
        if cache_capacity not in self._prefill:
            self._prefill[cache_capacity] = jax.jit(
                functools.partial(
                    lambda p, inp: self.model.prefill(
                        p, inp, self.plan, cache_capacity=cache_capacity)))
        return self._prefill[cache_capacity]

    def generate(self, inputs: dict, max_new: Optional[int] = None) -> np.ndarray:
        """inputs: dict with 'tokens' (B,S) (+ frames/patch_feats).  Returns
        generated tokens (B, max_new)."""
        max_new = max_new or self.cfg.max_new_tokens
        tokens = inputs["tokens"]
        b, s = tokens.shape
        cap = s + max_new + (self.model.cfg.vision_patches or 0)
        logits, state = self._prefill_fn(cap)(self.params, inputs)
        key = jax.random.key(self.cfg.seed)
        out = np.zeros((b, max_new), np.int32)
        tok = self._sample(logits, key, 0)
        for i in range(max_new):
            out[:, i] = np.asarray(tok[:, 0])
            if i == max_new - 1:
                break
            logits, state = self._decode(self.params, tok, state)
            tok = self._sample(logits, key, i + 1)
        return out

    def _sample(self, logits, key, i):
        lg = logits[:, -1].astype(jnp.float32)
        if self.cfg.temperature <= 0:
            return jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(
            k, lg / self.cfg.temperature, axis=-1)[:, None].astype(jnp.int32)
