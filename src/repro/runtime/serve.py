"""Serving loop: batched prefill + autoregressive decode with KV caches.

``Server`` owns params + plan; ``generate`` pads a request batch to the
static shapes, prefills, then decodes greedily or with temperature sampling.
The decode loop donates the state so caches update in place.

The plan is **hot-swappable**: everything derived from it (the jitted decode
fn, the per-capacity prefill cache) lives in one immutable ``_Bound``
snapshot published by a single reference assignment.  ``generate`` reads the
snapshot once per call, so an in-flight generation always runs one complete
plan end-to-end — a concurrent :meth:`Server.swap_plan` (the planning
service's hot-swap) takes effect on the *next* call, never mid-sequence.
``Server.from_store`` constructs a server straight from a persisted plan
fingerprint, with no planner in the loop.
"""
from __future__ import annotations

import collections
import functools
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.models.plan import ExecPlan
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0


class _Bound:
    """One plan plus everything jitted against it.  Immutable after
    construction (the prefill dict only memoizes pure jit wrappers per
    capacity — idempotent, so racing fills are harmless)."""

    __slots__ = ("plan", "decode", "_model", "_prefill")

    def __init__(self, model: Model, plan: ExecPlan):
        self.plan = plan
        self._model = model
        self.decode = jax.jit(
            lambda p, tok, st: model.decode(p, tok, st, plan),
            donate_argnums=(2,))
        self._prefill: dict = {}

    def prefill_fn(self, cache_capacity: int):
        if cache_capacity not in self._prefill:
            model, plan = self._model, self.plan
            self._prefill[cache_capacity] = jax.jit(
                functools.partial(
                    lambda p, inp: model.prefill(
                        p, inp, plan, cache_capacity=cache_capacity)))
        return self._prefill[cache_capacity]


class Server:
    def __init__(self, model: Model, params, plan: ExecPlan,
                 cfg: Optional[ServeConfig] = None):
        self.model = model
        self.params = params
        self.cfg = cfg or ServeConfig()
        self._bound = _Bound(model, plan)
        # request-arrival timestamps for traffic_hz(): the signal the
        # planning service's operating-point policy reads (latency-optimal
        # under load, energy-optimal idle)
        self._req_times: collections.deque = collections.deque(maxlen=256)

    @classmethod
    def from_store(cls, model: Model, params, store, fingerprint: str,
                   cfg: Optional[ServeConfig] = None) -> "Server":
        """Construct a server from a persisted plan: loads the newest
        :class:`~repro.service.store.PlanRecord` for ``fingerprint`` from a
        :class:`~repro.service.store.PlanStore` and rehydrates its
        ``ExecPlan`` — no search, no planner in the loop."""
        rec = store.load(fingerprint)
        if rec is None:
            raise LookupError(
                f"no stored plan for fingerprint {fingerprint!r} — run the "
                f"planning service (or Offloader.plan) first")
        plan = store.rehydrate(rec)
        if not isinstance(plan, ExecPlan):
            raise TypeError(
                f"stored plan for {fingerprint!r} rehydrates to "
                f"{type(plan).__name__}, not an ExecPlan — Server only "
                f"serves module-frontend plans")
        return cls(model, params, plan, cfg)

    @property
    def plan(self) -> ExecPlan:
        return self._bound.plan

    def swap_plan(self, plan: ExecPlan) -> None:
        """Hot-swap the execution plan.  Builds the new plan's jitted
        closures first, then publishes them in one reference assignment:
        concurrent ``generate`` calls finish on the plan they started with
        and the next call picks this one up — never a torn mix."""
        self._bound = _Bound(self.model, plan)

    def generate(self, inputs: dict, max_new: Optional[int] = None) -> np.ndarray:
        """inputs: dict with 'tokens' (B,S) (+ frames/patch_feats).  Returns
        generated tokens (B, max_new)."""
        bound = self._bound          # one snapshot: the whole call runs one
        max_new = max_new or self.cfg.max_new_tokens   # complete plan
        tokens = inputs["tokens"]
        b, s = tokens.shape
        t0 = time.perf_counter()
        self._req_times.append(t0)
        obs_metrics.gauge("serve.traffic_hz").set(self.traffic_hz())
        with obs_trace.span("serve.generate", batch=b, prompt_len=s,
                            max_new=max_new):
            cap = s + max_new + (self.model.cfg.vision_patches or 0)
            logits, state = bound.prefill_fn(cap)(self.params, inputs)
            key = jax.random.key(self.cfg.seed)
            out = np.zeros((b, max_new), np.int32)
            tok = self._sample(logits, key, 0)
            for i in range(max_new):
                out[:, i] = np.asarray(tok[:, 0])
                if i == max_new - 1:
                    break
                logits, state = bound.decode(self.params, tok, state)
                tok = self._sample(logits, key, i + 1)
        # the histogram lives in the process-wide registry keyed by name,
        # not on the _Bound snapshot — a mid-flight swap_plan publishes a
        # new snapshot but cannot reset the latency series
        obs_metrics.histogram("serve.generate_seconds").observe(
            time.perf_counter() - t0)
        return out

    def traffic_hz(self, window_s: float = 60.0) -> float:
        """Recent request rate (requests/s over the trailing window) — feed
        it to :meth:`repro.service.service.PlanService.select_for_traffic`
        to pick the right Pareto operating point for the current load."""
        if window_s <= 0:
            return 0.0
        cutoff = time.perf_counter() - float(window_s)
        return sum(1 for t in self._req_times if t >= cutoff) / float(window_s)

    def _sample(self, logits, key, i):
        lg = logits[:, -1].astype(jnp.float32)
        if self.cfg.temperature <= 0:
            return jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(
            k, lg / self.cfg.temperature, axis=-1)[:, None].astype(jnp.int32)
