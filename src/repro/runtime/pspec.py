"""Logical-axis sharding: maxtext-style rules mapping logical axis names to
mesh axes, with divisibility fallbacks.

Model code annotates activations with ``constrain(x, "batch", "seq", None)``;
outside a mesh context this is the identity, inside it becomes a
``with_sharding_constraint`` against the active rules.  Rules centralize the
DP/TP/EP/SP layout in one table (``runtime/sharding.py``) instead of
scattering mesh names through model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, tuple[str, ...]]

_STATE = threading.local()


class ShardingRules:
    """logical name -> mesh axis (or tuple of mesh axes)."""

    def __init__(self, mesh: Mesh, table: dict[str, AxisVal]):
        self.mesh = mesh
        self.table = dict(table)

    def resolve(self, logical: Optional[str], dim: int) -> AxisVal:
        """Resolve one logical axis to mesh axes, dropping non-divisible shards."""
        if logical is None:
            return None
        axes = self.table.get(logical)
        if axes is None:
            return None
        if isinstance(axes, str):
            axes = (axes,)
        # keep the longest prefix of mesh axes that divides the dim
        kept: list[str] = []
        size = 1
        for a in axes:
            if a not in self.mesh.shape:
                continue
            nxt = size * self.mesh.shape[a]
            if dim % nxt != 0:
                break
            kept.append(a)
            size = nxt
        if not kept:
            return None
        return tuple(kept) if len(kept) > 1 else kept[0]

    def pspec(self, shape: Sequence[int], logical_axes: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(logical_axes), (shape, logical_axes)
        used: set[str] = set()
        parts: list[AxisVal] = []
        for dim, name in zip(shape, logical_axes):
            r = self.resolve(name, dim)
            # a mesh axis may appear at most once in a PartitionSpec
            if r is not None:
                rt = (r,) if isinstance(r, str) else r
                rt = tuple(a for a in rt if a not in used)
                used.update(rt)
                r = None if not rt else (rt[0] if len(rt) == 1 else rt)
            parts.append(r)
        return P(*parts)

    def sharding(self, shape: Sequence[int], logical_axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(shape, logical_axes))


@contextlib.contextmanager
def axis_rules(rules: Optional[ShardingRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def current_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.pspec(x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# shard_map helpers: run a function with fully-local shards (used by the
# flash/wkv/rglru inner loops where SPMD propagation would thrash)
# ---------------------------------------------------------------------------


def dividing_axes(dim: int, candidates=(("pod", "data", "model"),
                                        ("data", "model"), ("pod", "data"),
                                        ("data",), ("model",))) -> tuple:
    """Longest mesh-axis tuple whose size divides `dim` (empty if none)."""
    rules = current_rules()
    if rules is None:
        return ()
    mesh = rules.mesh
    for cand in candidates:
        axes = tuple(a for a in cand if a in mesh.shape)
        if not axes:
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size == 0:
            return axes
    return ()


def local_map(fn, in_specs, out_specs, *args):
    """shard_map `fn` under the active rules' mesh (identity without rules).
    The body runs with rules disabled so nested `constrain`s are no-ops."""
    rules = current_rules()
    if rules is None:
        return fn(*args)

    def inner(*a):
        with axis_rules(None):
            return fn(*a)

    return shard_map_compat(inner, mesh=rules.mesh, in_specs=in_specs,
                            out_specs=out_specs)(*args)


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: `jax.shard_map` (new API, `check_vma`) when
    present, else `jax.experimental.shard_map.shard_map` (old API,
    `check_rep`).  Replication checking is disabled in both — the local
    bodies here intentionally compute unreplicated partial results."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
