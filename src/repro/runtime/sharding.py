"""Parameter / activation / decode-state sharding rules.

One table maps logical axis names to mesh axes (DP over ``pod``+``data``,
FSDP over ``data``, TP/EP/SP over ``model``); path-pattern rules assign
logical axes to every parameter and decode-state leaf.  Divisibility
fallbacks live in ``ShardingRules.resolve`` (non-divisible dims replicate).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.runtime.pspec import ShardingRules

# ---------------------------------------------------------------------------
# logical axis -> mesh axes
# ---------------------------------------------------------------------------


def logical_table(mesh: Mesh) -> dict:
    has_pod = "pod" in mesh.shape
    return {
        "batch": ("pod", "data") if has_pod else ("data",),
        "fsdp": "data",
        "tensor": "model",
        "vocab": "model",
        "experts": "model",
        "seq_sp": "model",
        "kv_heads": "model",
        "kv_seq": "model",
    }


def make_rules(mesh: Mesh) -> ShardingRules:
    return ShardingRules(mesh, logical_table(mesh))


# ---------------------------------------------------------------------------
# parameter rules (matched on "/"-joined key path, right-aligned axes)
# ---------------------------------------------------------------------------

_P_IN_OUT = ("fsdp", "tensor")    # (d_in, d_out-parallel) weights
_P_OUT_IN = ("tensor", "fsdp")    # (d_in-parallel, d_out) weights

_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"moe/w_gate$", ("experts", "fsdp", None)),
    (r"moe/w_up$", ("experts", "fsdp", None)),
    (r"moe/w_down$", ("experts", None, "fsdp")),
    (r"moe/w_router$", ("fsdp", None)),
    (r"(^|/)(embed|lm_head)$", ("vocab", "fsdp")),
    (r"(^|/)(wq|wk|wv|wg|wr|w_gate|w_up|w_branch|w_in|dd_w1|w_lora_a|cm_wk|cm_wr|vis_w1)$",
     _P_IN_OUT),
    (r"(^|/)(wo|w_down|w_out|cm_wv|w_lora_b|dd_w2|vis_w2)$", _P_OUT_IN),
    (r"(^|/)(w_a|w_x)$", ("tensor", None, None)),
    (r"(^|/)w_conv$", (None, "tensor")),
    (r"(^|/)(lam|b_conv|b_a|b_x)$", ("tensor",)),
    (r"(^|/)w_router$", ("fsdp", None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_ATTN_Q = re.compile(r"(attn|xattn)/(wq|wo|bq)$")
_ATTN_KV = re.compile(r"(attn|xattn)/(wk|wv|bk|bv)$")
_RWKV_HEADED = re.compile(r"tm_cm/(wr|wk|wv|wg|wo)$")


def _axes_for_param(path: str, ndim: int,
                    cfg: Optional[ArchConfig] = None,
                    mesh: Optional[Mesh] = None) -> tuple:
    # Attention projections: sharding the head dim over "model" only makes
    # sense when whole heads land on a device — otherwise the score einsums
    # contract over a sharded head_dim and XLA materializes giant gathers.
    if cfg is not None and mesh is not None:
        msize = mesh.shape.get("model", 1)
        if _ATTN_Q.search(path):
            ok = cfg.n_heads % msize == 0
            ax = ("tensor", "fsdp") if path.endswith("wo") else ("fsdp", "tensor")
            if not ok:
                ax = (None, "fsdp") if path.endswith("wo") else ("fsdp", None)
            return (None,) * (ndim - len(ax)) + ax[-ndim:]
        if _ATTN_KV.search(path):
            ok = cfg.n_kv_heads % msize == 0
            ax = ("fsdp", "tensor") if ok else ("fsdp", None)
            return (None,) * (ndim - len(ax)) + ax[-ndim:]
        if _RWKV_HEADED.search(path):
            nh = cfg.d_model // max(cfg.rwkv_head_dim, 1)
            ok = nh % msize == 0
            if path.endswith("wo"):
                ax = ("tensor", "fsdp") if ok else (None, "fsdp")
            else:
                ax = ("fsdp", "tensor") if ok else ("fsdp", None)
            return (None,) * (ndim - len(ax)) + ax
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            if len(axes) > ndim:
                axes = axes[-ndim:]
            return (None,) * (ndim - len(axes)) + tuple(axes)
    return (None,) * ndim


def param_logical_axes(param_shapes: Any, cfg: Optional[ArchConfig] = None,
                       mesh: Optional[Mesh] = None) -> Any:
    """Pytree of logical-axis tuples matching the params structure."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _axes_for_param(_path_str(path), len(leaf.shape),
                                           cfg, mesh),
        param_shapes)


def tree_pspecs(rules: ShardingRules, shapes: Any, axes: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda leaf, ax: rules.pspec(leaf.shape, ax), shapes, axes)


def tree_shardings(rules: ShardingRules, shapes: Any, axes: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda leaf, ax: NamedSharding(rules.mesh, rules.pspec(leaf.shape, ax)),
        shapes, axes)


# ---------------------------------------------------------------------------
# decode-state rules
# ---------------------------------------------------------------------------


def _axes_for_state(path: str, shape: tuple, cfg: ArchConfig, mesh: Mesh) -> tuple:
    ndim = len(shape)
    model = mesh.shape.get("model", 1)
    if path.endswith("cache_len"):
        return ()
    if re.search(r"(^|/)(k|v|xk|xv)$", path):
        # (L, B, S, Hkv, D) or (B, S, Hkv, D)
        hkv, s = shape[-2], shape[-3]
        lead = (None,) * (ndim - 4)
        if hkv % model == 0:
            return lead + ("batch", None, "kv_heads", None)
        if s % model == 0:
            return lead + ("batch", "kv_seq", None, None)
        return lead + ("batch", None, None, None)
    if path.endswith("wkv"):  # (L,B,H,Dk,Dv)
        h = shape[-3]
        lead = (None,) * (ndim - 4)
        if h % model == 0:
            return lead + ("batch", "kv_heads", None, None)
        return lead + ("batch", None, None, "tensor")
    if re.search(r"shift_(tm|cm)$", path):  # (L,B,d)
        return (None,) * (ndim - 2) + ("batch", "tensor")
    if path.endswith("/h"):  # rglru state (L,B,dr)
        return (None,) * (ndim - 2) + ("batch", "tensor")
    if path.endswith("conv"):  # (L,B,w-1,dr)
        return (None,) * (ndim - 3) + ("batch", None, "tensor")
    return (None,) * ndim


def state_logical_axes(state_shapes: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _axes_for_state(_path_str(path), tuple(leaf.shape), cfg, mesh),
        state_shapes)


# ---------------------------------------------------------------------------
# batch (input) rules
# ---------------------------------------------------------------------------


def batch_logical_axes(batch_shapes: Any) -> Any:
    def f(path, leaf):
        ndim = len(leaf.shape)
        return ("batch",) + (None,) * (ndim - 1)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: f(path, leaf), batch_shapes)
