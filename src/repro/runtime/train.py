"""Train-step builders.

* ``make_train_step``   — the production pjit step: loss -> grads -> AdamW,
  sharding via the logical-axis rules (DP/FSDP/TP/EP from one table), buffer
  donation for params/optimizer state.
* ``make_compressed_dp_step`` — shard_map data-parallel variant with
  hierarchical gradient reduction: fp32 reduce inside a pod, error-feedback
  int8 across pods (the slow hop).  Used by the compression benchmark and
  example; the mechanism is exact-tracking thanks to error feedback.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import Model
from repro.models.plan import ExecPlan
from repro.optim import (AdamWState, CompressionState, OptimizerConfig,
                         adamw_init, adamw_update, ef_compress_update, ef_init)
from repro.runtime.pspec import ShardingRules, axis_rules


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    comp: Optional[CompressionState]


def init_train_state(model: Model, rng: jax.Array, with_compression: bool = False,
                     dtype=jnp.float32) -> TrainState:
    params = model.init(rng, dtype=dtype)
    return TrainState(params, adamw_init(params),
                      ef_init(params) if with_compression else None)


def make_train_step(model: Model, plan: ExecPlan, opt_cfg: OptimizerConfig,
                    schedule: Callable, rules: Optional[ShardingRules] = None):
    """Returns train_step(state, batch) -> (state, metrics).  Pure; jit/lower
    it under ``with axis_rules(rules)`` so activation constraints resolve."""

    def grads_of(params, batch):
        def loss_fn(p):
            return model.loss(p, batch, plan)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state: TrainState, batch: dict):
        mb = max(plan.microbatch, 1)
        if mb == 1:
            (loss, metrics), grads = grads_of(state.params, batch)
        else:
            # gradient accumulation: scan over microbatches; activation
            # memory scales by 1/mb at the cost of mb weight re-reads
            def split(x):
                b = x.shape[0]
                assert b % mb == 0, (b, mb)
                return x.reshape(mb, b // mb, *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mbatch):
                g_acc, m_acc = carry
                (_, metrics), grads = grads_of(state.params, mbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / mb, g_acc, grads)
                m_acc = jax.tree_util.tree_map(
                    lambda a, m: a + m / mb, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            m0 = jax.eval_shape(lambda: grads_of(state.params, jax.tree_util.tree_map(
                lambda x: x[0], micro))[0][1])
            m0 = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, metrics), _ = jax.lax.scan(acc_fn, (g0, m0), micro)
        lr = schedule(state.opt.step)
        new_p, new_opt, om = adamw_update(grads, state.opt, state.params,
                                          opt_cfg, lr)
        metrics = dict(metrics)
        metrics.update(om)
        return TrainState(new_p, new_opt, state.comp), metrics

    return train_step


def jit_train_step(model: Model, plan: ExecPlan, opt_cfg: OptimizerConfig,
                   schedule: Callable, rules: ShardingRules,
                   state_shardings, batch_shardings, donate: bool = True):
    """AOT-friendly jitted step with shardings + donation."""
    step = make_train_step(model, plan, opt_cfg, schedule, rules)

    def traced(state, batch):
        with axis_rules(rules):
            return step(state, batch)

    return jax.jit(
        traced,
        in_shardings=(state_shardings, batch_shardings),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else (),
    )


# ---------------------------------------------------------------------------
# compressed hierarchical-DP step (shard_map over (pod, data))
# ---------------------------------------------------------------------------


def make_compressed_dp_step(model: Model, plan: ExecPlan,
                            opt_cfg: OptimizerConfig, schedule: Callable,
                            mesh, compress: bool = True):
    """Pure data-parallel step over mesh axes (pod?, data) with hierarchical
    gradient reduction: exact fp32 psum within a pod, EF-int8 across pods.

    Params are replicated; batch is sharded over all DP axes.  Suitable for
    models that fit one device (the compression mechanism demo); at scale the
    same pattern rides on the FSDP step's pod axis.
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    has_pod = "pod" in mesh.shape
    n_pods = mesh.shape.get("pod", 1)

    from jax.experimental.shard_map import shard_map

    batch_spec = P(dp_axes)
    rep = P()

    def local_step(state: TrainState, batch: dict):
        def loss_fn(p):
            return model.loss(p, batch, plan)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params)
        # exact reduction inside the pod (fast ICI)
        grads = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g.astype(jnp.float32), "data"), grads)
        comp = state.comp
        if has_pod:
            if compress and comp is not None:
                qs, scales, comp = ef_compress_update(grads, comp)
                # int8 payload on the slow hop; int16 accumulator is exact
                # for <= 256 pods (127 * 256 < 2^15)
                summed = jax.tree_util.tree_map(
                    lambda q: jax.lax.psum(q.astype(jnp.int16), "pod"), qs)
                grads = jax.tree_util.tree_map(
                    lambda s, sc: s.astype(jnp.float32) * sc / n_pods,
                    summed, scales)
            else:
                grads = jax.tree_util.tree_map(
                    lambda g: jax.lax.pmean(g, "pod"), grads)
        lr = schedule(state.opt.step)
        new_p, new_opt, om = adamw_update(grads, state.opt, state.params,
                                          opt_cfg, lr)
        metrics = dict(metrics)
        metrics.update(om)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, dp_axes[0]) if dp_axes else m, metrics)
        return TrainState(new_p, new_opt, comp), metrics

    state_specs = TrainState(rep, AdamWState(rep, rep, rep), rep)

    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(state_specs, batch_spec),
        out_specs=(state_specs, rep),
        check_rep=False)
    return jax.jit(smapped, donate_argnums=(0,))
