"""Training launcher: ``--arch <id>`` selects any assigned architecture.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b --steps 50 \\
      [--reduced] [--ckpt-dir DIR] [--resume] [--microbatch N]

Runs the supervised training loop (checkpoint/restart + straggler monitor)
on this host's devices.  Full-scale multi-chip configs are exercised via
``repro.launch.dryrun``; this driver actually executes, so it defaults to
the reduced same-family config unless --no-reduced is given.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.core import block_offload_pass, default_db
from repro.core.frontends import module_frontend
from repro.data import Batcher, DataConfig, SyntheticLMDataset
from repro.models import build_model
from repro.models.plan import ExecPlan
from repro.obs import trace as obs_trace
from repro.obs.log import get_logger, setup as setup_logging
from repro.optim import OptimizerConfig
from repro.optim.schedule import make_schedule
from repro.runtime.fault_tolerance import Supervisor
from repro.runtime.train import init_train_state, make_train_step

log = get_logger("launch.train")


def main() -> None:
    setup_logging()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-reduced", action="store_true",
                    help="use the FULL config (needs real accelerators)")
    ap.add_argument("--trace", default="",
                    help="write an obs trace journal to this path "
                         "(render with repro.launch.obsreport)")
    args = ap.parse_args()

    with obs_trace.maybe_tracing(args.trace or None):
        _run(args)


def _run(args) -> None:
    cfg = get_config(args.arch)
    if not args.no_reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(model.param_shapes()))
    log.info("arch=%s (%s) params=%.2fM devices=%d", args.arch,
             "full" if args.no_reduced else "reduced", n_params / 1e6,
             len(jax.devices()))

    # the paper's pipeline: pattern-DB block offload decides implementations
    block = block_offload_pass(module_frontend.build_graph(cfg), default_db())
    plan = ExecPlan(compute_dtype="float32", attn_kv_chunk=128,
                    microbatch=args.microbatch).replace(**block.plan_updates)
    log.info("offload plan: %s", block.plan_updates)

    data = SyntheticLMDataset(DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        vocab=cfg.vocab, seed=0))
    step_fn = jax.jit(make_train_step(
        model, plan, OptimizerConfig(lr=args.lr),
        make_schedule("cosine", peak_lr=args.lr, warmup_steps=10,
                      total_steps=args.steps)), donate_argnums=(0,))

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    state = init_train_state(model, jax.random.key(0))
    start = 0
    if args.resume and mgr.latest_step() is not None:
        start, state = mgr.restore(state)
        log.info("resumed from step %d", start)

    sup = Supervisor(mgr, ckpt_every=args.ckpt_every,
                     on_straggler=lambda s, dt: log.warning(
                         "straggler step %d: %.0f ms", s, dt * 1e3))
    losses: list = []

    def batch_fn(s):
        return {k: jnp.asarray(v) for k, v in data.batch(s).items()}

    def wrapped(state, batch):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if len(losses) % 10 == 0:
            log.info("step %4d  loss=%.4f", start + len(losses), losses[-1])
        return state, metrics

    state, report = sup.run(state, batch_fn, wrapped, n_steps=args.steps,
                            start_step=start)
    log.info("done: %d steps, %d restarts; loss %.4f -> %.4f",
             report.steps_done, report.restarts, losses[0],
             np.mean(losses[-5:]))


if __name__ == "__main__":
    main()
