import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "10")

"""Multi-pod dry-run: ``lower().compile()`` every (architecture x input
shape) on the production meshes, record memory_analysis / cost_analysis /
roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama_1_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--plan tuned]

Results append to experiments/dryrun/<arch>__<shape>__<mesh>.json.  The 512
placeholder devices exist ONLY in this process (XLA_FLAGS is set above,
before any jax import, and nowhere else in the repo).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro import roofline as rl
from repro.configs.base import (ALL_SHAPES, ARCH_IDS, SHAPES_BY_NAME,
                                ArchConfig, ShapeSpec, get_config)
from repro.launch.mesh import make_production_mesh
from repro.launch.plans import production_plan, tuned_plan
from repro.models.api import Model, build_model
from repro.models.plan import ExecPlan
from repro.obs import trace as obs_trace
from repro.obs.log import get_logger, setup as setup_logging
from repro.optim import OptimizerConfig, adamw_init
from repro.optim.schedule import make_schedule
from repro.runtime import sharding as shd
from repro.runtime.pspec import axis_rules
from repro.runtime.train import TrainState, jit_train_step, make_train_step

Sds = jax.ShapeDtypeStruct

log = get_logger("launch.dryrun")


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------


def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, plan: ExecPlan,
               params_dtype=None):
    """Returns (lowered, n_devices, model_flops_global)."""
    model = build_model(cfg)
    rules = shd.make_rules(mesh)
    n_dev = mesh.size
    specs = model.input_specs(shape)
    n_active = cfg.param_count(active_only=True)

    if shape.kind == "train":
        pdtype = params_dtype or jnp.float32
        param_shapes = model.param_shapes(dtype=pdtype)
        p_axes = shd.param_logical_axes(param_shapes, cfg, mesh)
        p_shard = shd.tree_shardings(rules, param_shapes, p_axes)
        opt_shapes = jax.eval_shape(adamw_init, param_shapes)
        opt_axes = type(opt_shapes)(step=(), mu=p_axes, nu=p_axes)
        o_shard = shd.tree_shardings(rules, opt_shapes, opt_axes)
        state_shardings = TrainState(p_shard, o_shard, None)
        state_shapes = TrainState(param_shapes, opt_shapes, None)
        b_axes = shd.batch_logical_axes(specs)
        b_shard = shd.tree_shardings(rules, specs, b_axes)
        step = jit_train_step(
            model, plan, OptimizerConfig(),
            make_schedule(total_steps=10_000), rules,
            state_shardings, b_shard)
        lowered = step.lower(state_shapes, specs)
        mf = rl.model_flops_train(n_active, shape.tokens)
        return lowered, n_dev, mf

    # serving paths use bf16 params
    pdtype = params_dtype or jnp.bfloat16
    param_shapes = model.param_shapes(dtype=pdtype)
    p_axes = shd.param_logical_axes(param_shapes, cfg, mesh)
    p_shard = shd.tree_shardings(rules, param_shapes, p_axes)

    if shape.kind == "prefill":
        b_axes = shd.batch_logical_axes(specs)
        b_shard = shd.tree_shardings(rules, specs, b_axes)

        def prefill(p, inp):
            with axis_rules(rules):
                return model.prefill(p, inp, plan, cache_capacity=shape.seq_len)

        # shard the produced decode state (esp. KV caches) like decode's input
        out_state = jax.eval_shape(prefill, param_shapes, specs)[1]
        st_axes = shd.state_logical_axes(out_state, cfg, mesh)
        st_shard = shd.tree_shardings(rules, out_state, st_axes)
        lowered = jax.jit(prefill, in_shardings=(p_shard, b_shard),
                          out_shardings=(None, st_shard)).lower(
            param_shapes, specs)
        mf = rl.model_flops_infer(n_active, shape.tokens)
        return lowered, n_dev, mf

    # decode: one token against a seq_len cache
    state_specs = specs["state"]
    s_axes = shd.state_logical_axes(state_specs, cfg, mesh)
    s_shard = shd.tree_shardings(rules, state_specs, s_axes)
    tok_shard = shd.tree_shardings(
        rules, specs["token"], shd.batch_logical_axes(specs["token"]))

    def decode(p, tok, st):
        with axis_rules(rules):
            return model.decode(p, tok, st, plan)

    lowered = jax.jit(
        decode, in_shardings=(p_shard, tok_shard, s_shard),
        out_shardings=(None, s_shard),
        donate_argnums=(2,)).lower(param_shapes, specs["token"], state_specs)
    mf = rl.model_flops_infer(n_active, shape.global_batch)
    return lowered, n_dev, mf


# ---------------------------------------------------------------------------
# run + record
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             plan_kind: str = "production", out_dir: str = "experiments/dryrun",
             verbose: bool = True) -> dict:
    setup_logging()          # idempotent — run_cell is also a library entry
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "plan": plan_kind, "status": "skip", "ts": time.time(),
    }
    if not cfg.supports_shape(shape):
        rec["skip_reason"] = cfg.skip_reason(shape)
        _write(rec, out_dir)
        if verbose:
            log.info("[skip] %s x %s: %s", arch, shape_name,
                     rec["skip_reason"])
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        plan = (tuned_plan if plan_kind == "tuned" else production_plan)(cfg, shape)
        t0 = time.time()
        lowered, n_dev, mf = lower_cell(cfg, shape, mesh, plan)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        log.info("%s", mem)   # proves it fits (per-device bytes)
        ca = compiled.cost_analysis()
        log.info("%s", {k: ca[k] for k in ("flops", "bytes accessed")
                        if k in ca})
        roof = rl.analyze(compiled, compiled.as_text(), n_dev,
                          model_flops_global=mf)
        live = (getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "generated_code_size_in_bytes", 0))
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
                "live_bytes": live,
                "fits_16gb": bool(live <= 16e9),
            },
            "roofline": roof.summary(),
            "collectives": roof.histogram,
            "xla_cost_analysis": {k: float(ca[k]) for k in
                                  ("flops", "bytes accessed") if k in ca},
        })
        if verbose:
            s = roof.summary()
            log.info("[ok] %s x %s x %s: live=%.2fGB compute=%.2fms "
                     "memory=%.2fms collective=%.2fms dominant=%s "
                     "roofline_frac=%.3f",
                     arch, shape_name, mesh_name, live / 1e9,
                     s["compute_s"] * 1e3, s["memory_s"] * 1e3,
                     s["collective_s"] * 1e3, s["dominant"],
                     s["roofline_fraction"])
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            log.error("[ERROR] %s x %s x %s: %s", arch, shape_name,
                      mesh_name, rec["error"][:300])
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{rec['plan']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    setup_logging()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--plan", default="production",
                    choices=["production", "tuned"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already recorded ok/skip")
    ap.add_argument("--trace", default="",
                    help="write an obs trace journal to this path "
                         "(render with repro.launch.obsreport)")
    args = ap.parse_args()

    with obs_trace.maybe_tracing(args.trace or None):
        _run(args)


def _run(args) -> None:
    archs = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if args.all or not args.shape \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    # cheap kinds first so failures surface early; single-pod before multi-pod
    shape_order = {"decode_32k": 0, "prefill_32k": 1, "long_500k": 2, "train_4k": 3}
    cells = [(mp, shape_order.get(sh, 9), arch, sh)
             for mp in meshes for sh in shapes for arch in archs]
    cells.sort()

    n_ok = n_err = n_skip = 0
    for mp, _, arch, shape in cells:
        if args.resume:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            p = os.path.join(args.out,
                             f"{arch}__{shape}__{mesh_name}__{args.plan}.json")
            if os.path.exists(p):
                try:
                    with open(p) as f:
                        old = json.load(f)
                    if old.get("status") in ("ok", "skip"):
                        n_ok += old["status"] == "ok"
                        n_skip += old["status"] == "skip"
                        continue
                except (json.JSONDecodeError, OSError):
                    pass
        rec = run_cell(arch, shape, mp, args.plan, args.out)
        n_ok += rec["status"] == "ok"
        n_err += rec["status"] == "error"
        n_skip += rec["status"] == "skip"
    log.info("done: ok=%d error=%d skip=%d", n_ok, n_err, n_skip)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
