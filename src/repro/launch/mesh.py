"""Production meshes.

Single pod: 16x16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the ``pod`` axis
carries pure DP (gradient all-reduce only — the slow DCN/ICI hop that the
compression path targets).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"))
