"""Production meshes.

Single pod: 16x16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the ``pod`` axis
carries pure DP (gradient all-reduce only — the slow DCN/ICI hop that the
compression path targets).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import functools

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"))


@functools.lru_cache(maxsize=None)
def make_destination_mesh(n: int, axis: str = "data"):
    """The mesh behind one ``MeshDestination`` gene: ``n`` devices on a
    single named axis.  Cached per (n, axis) — the device set is fixed for
    the process, and the substitution engine asks for the same mesh once
    per sharded site."""
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(f"mesh destination wants {n} devices, "
                         f"host has {len(devices)}")
    return jax.make_mesh((n,), (axis,))
