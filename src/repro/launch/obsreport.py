"""Trace/metrics report: render a ``OffloadConfig.trace`` JSONL for humans.

  PYTHONPATH=src python -m repro.launch.obsreport /tmp/plan_trace.jsonl

Prints an indented span-tree timeline — one line per span with its offset
from the root, duration, share of the root's wall time and key attributes —
a coverage line per root (how much of the root's wall its direct children
account for), and the metrics snapshot the tracer appended on close.
Reads only the JSONL; nothing here touches jax or the planning stack.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Optional

from repro.obs.trace import read_trace

__all__ = ["render", "render_metrics", "main"]

_NAME_COL = 46


def _short(value: Any, limit: int = 24) -> str:
    s = str(value)
    return s if len(s) <= limit else s[:limit - 1] + "…"


def _attr_str(span: dict, max_attrs: int = 4) -> str:
    attrs = span.get("attrs") or {}
    shown = list(attrs.items())[:max_attrs]
    out = " ".join(f"{k}={_short(v)}" for k, v in shown)
    if len(attrs) > max_attrs:
        out += f" (+{len(attrs) - max_attrs})"
    return out


def render(spans: list, metrics: Optional[dict] = None) -> str:
    """The report as one string (the CLI prints it; tests assert on it)."""
    lines: list[str] = []
    by_id = {s["id"]: s for s in spans}
    children: dict[int, list] = {}
    roots: list = []
    for s in spans:
        parent = s.get("parent")
        if parent is not None and parent in by_id:
            children.setdefault(parent, []).append(s)
        else:
            roots.append(s)
    for kids in children.values():
        kids.sort(key=lambda s: s["t0"])
    roots.sort(key=lambda s: s["t0"])

    trace_ids = sorted({s.get("trace", "?") for s in spans})
    lines.append(f"trace {', '.join(trace_ids) or '-'}  "
                 f"spans={len(spans)} roots={len(roots)}")

    def walk(span: dict, depth: int, root_t0: float, root_dur: float) -> None:
        name = "  " * depth + span["name"]
        offset_ms = (span["t0"] - root_t0) * 1e3
        dur_ms = span["dur_s"] * 1e3
        pct = 100.0 * span["dur_s"] / root_dur if root_dur > 0 else 0.0
        lines.append(f"{name:<{_NAME_COL}} +{offset_ms:9.2f}ms "
                     f"{dur_ms:10.2f}ms {pct:5.1f}%  {_attr_str(span)}")
        for child in children.get(span["id"], ()):
            walk(child, depth + 1, root_t0, root_dur)

    for root in roots:
        lines.append("")
        walk(root, 0, root["t0"], root["dur_s"])
        kids = children.get(root["id"], ())
        if kids and root["dur_s"] > 0:
            covered = sum(c["dur_s"] for c in kids)
            lines.append(
                f"coverage: {len(kids)} direct children "
                f"({', '.join(sorted({c['name'] for c in kids}))}) account "
                f"for {100.0 * covered / root['dur_s']:.1f}% of "
                f"{root['name']} wall")
    if metrics:
        lines.append("")
        lines.append(render_metrics(metrics))
    return "\n".join(lines)


def render_metrics(snapshot: dict) -> str:
    """The metrics snapshot, one line per series."""
    lines = ["metrics:"]
    for name in sorted(snapshot):
        fam = snapshot[name]
        for series in fam.get("series", ()):
            labels = series.get("labels") or {}
            tag = name + ("{" + ",".join(f"{k}={v}" for k, v in
                                         sorted(labels.items())) + "}"
                          if labels else "")
            if fam.get("kind") == "histogram":
                val = (f"count={series.get('count')} "
                       f"sum={series.get('sum', 0.0):.6g} "
                       f"mean={series.get('mean', 0.0):.6g}")
            else:
                val = f"{series.get('value', 0.0):.6g}"
            lines.append(f"  {tag:<52} {fam.get('kind', '?'):<10} {val}")
    return "\n".join(lines)


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        description="render an offload trace JSONL as a span-tree timeline")
    ap.add_argument("trace", help="trace file written via OffloadConfig.trace")
    ap.add_argument("--json", action="store_true",
                    help="dump the parsed spans + metrics as JSON instead")
    args = ap.parse_args(argv)
    spans, metrics = read_trace(args.trace)
    try:
        if args.json:
            print(json.dumps({"spans": spans, "metrics": metrics}, indent=1))
        else:
            print(render(spans, metrics))
    except BrokenPipeError:            # | head is a fine way to read a trace
        return 0
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
