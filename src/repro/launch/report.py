"""Render EXPERIMENTS.md tables from the dry-run records.

  PYTHONPATH=src python -m repro.launch.report [--plan production] [--mesh pod16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir="experiments/dryrun", plan="production"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"*__{plan}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def render(recs, mesh="pod16x16"):
    rows = []
    hdr = ("| arch | shape | fits16G | compute ms | memory ms | coll ms | "
           "dominant | step ms | useful | roofline |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted([r for r in recs if r["mesh"] == mesh],
                    key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | skip | — | — | — |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | ERR | | | | | | | |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{'Y' if r['memory']['fits_16gb'] else 'N'} | "
            f"{fmt_ms(ro['compute_s'])} | {fmt_ms(ro['memory_s'])} | "
            f"{fmt_ms(ro['collective_s'])} | {ro['dominant']} | "
            f"{fmt_ms(ro['step_s'])} | {ro['useful_flops_ratio']:.2f} | "
            f"{ro['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def summary(recs):
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    err = [r for r in recs if r["status"] == "error"]
    by_dom = {}
    for r in ok:
        by_dom[r["roofline"]["dominant"]] = by_dom.get(r["roofline"]["dominant"], 0) + 1
    fit = sum(1 for r in ok if r["memory"]["fits_16gb"])
    return (f"cells ok={len(ok)} skip={len(skip)} err={len(err)}; "
            f"fits 16GB: {fit}/{len(ok)}; dominant: {by_dom}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan", default="production")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.out, args.plan)
    print(summary(recs))
    print()
    print(render(recs, args.mesh))


if __name__ == "__main__":
    main()
