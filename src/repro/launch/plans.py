"""Per-(arch x shape) production ExecPlans.

The *baseline* production plan is what the offload planner's block pass
yields on every arch (all function blocks on their offloaded
implementations) with shape-dependent knobs: remat only where there is a
backward pass, chunked-vocab loss only where there is a loss, FSDP
(per-layer gather) always at production scale.

``tuned_plan`` holds the post-hillclimb overrides recorded in
EXPERIMENTS.md §Perf (kept separate so the paper-faithful baseline stays
reproducible).
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.plan import ExecPlan, OFFLOAD_PLAN


# activation-heavy archs split the global batch (grad accumulation); chosen
# from measured dry-run live-bytes (see EXPERIMENTS.md §Perf memory log)
_TRAIN_MICROBATCH = {
    "gemma_7b": 2,            # 16.4 GB -> fits with mb=2 (d_ff=24576)
    "recurrentgemma_2b": 2,   # 22.2 GB
    "rwkv6_3b": 2,            # 16.3 GB
    "llava_next_mistral_7b": 2,
    "llama4_scout_17b_a16e": 16,  # 86.5 GB at mb=1: 48L x 5120 + MoE buffers
    "olmoe_1b_7b": 4,         # dispatch buffers scale with tokens/shard
}


def production_plan(cfg: ArchConfig, shape: ShapeSpec) -> ExecPlan:
    plan = OFFLOAD_PLAN
    if shape.kind == "train":
        # remat="full": recompute whole layers in backward — the "dots"
        # policy saves (tokens, d_ff) products inside the scan, 40 GB/device
        # at train_4k scale (measured in the dry-run; see EXPERIMENTS.md).
        plan = plan.replace(remat="full", loss_impl="chunked_vocab",
                            loss_vocab_chunk=8_192,
                            attn_q_chunk=512, attn_kv_chunk=1024,
                            microbatch=_TRAIN_MICROBATCH.get(cfg.arch_id, 1))
    else:
        plan = plan.replace(remat="none", loss_impl="full",
                            attn_q_chunk=512,
                            attn_kv_chunk=2048 if shape.seq_len >= 32_768 else 1024)
    if cfg.family == "ssm":
        plan = plan.replace(wkv_chunk=64)
    if cfg.block_pattern:
        plan = plan.replace(rglru_chunk=256)
    return plan


# --- §Perf hillclimb overrides (filled in as the perf log lands) ------------

_TUNED: dict[tuple[str, str], dict] = {
    # ("arch_id", "shape_name"): {plan field: value}
    # §Perf iter 7: bf16 FSDP weight gathers (see EXPERIMENTS.md)
    ("tinyllama_1_1b", "train_4k"): {"gather_dtype": "compute"},
    ("llama4_scout_17b_a16e", "train_4k"): {"gather_dtype": "compute",
                                            "microbatch": 8},
    ("gemma_7b", "train_4k"): {"gather_dtype": "compute"},
}


def tuned_plan(cfg: ArchConfig, shape: ShapeSpec) -> ExecPlan:
    plan = production_plan(cfg, shape)
    over = _TUNED.get((cfg.arch_id, shape.name))
    return plan.replace(**over) if over else plan
