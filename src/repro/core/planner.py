"""End-to-end offload planner (paper §4.2 実装動作).

Order is the paper's: *function-block offload first* (algorithm-level
replacement beats loop-level parallelization), each matched block measured
on/off (and combinations when several match), then *loop offload by GA* over
the remaining regions; the best-measured pattern is the final solution.

Two entry points:
  * :func:`plan_python_offload` — the ast frontend, real wall-clock fitness.
  * :func:`plan_module_offload` — the module frontend, cost-model fitness at
    production scale (the caller provides the ``lower_fn`` built by the
    runtime: plan -> jax.stages.Lowered).

Measurement scheduling goes through the evaluation engine
(:mod:`repro.core.evaluator`): both entry points key a persistent
measurement cache by (graph fingerprint, measurement context) via
``GAConfig.cache_dir``, so re-planning the same program never re-measures a
known pattern.  The wall-clock path pins serial evaluation (timings on
shared hardware don't interleave); the cost-model path may parallelize
compile-bound measurements with ``GAConfig.workers`` or an external process
pool (see ``benchmarks/bench_ga_offload.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import os
import platform
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import similarity as sim
from repro.core.block_offload import BlockOffloadResult, block_offload_pass
from repro.core.fitness import CostModelFitness, WallClockFitness
from repro.core.frontends import module_frontend
from repro.core.frontends.ast_frontend import Executor, PyProgram
from repro.core.ga import Evaluation, GAConfig
from repro.core.genes import coding_from_graph
from repro.core.loop_offload import LoopOffloadResult, loop_offload_pass
from repro.core.pattern_db import PatternDB, default_db
from repro.core.transfer_planner import TransferPlan, plan_transfers
from repro.models.plan import ExecPlan

# ---------------------------------------------------------------------------
# library-call adapters for the ast frontend ("CUDA library" substitution)
# ---------------------------------------------------------------------------


def _order_by_appearance(names, source: str) -> list:
    return sorted(names, key=lambda v: source.find(v) if v in source else 1 << 30)


def _adapt_matmul(region, env, source):
    arrays_in = [v for v in region.uses - region.defs
                 if isinstance(env.get(v), np.ndarray) and env[v].ndim == 2]
    outs = [v for v in region.defs
            if isinstance(env.get(v), np.ndarray) and env[v].ndim == 2]
    arrays_in = _order_by_appearance(arrays_in, source)
    if len(arrays_in) != 2 or len(outs) != 1:
        raise ValueError("matmul adapter needs exactly (a, b) -> c")
    return (lambda a, b: jnp.matmul(a, b)), arrays_in, outs


def _adapt_fft(region, env, source):
    ins = _order_by_appearance(
        [v for v in region.uses - region.defs
         if isinstance(env.get(v), np.ndarray)], source)
    outs = _order_by_appearance(
        [v for v in region.defs if isinstance(env.get(v), np.ndarray)], source)
    if len(ins) == 2 and len(outs) == 2:    # (re, im) -> (re, im): adapt complex
        def fft2ri(re, im):
            z = jnp.fft.fft(re + 1j * im)
            return jnp.real(z), jnp.imag(z)
        return fft2ri, ins, outs
    if len(ins) == 1 and len(outs) == 1:
        return (lambda x: jnp.abs(jnp.fft.fft(x))), ins, outs
    raise ValueError("fft adapter: unsupported interface")


_AST_ADAPTERS: dict[str, Callable] = {
    "matmul": _adapt_matmul,
    "fft": _adapt_fft,
}


# ---------------------------------------------------------------------------
# python program planning
# ---------------------------------------------------------------------------


@dataclass
class PythonPlanResult:
    program: PyProgram
    block: BlockOffloadResult
    loops: LoopOffloadResult
    impl: dict                       # final region -> implementation
    lib_calls: dict
    transfer_plan: TransferPlan
    baseline_time_s: float
    block_time_s: float
    final_time_s: float
    ga_history: list = field(default_factory=list)

    @property
    def speedup(self) -> float:
        return self.baseline_time_s / self.final_time_s


def plan_python_offload(program: PyProgram, inputs: dict,
                        ga_cfg: Optional[GAConfig] = None,
                        db: Optional[PatternDB] = None,
                        confirm: Callable | bool = True,
                        repeats: int = 3,
                        log: Optional[Callable[[str], None]] = None,
                        hoist_transfers: bool = True) -> PythonPlanResult:
    db = db or default_db()
    log = log or (lambda s: None)

    # --- calibration: interpret once; snapshots + reference outputs ---------
    snaps: dict[str, dict] = {}
    ex0 = Executor(program, {}, hoist_transfers=False)
    ex0.pre_loop_hook = lambda name, env: snaps.setdefault(name, dict(env))
    env0 = ex0.run(**inputs)
    out_names = program.output_names or sorted(
        v for v in env0 if isinstance(env0[v], (np.ndarray,)))
    reference = {n: np.asarray(env0[n]) for n in out_names}
    program.check_offloadable(inputs)

    def runner(impl: dict, lib_calls: dict) -> Callable[[], dict]:
        def run():
            ex = Executor(program, impl, hoist_transfers=hoist_transfers,
                          lib_calls=lib_calls)
            env = ex.run(**inputs)
            return {n: np.asarray(env[n]) for n in out_names}
        return run

    # one fitness instance for the whole planning run (it was re-built per
    # chromosome, re-capturing the reference tree each measurement); `build`
    # reads the measurement spec staged by `timed` / the GA fitness below
    _spec: dict = {"impl": {}, "lib": {}}
    wall_fit = WallClockFitness(
        build=lambda bits: runner(_spec["impl"], _spec["lib"]),
        reference_output=reference, repeats=repeats)

    def timed(impl: dict, lib_calls: dict) -> Evaluation:
        _spec["impl"], _spec["lib"] = impl, lib_calls
        return wall_fit(())

    baseline = timed({}, {})
    log(f"baseline (all-interpreted): {baseline.time_s:.4f}s")

    # --- Step A: function-block offload (first, per paper §4.2) -------------
    block = block_offload_pass(graph=program.graph, db=db, confirm=confirm)
    candidates = {}
    for bo in block.offloads:
        adapter = _AST_ADAPTERS.get(bo.pattern)
        if adapter is None:
            continue
        envs = snaps.get(bo.region)
        if envs is None:
            continue
        try:
            candidates[bo.region] = adapter(
                program.graph.by_name(bo.region), envs, program.source)
        except ValueError as e:
            log(f"block {bo.region} ({bo.pattern}): adapter failed: {e}")

    # measure each block and combinations (paper §4.2.1)
    best_lib: dict = {}
    best_time = baseline.time_s
    keys = list(candidates)
    combos = itertools.chain.from_iterable(
        itertools.combinations(keys, r) for r in range(1, len(keys) + 1)) \
        if len(keys) <= 3 else [tuple(keys)] + [(k,) for k in keys]
    for combo in combos:
        lib = {k: candidates[k] for k in combo}
        impl = {k: "lib" for k in combo}
        ev = timed(impl, lib)
        log(f"block combo {combo}: {ev.time_s:.4f}s valid={ev.valid}")
        if ev.valid and ev.time_s < best_time:
            best_time, best_lib = ev.time_s, lib
    block_impl = {k: "lib" for k in best_lib}
    block_time = best_time

    # --- Step B: GA loop offload over the remaining loops -------------------
    claimed = set(best_lib)
    for r in program.graph.regions:      # descendants of claimed blocks too
        p_ = r.parent
        while p_ is not None:
            if p_ in claimed:
                claimed.add(r.name)
                break
            p_ = program.graph.by_name(p_).parent
    claimed = tuple(sorted(claimed))
    coding = coding_from_graph(program.graph, exclude=claimed)

    def fitness(bits: tuple) -> Evaluation:
        impl = dict(block_impl)
        impl.update(coding.decode(bits))
        _spec["impl"], _spec["lib"] = impl, best_lib
        return wall_fit(bits)

    # persistent-cache key context: wall-clock measurements are only
    # comparable for the same source, constants, input shapes AND the same
    # machine — unlike cost-model estimates, timings are not portable, so a
    # shared cache_dir must not serve one host's timings to another
    shapes = {k: getattr(v, "shape", ()) for k, v in sorted(inputs.items())}
    block_patterns = sorted((bo.region, bo.pattern) for bo in block.offloads
                            if bo.region in best_lib)
    cache_extra = (f"src={hashlib.sha256(program.source.encode()).hexdigest()[:12]}"
                   f"|consts={sorted(program.consts.items())}"
                   f"|shapes={sorted(shapes.items())}"
                   f"|block={block_patterns}"
                   f"|hoist={hoist_transfers}|repeats={repeats}"
                   f"|host={platform.node()}|ncpu={os.cpu_count()}"
                   f"|dev={jax.default_backend()}|wallclock")
    cfg_ga = ga_cfg or GAConfig()
    if cfg_ga.workers > 1:
        # wall-clock measurements interleave on shared hardware — parallel
        # timing is meaningless; only compile-bound fitness may parallelize
        log("wall-clock fitness: forcing serial evaluation (workers=0)")
        cfg_ga = dataclasses.replace(cfg_ga, workers=0)
    loops = loop_offload_pass(program.graph, fitness, cfg_ga,
                              exclude=claimed, log=log,
                              cache_extra=cache_extra)

    final_impl = dict(block_impl)
    final_impl.update(coding.decode(loops.ga.best.bits))
    tp = plan_transfers(program.graph, final_impl, hoist=hoist_transfers)
    return PythonPlanResult(
        program=program, block=block, loops=loops, impl=final_impl,
        lib_calls=best_lib, transfer_plan=tp,
        baseline_time_s=baseline.time_s, block_time_s=block_time,
        final_time_s=min(loops.ga.best.time_s, block_time),
        ga_history=loops.ga.history)


# ---------------------------------------------------------------------------
# module (model) planning
# ---------------------------------------------------------------------------


@dataclass
class ModulePlanResult:
    graph: Any
    block: BlockOffloadResult
    loops: LoopOffloadResult
    base_plan: ExecPlan
    final_plan: ExecPlan
    baseline: Evaluation
    best: Evaluation


def plan_module_offload(cfg, lower_fn: Callable[[ExecPlan], Any],
                        n_devices: int, model_flops: float = 0.0,
                        ga_cfg: Optional[GAConfig] = None,
                        db: Optional[PatternDB] = None,
                        base_plan: Optional[ExecPlan] = None,
                        hbm_budget: float = 16e9,
                        log: Optional[Callable[[str], None]] = None) -> ModulePlanResult:
    """Offload planning for an assigned architecture at production scale.

    The verification environment is the AOT compiler: each chromosome lowers
    and compiles on the production mesh, the roofline step time is its
    measured fitness, per-device HBM overflow disqualifies (time = ∞).
    """
    db = db or default_db()
    graph = module_frontend.build_graph(cfg)
    block = block_offload_pass(graph, db)
    base = (base_plan or ExecPlan()).replace(**block.plan_updates)
    exclude = block.claimed_regions

    fitness = CostModelFitness(
        lower=lambda bits: lower_fn(
            module_frontend.plan_from_bits(graph, bits, base, exclude)),
        n_devices=n_devices, model_flops=model_flops, hbm_budget=hbm_budget)

    # compile-bound fitness parallelizes safely (XLA releases the GIL), and
    # compiled step-time estimates are machine-portable — key the persistent
    # cache by architecture + mesh + scale
    cache_extra = (f"arch={cfg.arch_id}|dev={n_devices}"
                   f"|flops={model_flops:.3g}|hbm={hbm_budget:.3g}"
                   f"|base={base}|costmodel")
    loops = loop_offload_pass(graph, fitness, ga_cfg or GAConfig(), exclude,
                              log=log, cache_extra=cache_extra)
    final = module_frontend.plan_from_bits(graph, loops.ga.best.bits, base, exclude)
    return ModulePlanResult(
        graph=graph, block=block, loops=loops, base_plan=base,
        final_plan=final, baseline=loops.ga.baseline, best=loops.ga.best)
