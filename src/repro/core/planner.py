"""Legacy planner entry points — thin deprecation shims (paper §4.2 実装動作).

The real pipeline is :class:`repro.core.offload.Offloader`: one
``plan(target, inputs, config)`` for every frontend, with the paper's order
preserved inside it (*function-block offload first*, then *loop offload by
GA* over the remaining regions, best measured pattern wins).

These wrappers keep the original call signatures and result types
(:class:`PythonPlanResult`, :class:`ModulePlanResult`) for existing callers
and examples; new code should use ``Offloader.plan`` / ``plan_offload`` and
get the unified :class:`~repro.core.offload.OffloadResult` instead.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.block_offload import BlockOffloadResult
from repro.core.frontends.ast_frontend import PyProgram
from repro.core.frontends.registry import OffloadConfig
from repro.core.ga import Evaluation, GAConfig
from repro.core.loop_offload import LoopOffloadResult
from repro.core.offload import Offloader
from repro.core.pattern_db import PatternDB
from repro.core.transfer_planner import TransferPlan
from repro.models.plan import ExecPlan


def _deprecated(old: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use repro.core.offload.Offloader.plan "
        f"(one entry point for every frontend, unified OffloadResult)",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# python program planning
# ---------------------------------------------------------------------------


@dataclass
class PythonPlanResult:
    program: PyProgram
    block: BlockOffloadResult
    loops: LoopOffloadResult
    impl: dict                       # final region -> implementation
    lib_calls: dict
    transfer_plan: TransferPlan
    baseline_time_s: float
    block_time_s: float
    final_time_s: float
    ga_history: list = field(default_factory=list)

    @property
    def speedup(self) -> float:
        return self.baseline_time_s / self.final_time_s


def plan_python_offload(program: PyProgram, inputs: dict,
                        ga_cfg: Optional[GAConfig] = None,
                        db: Optional[PatternDB] = None,
                        confirm: Callable | bool = True,
                        repeats: int = 3,
                        log: Optional[Callable[[str], None]] = None,
                        hoist_transfers: bool = True) -> PythonPlanResult:
    """Deprecated shim over ``Offloader.plan`` (ast frontend, wall clock)."""
    _deprecated("plan_python_offload")
    cfg = OffloadConfig(
        frontend="python_ast", ga=ga_cfg or GAConfig(), db=db,
        confirm=confirm, repeats=repeats, hoist_transfers=hoist_transfers,
        log=log)
    res = Offloader(cfg).plan(program, inputs)
    block_time = res.details.get("block_time_s", res.baseline.time_s)
    # legacy contract: lib_calls holds (callable, in_names, out_names)
    # triples for CLAIMED blocks only — variant-site menus (regions still in
    # the gene) are a PR-4 concept the old result type never had; their
    # decoded winners are visible through `impl` / the new OffloadResult
    legacy_lib = {r: entry["lib"]
                  for r, entry in res.details.get("lib_calls", {}).items()
                  if isinstance(entry, dict) and "lib" in entry}
    return PythonPlanResult(
        program=res.details["program"], block=res.block,
        loops=LoopOffloadResult(res.coding, res.ga),
        impl=res.pattern, lib_calls=legacy_lib,
        transfer_plan=res.transfer_plan,
        baseline_time_s=res.baseline.time_s, block_time_s=block_time,
        final_time_s=min(res.ga.best.time_s, block_time),
        ga_history=res.ga.history)


# ---------------------------------------------------------------------------
# module (model) planning
# ---------------------------------------------------------------------------


@dataclass
class ModulePlanResult:
    graph: Any
    block: BlockOffloadResult
    loops: LoopOffloadResult
    base_plan: ExecPlan
    final_plan: ExecPlan
    baseline: Evaluation
    best: Evaluation


def plan_module_offload(cfg, lower_fn: Callable[[ExecPlan], Any],
                        n_devices: int, model_flops: float = 0.0,
                        ga_cfg: Optional[GAConfig] = None,
                        db: Optional[PatternDB] = None,
                        base_plan: Optional[ExecPlan] = None,
                        hbm_budget: float = 16e9,
                        log: Optional[Callable[[str], None]] = None
                        ) -> ModulePlanResult:
    """Deprecated shim over ``Offloader.plan`` (module frontend, AOT cost
    model at production scale)."""
    _deprecated("plan_module_offload")
    ocfg = OffloadConfig(
        frontend="module", ga=ga_cfg or GAConfig(), db=db, log=log,
        options={"lower_fn": lower_fn, "n_devices": n_devices,
                 "model_flops": model_flops, "hbm_budget": hbm_budget,
                 "base_plan": base_plan})
    res = Offloader(ocfg).plan(cfg)
    return ModulePlanResult(
        graph=res.graph, block=res.block,
        loops=LoopOffloadResult(res.coding, res.ga),
        base_plan=res.details["base_plan"], final_plan=res.artifact,
        baseline=res.ga.baseline, best=res.best)
