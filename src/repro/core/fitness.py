"""Fitness evaluators: the "verification environment" measurements.

Two measurement backends, both measuring real artifacts (the paper's
anti-static-prediction stance, §3.1):

* :class:`WallClockFitness` — execute and time (min over repeats after a
  warm-up compile), verify results against the reference path (PCAST
  analogue) -> invalid = time ∞.
* :class:`CostModelFitness` — AOT ``lower().compile()`` at production scale
  on the production mesh; the measured artifact is the compiled binary:
  roofline step time as the objective, per-device HBM fit as the validity
  check (OOM -> time ∞, like a compile error in the paper).

Both are plain ``bits -> Evaluation`` callables; caching, dedup, parallel
dispatch and persistence belong to :mod:`repro.core.evaluator`, not here.
``CostModelFitness`` holds no mutable state across calls and is safe to
invoke from evaluator worker threads/processes; ``WallClockFitness`` timings
only mean something when measured one at a time (keep ``workers=0``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.ga import Evaluation
from repro.core.verifier import verify
from repro import roofline as rl


# ---------------------------------------------------------------------------
# wall-clock fitness (smoke scale, real execution)
# ---------------------------------------------------------------------------


@dataclass
class PreparedRun:
    """Output of :meth:`WallClockFitness.prepare`: a compiled, verified
    runner awaiting its (strictly serial) timing loop — or the failure
    Evaluation that takes its place."""

    bits: tuple
    runner: Optional[Callable[[], Any]] = None
    failure: Optional[Evaluation] = None   # build/compile/verify outcome


@dataclass
class WallClockFitness:
    """bits -> build(bits) -> callable; timed and verified vs reference.

    Two-phase: :meth:`prepare` does everything that need not be serial —
    build the artifact, run the warm-up (compilation; releases the GIL
    inside XLA), verify against the reference — and :meth:`measure` runs
    the timing loop, which only means something measured one at a time.
    ``__call__`` chains them (the historical serial behavior); the
    evaluation engine overlaps different chromosomes' ``prepare`` calls
    ahead of a serial ``measure`` pass (``Evaluator.compile_workers``).
    ``build`` must therefore be safe to invoke from concurrent threads
    (every shipped builder constructs a fresh runner per call).
    """

    build: Callable[[tuple], Callable[[], Any]]   # returns a nullary runner
    reference_output: Any = None                  # captured from all-off if None
    repeats: int = 3
    rtol: float = 1e-2
    atol: float = 1e-2
    verify_outputs: bool = True

    def prepare(self, bits: tuple) -> PreparedRun:
        bits = tuple(bits)
        try:
            runner = self.build(bits)
            out = runner()                        # warm-up (compilation)
            out = jax.tree_util.tree_map(
                lambda x: np.asarray(x) if hasattr(x, "dtype") else x, out)
        except Exception as e:  # noqa: BLE001 — paper: errors leave the GA
            return PreparedRun(bits, failure=Evaluation(
                bits, float("inf"), False,
                {"error": f"{type(e).__name__}: {e}"[:300]}))
        if self.verify_outputs and self.reference_output is not None:
            v = verify(self.reference_output, out, self.rtol, self.atol)
            if not v.ok:
                return PreparedRun(bits, failure=Evaluation(
                    bits, float("inf"), False,
                    {"verify": f"max_abs={v.max_abs:.3g} "
                               f"max_rel={v.max_rel:.3g} {v.detail}"}))
        return PreparedRun(bits, runner=runner)

    def measure(self, prepared: PreparedRun) -> Evaluation:
        if prepared.failure is not None:
            return prepared.failure
        best = float("inf")
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            out2 = prepared.runner()
            jax.tree_util.tree_map(
                lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
                out2)
            best = min(best, time.perf_counter() - t0)
        return Evaluation(prepared.bits, best, True, {})

    def __call__(self, bits: tuple) -> Evaluation:
        return self.measure(self.prepare(bits))


# ---------------------------------------------------------------------------
# cost-model fitness (production scale, AOT compile + roofline)
# ---------------------------------------------------------------------------


@dataclass
class CostModelFitness:
    """bits -> lower/compile -> roofline step time; OOM/compile error = ∞.

    ``lower`` maps bits to a jax.stages.Lowered (the caller owns mesh,
    shardings and input specs).  ``hbm_budget`` is per-device bytes.
    """

    lower: Callable[[tuple], Any]
    n_devices: int
    model_flops: float = 0.0
    hbm_budget: float = 16e9          # TPU v5e: 16 GB

    def __call__(self, bits: tuple) -> Evaluation:
        try:
            lowered = self.lower(bits)
            compiled = lowered.compile()
        except Exception as e:  # noqa: BLE001
            return Evaluation(bits, float("inf"), False,
                              {"error": f"{type(e).__name__}: {e}"[:300]})
        try:
            mem = compiled.memory_analysis()
            live = (getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)
                    + getattr(mem, "generated_code_size_in_bytes", 0))
        except Exception:  # pragma: no cover — backend without memory stats
            mem, live = None, 0
        roof = rl.analyze(compiled, compiled.as_text(), self.n_devices,
                          model_flops_global=self.model_flops)
        detail = {"roofline": roof.summary(), "live_bytes": live}
        if live > self.hbm_budget:
            return Evaluation(bits, float("inf"), False,
                              {**detail, "error": f"OOM: {live/1e9:.2f} GB "
                                                  f"> {self.hbm_budget/1e9:.0f} GB"})
        return Evaluation(bits, roof.step_s, True, detail)
