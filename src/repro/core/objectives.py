"""Objective models for multi-objective offload search (arXiv:2110.11520 +
arXiv:2011.12431 direction): latency × energy × transfer bytes.

The paper's follow-on work evaluates *power saving* and *mixed offload
destinations* as the production goal, so the GA needs more than a wall-clock
scalar.  This module defines the objective vector the NSGA selection in
:func:`repro.core.ga.run_ga` ranks by:

* ``latency``  — the measured (or cost-modeled) seconds, unchanged: the
  :class:`~repro.core.ga.Evaluation`'s ``time_s``.
* ``energy``   — joules.  When the fitness measured real board power (an
  ``energy_j`` detail field, e.g. from NVML — :func:`nvml_power_w` probes
  for it) that number wins; otherwise a deterministic *modeled* estimate:
  the chromosome's execution seconds split across destinations by static
  trip share, each share charged that destination's
  ``Destination.active_power_w`` prior, plus the cost-only stub's modeled
  seconds at the stub's watts.  The priors differ per destination (GPU hot,
  FPGA stub cool, CPU in between), so mixed-destination Pareto fronts exist
  on CPU-only CI where every measurement runs on the same silicon.
* ``transfer`` — static transfer volume in bytes from the transfer planner
  (per-variable bytes × dynamic trip products), the paper's
  CPU↔accelerator round-trip penalty as its own axis.

Energy and transfer are pure functions of ``(bits, time_s)``, so journal
rows that predate this module (no per-objective detail fields) degrade
gracefully: the objective function recomputes the modeled values on the fly
and only the latency axis relies on what was persisted.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence

from repro.core.ga import Evaluation
# DEFAULT_ACTIVE_POWER_W lives with the Destination hierarchy now;
# re-exported here because it is this module's historical home.
from repro.core.genes import (DEFAULT_ACTIVE_POWER_W, GeneCoding,
                              MeshDestination, _trip_product, get_destination,
                              site_modeled_cost_s)
from repro.core.ir import RegionGraph
from repro.core.transfer_planner import collective_factor, plan_transfers

__all__ = ["OBJECTIVES", "annotate_objectives", "make_objective_fn",
           "modeled_energy_j", "nvml_power_w", "objective_values",
           "static_transfer_bytes"]

#: the canonical objective order: index 0 is always latency (the GA's
#: patience/history axis and the single-objective fallback).
OBJECTIVES: tuple[str, ...] = ("latency", "energy", "transfer")

_nvml_watts: Optional[float] = None
_nvml_probed = False


def nvml_power_w() -> Optional[float]:
    """Current GPU board power draw in watts via NVML, or None when no NVML
    stack (or no GPU) is available.  The import is gated — the container
    may not ship ``pynvml``, and a CPU-only host has nothing to read — so
    the modeled per-destination priors are the portable default."""
    global _nvml_watts, _nvml_probed
    if _nvml_probed:
        return _nvml_watts
    _nvml_probed = True
    try:  # pragma: no cover — exercised only on NVML-equipped hosts
        import pynvml
        pynvml.nvmlInit()
        handle = pynvml.nvmlDeviceGetHandleByIndex(0)
        _nvml_watts = pynvml.nvmlDeviceGetPowerUsage(handle) / 1000.0
    except Exception:  # noqa: BLE001 — any missing piece means "no NVML"
        _nvml_watts = None
    return _nvml_watts


def destination_power_w(name: str) -> float:
    """Active watts prior for one destination: ``Destination.watts()`` — the
    per-device prior times the device count, so an n-mesh draws n boards'
    worth.  NVML (when present) overrides the per-device prior for
    accelerator destinations — measured board power beats a table — while
    the reference path and cost-only stubs keep their modeled priors (NVML
    says nothing about them)."""
    dest = get_destination(name)
    prior = dest.watts()
    if not dest.is_cost_only and dest.impl_index > 0:
        measured = nvml_power_w()
        if measured is not None and measured > 0:
            return measured * dest.device_count
    return prior


def modeled_energy_j(graph: RegionGraph, coding: GeneCoding,
                     bits: Sequence[int], time_s: float) -> float:
    """Deterministic joules for one chromosome given its (charged) seconds.

    The stub's modeled seconds (already folded into ``time_s`` by the
    destination-cost fitness wrapper) are billed at the stub's watts; the
    remaining execution seconds are split across destinations by static
    trip share — each site's trip product weights its destination's
    ``active_power_w``, reference/claimed work weights the CPU — so a
    chromosome that parks heavy trips on a hot device pays for it even
    though CPU-only CI measured every pattern on the same silicon.
    """
    if not math.isfinite(time_s) or time_s < 0:
        return float("inf")
    bits = tuple(int(v) for v in bits)
    claimed = coding.claimed_members(bits)
    stub_s_total = 0.0
    stub_j = 0.0
    # trip-share watt mix of the executable seconds; weight 1.0 of host
    # work exists in every chromosome (dispatch, glue, unsited regions)
    watt_weight = destination_power_w(coding.destinations[0]) * 1.0
    weight = 1.0
    for site, v in zip(coding.sites, bits):
        dest = get_destination(coding.destinations[int(v)])
        region = graph.by_name(site.region)
        trips = float(_trip_product(graph, region))
        if site.region in claimed:
            continue                      # the block adapter's work is
                                          # counted by the block gene's site
        if dest.is_cost_only:
            # stub devices and unavailable meshes: the modeled seconds
            # (already folded into time_s by the destination-cost fitness
            # wrapper) bill at the destination's full draw — per-device
            # watts × device count for meshes (ISSUE: energy = watts × n)
            site_s = site_modeled_cost_s(graph, region, dest)
            stub_s_total += site_s
            stub_j += site_s * dest.watts()
            continue
        weight += trips
        watt_weight += trips * destination_power_w(dest.name)
    exec_s = max(time_s - stub_s_total, 0.0)
    return exec_s * (watt_weight / weight) + stub_j


def static_transfer_bytes(graph: RegionGraph, coding: GeneCoding,
                          bits: Sequence[int],
                          var_bytes: Optional[dict] = None,
                          base_impl: Optional[dict] = None) -> float:
    """Transfer volume of one chromosome: planner transfers weighted by
    per-variable bytes and dynamic trip products (per-iteration transfers
    pay every trip — the round-trip penalty).  Same accounting as the
    surrogate's ``bytes`` feature, exposed as an objective.

    Mesh placements change the accounting in two directions: each host<->
    device transfer splits across the mesh's n links (``Transfer.shards``
    divides its volume — the per-link bytes are what the PCIe round-trip
    penalty prices), while the axis's collective adds
    ``collective_factor(axis, n)`` times the region's output bytes per
    trip.  Sharding a transfer-heavy region can therefore *win* this axis
    over a single device — the trade-off the Pareto front exposes."""
    bits = tuple(int(v) for v in bits)
    impl = dict(base_impl or {})
    impl.update(coding.decode(bits))
    dests = coding.destinations_of(bits)
    plan = plan_transfers(graph, impl, hoist=True, destinations=dests)
    vb = var_bytes or {}
    total = 0.0
    for t in plan.transfers:
        trips = 1
        if t.per_iteration:
            trips = _trip_product(graph, graph.by_name(t.at_region))
        total += trips * float(vb.get(t.var, 1.0)) / max(t.shards, 1)
    claimed = coding.claimed_members(bits)
    for site in coding.sites:
        dest = get_destination(dests[site.region])
        if not isinstance(dest, MeshDestination) or site.region in claimed:
            continue
        region = graph.by_name(site.region)
        out_bytes = sum(float(vb.get(v, 1.0)) for v in region.defs)
        total += (_trip_product(graph, region)
                  * collective_factor(dest.axis, dest.n) * out_bytes)
    return total


def objective_values(ev: Evaluation, graph: RegionGraph, coding: GeneCoding,
                     objectives: Sequence[str] = OBJECTIVES,
                     var_bytes: Optional[dict] = None,
                     base_impl: Optional[dict] = None) -> tuple[float, ...]:
    """One evaluation's objective vector, smaller-is-better on every axis.

    Detail fields win when the measurement recorded them (``energy_j`` from
    a power-instrumented fitness, ``transfer_bytes`` stamped at annotation
    time); anything missing is recomputed from the models above, so legacy
    journal rows degrade to latency-plus-modeled instead of being dropped.
    Invalid/non-finite evaluations map to all-``inf`` (dominated by every
    real point, mutually non-dominating)."""
    if not ev.valid or not math.isfinite(ev.time_s):
        return tuple(float("inf") for _ in objectives)
    out = []
    for name in objectives:
        if name == "latency":
            v = ev.time_s
        elif name == "energy":
            v = ev.detail.get("energy_j")
            if not isinstance(v, (int, float)) or not math.isfinite(v):
                v = modeled_energy_j(graph, coding, ev.bits, ev.time_s)
        elif name == "transfer":
            v = ev.detail.get("transfer_bytes")
            if not isinstance(v, (int, float)) or not math.isfinite(v):
                v = static_transfer_bytes(graph, coding, ev.bits,
                                          var_bytes=var_bytes,
                                          base_impl=base_impl)
        else:
            raise ValueError(f"unknown objective {name!r}; "
                             f"known: {OBJECTIVES}")
        out.append(float(v) if math.isfinite(float(v)) else float("inf"))
    return tuple(out)


def make_objective_fn(graph: RegionGraph, coding: GeneCoding,
                      objectives: Sequence[str] = OBJECTIVES,
                      var_bytes: Optional[dict] = None,
                      base_impl: Optional[dict] = None
                      ) -> Callable[[Evaluation], tuple[float, ...]]:
    """Bind :func:`objective_values` for the GA's NSGA selection (and for
    :meth:`OffloadResult.front_summary`).  The static per-bits terms
    (transfer plan, trip products) are memoized per chromosome."""
    objectives = tuple(objectives)
    memo: dict[tuple[tuple, float, bool], tuple[float, ...]] = {}

    def fn(ev: Evaluation) -> tuple[float, ...]:
        key = (tuple(int(v) for v in ev.bits), float(ev.time_s), ev.valid)
        hit = memo.get(key)
        if hit is None:
            hit = objective_values(ev, graph, coding, objectives,
                                   var_bytes=var_bytes, base_impl=base_impl)
            memo[key] = hit
        return hit

    return fn


def annotate_objectives(graph: RegionGraph, coding: GeneCoding,
                        var_bytes: Optional[dict] = None,
                        base_impl: Optional[dict] = None
                        ) -> Callable[[Evaluation], Evaluation]:
    """An :class:`~repro.core.evaluator.Evaluator` ``annotate`` hook that
    stamps ``energy_j`` / ``transfer_bytes`` into every new measurement's
    detail dict.  The measurement journal persists scalar detail fields, so
    rows written under this hook carry per-objective ground truth the
    per-objective surrogate fits train on; fields already present (a
    power-measuring fitness) are never overwritten."""

    def ann(ev: Evaluation) -> Evaluation:
        if not ev.valid or not math.isfinite(ev.time_s):
            return ev
        det = dict(ev.detail)
        if "energy_j" not in det:
            det["energy_j"] = modeled_energy_j(graph, coding, ev.bits,
                                               ev.time_s)
        if "transfer_bytes" not in det:
            det["transfer_bytes"] = static_transfer_bytes(
                graph, coding, ev.bits, var_bytes=var_bytes,
                base_impl=base_impl)
        return Evaluation(ev.bits, ev.time_s, ev.valid, det)

    return ann
