"""Batched-parallel GA evaluation engine (the "verification environment"
scheduler).

The paper measures every offload pattern in a real verification environment
(compile + run), which makes measurement the search bottleneck.  Yamato's
follow-up work (arXiv:2002.12115) attacks exactly this: reduce the *number*
of verification measurements (dedup, duplicate-avoiding offspring) and their
*cost* (reuse across runs).  This module is that subsystem:

* **generation-batched, parallel evaluation** — the whole offspring
  population is deduped against the cache and dispatched to a thread pool
  (compile-bound fitness like :class:`repro.core.fitness.CostModelFitness`
  releases the GIL inside XLA; wall-clock fitness should stay serial for
  timing fidelity, ``workers=0``), with *in-flight dedup* so identical
  chromosomes proposed concurrently are measured once;

* a **persistent on-disk measurement cache** keyed by
  ``(program fingerprint, bits)`` so re-planning the same program across
  processes or benchmark runs never re-measures a known pattern;

* an optional **surrogate pre-screen**: offspring are ranked by a cost
  estimate (the static transfer-cost formula below, or a journal-fitted
  :class:`repro.core.surrogate.FittedSurrogate`) and only the most
  promising ``screen_top_k`` are measured per generation.  Measurement
  stays the final arbiter — the surrogate only prioritizes, it never
  scores a chromosome (the paper's anti-static-prediction stance);

* a **compile-parallel / time-serial phase** for two-phase fitness
  functions (:class:`repro.core.fitness.WallClockFitness` and anything
  else exposing ``prepare(bits)`` / ``measure(prepared)``): when the
  timing loop must stay serial (``workers <= 1``), per-chromosome warm-up
  compiles — ``engine.substitute()`` + ``jax.jit`` tracing, which release
  the GIL inside XLA — are dispatched concurrently on ``compile_workers``
  threads *ahead* of the strictly serial timing loop, so a generation pays
  max(compile) instead of sum(compile).  :class:`EvalStats` reports the
  wall-clock saved.

The engine is deterministic: results are returned in population order and a
fixed-seed GA run produces byte-identical results in serial and parallel
modes (fitness functions themselves must be deterministic for this to hold,
which is true of the cost-model path).
"""
from __future__ import annotations

import dataclasses
import math
import os
import threading
import time
from concurrent.futures import Future, ProcessPoolExecutor, ThreadPoolExecutor
from concurrent.futures import wait as _wait_futures
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

from repro.core.ga import Evaluation
from repro.core.journal import Journal, file_lock, newest_per_key
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = ["EvalStats", "Evaluator", "ProcessPool", "transfer_cost_surrogate",
           "register_fitness_factory", "fitness_factory",
           "fitness_factory_names", "record_search_meta", "last_rank_corr"]

#: backcompat alias — the sidecar-flock helper now lives in
#: :mod:`repro.core.journal` so every record stream (seed bank, search meta,
#: surrogate fits, measurements, plan store) shares one code path.
_file_lock = file_lock


# ---------------------------------------------------------------------------
# persistent measurement cache
# ---------------------------------------------------------------------------


def _bits_key(bits: Sequence[int]) -> str:
    return "".join(str(int(b)) for b in bits) or "-"


#: default per-fingerprint measurement-journal bound.  A long-lived planning
#: service replays GA refinement against the same fingerprint indefinitely;
#: newest-per-bits compaction past 2x this bound (the seed bank's policy)
#: keeps journals finite without ever discarding the latest measurement of a
#: pattern.
_MEASUREMENTS_MAX_RECORDS = 2048


class MeasurementCache:
    """On-disk (fingerprint, bits) -> Evaluation store, one JSONL per program.

    Built on the shared :class:`repro.core.journal.Journal` (the same
    flock/fsync code path as the seed bank, search meta, surrogate fits and
    the plan store): appends serialize on the sidecar lock so concurrent
    writers from different processes can share one file; duplicate lines are
    harmless (last write wins on load).  Only *finite, valid-or-invalid
    measured* results are persisted — screened or skipped chromosomes never
    enter the store.  The journal is bounded: past ``2 * max_records`` lines
    it compacts to the newest record per bits-key, newest ``max_records``
    overall, so a long-lived service can't grow it without limit.
    """

    def __init__(self, cache_dir: str, fingerprint: str,
                 max_records: int = _MEASUREMENTS_MAX_RECORDS):
        self.dir = cache_dir
        self.fingerprint = fingerprint
        self.max_records = max(1, int(max_records))
        os.makedirs(cache_dir, exist_ok=True)
        self.path = os.path.join(cache_dir, f"measurements_{fingerprint}.jsonl")
        self._journal = Journal(self.path)

    def load(self) -> dict[tuple, Evaluation]:
        out: dict[tuple, Evaluation] = {}
        for rec in self._journal.records():
            if rec.get("fingerprint") != self.fingerprint:
                continue
            try:
                bits = tuple(int(c) for c in rec["bits"]) \
                    if rec["bits"] != "-" else ()
                t = rec["time_s"]
                out[bits] = Evaluation(
                    bits, float("inf") if t is None else float(t),
                    bool(rec["valid"]), dict(rec.get("detail") or {}))
            except (KeyError, TypeError, ValueError):
                continue  # foreign/legacy line
        return out

    def store(self, ev: Evaluation) -> None:
        rec = {
            "fingerprint": self.fingerprint,
            "bits": _bits_key(ev.bits),
            "time_s": ev.time_s if math.isfinite(ev.time_s) else None,
            "valid": ev.valid,
            "detail": {k: v for k, v in ev.detail.items()
                       if isinstance(v, (str, int, float, bool))},
        }
        self._journal.append([rec])
        self._journal.compact(
            lambda recs: newest_per_key(
                recs, key=lambda r: (r.get("fingerprint"), r.get("bits")),
                max_records=self.max_records),
            threshold=2 * self.max_records)


# ---------------------------------------------------------------------------
# per-search metadata: the surrogate's measured track record
# ---------------------------------------------------------------------------

_SEARCH_META_FILE = "search_meta.jsonl"
_SEARCH_META_MAX_LINES = 512
#: default staleness horizon for rank-corr records: a fingerprint's surrogate
#: track record from last week says little about today's machine/load, and
#: auto-screening must never act on a stale fingerprint.
_SEARCH_META_HORIZON_S = 7 * 24 * 3600.0


def record_search_meta(cache_dir: str, fingerprint: str,
                       rank_corr: float, now: Optional[float] = None,
                       horizon_s: Optional[float] = None,
                       kind: Optional[str] = None) -> None:
    """Journal one search's surrogate rank correlation for its program
    fingerprint — the evidence :func:`last_rank_corr` serves back so a later
    search of the same program can justify screening automatically.

    Records are timestamped, and the journal decays: records older than the
    staleness horizon (``horizon_s``, default one week) are compacted away,
    as are legacy records without a timestamp (their age is unprovable).
    Past ``_SEARCH_META_MAX_LINES`` live lines the journal additionally
    collapses to the newest record per fingerprint (writes serialize on a
    sidecar flock, like the seed bank's journal)."""
    if not math.isfinite(rank_corr):
        return
    now = time.time() if now is None else float(now)
    horizon = _SEARCH_META_HORIZON_S if horizon_s is None else float(horizon_s)
    os.makedirs(cache_dir, exist_ok=True)
    journal = Journal(os.path.join(cache_dir, _SEARCH_META_FILE))
    rec = {"fingerprint": fingerprint, "rank_corr": float(rank_corr),
           "ts": now}
    if kind:                     # which surrogate produced the evidence
        rec["kind"] = str(kind)  # (static formula vs journal-fitted model)
    with journal.lock():
        journal.append([rec], locked=False)
        recs = journal.records()
        fresh = [r for r in recs
                 if isinstance(r.get("ts"), (int, float))
                 and now - r["ts"] <= horizon]
        if len(fresh) == len(recs) and len(recs) <= _SEARCH_META_MAX_LINES:
            return
        journal.rewrite(
            newest_per_key(fresh, key=lambda r: r.get("fingerprint"),
                           max_records=_SEARCH_META_MAX_LINES),
            locked=False)


def last_rank_corr(cache_dir: str, fingerprint: str,
                   max_age_s: Optional[float] = None,
                   now: Optional[float] = None) -> Optional[float]:
    """Most recent recorded surrogate rank correlation for a fingerprint.

    Records older than ``max_age_s`` (default: the one-week staleness
    horizon) — and legacy records with no timestamp — are ignored, so
    auto-screening can never act on a stale fingerprint."""
    now = time.time() if now is None else float(now)
    max_age = _SEARCH_META_HORIZON_S if max_age_s is None else float(max_age_s)
    out: Optional[float] = None
    journal = Journal(os.path.join(cache_dir, _SEARCH_META_FILE))
    for rec in journal.records():
        if rec.get("fingerprint") == fingerprint:
            ts = rec.get("ts")
            if not isinstance(ts, (int, float)) or now - ts > max_age:
                continue         # stale (or unprovably fresh)
            corr = rec.get("rank_corr")
            if isinstance(corr, (int, float)) and math.isfinite(corr):
                out = float(corr)
    return out


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclass
class EvalStats:
    """Measurement accounting: how much verification work the engine avoided."""

    measurements: int = 0        # fitness_fn actually invoked
    cache_hits: int = 0          # served from the in-memory cache
    persistent_hits: int = 0     # served from the on-disk cache at first touch
    inflight_hits: int = 0       # joined an in-flight measurement
    screened_out: int = 0        # skipped by the surrogate pre-screen
    eval_wall_s: float = 0.0     # wall-clock spent inside evaluate_batch
    overlapped_compiles: int = 0  # warm-up compiles run in the overlap phase
    compile_serial_s: float = 0.0  # sum of individual prepare() durations
    compile_wall_s: float = 0.0    # wall-clock of the overlapped prepare phase
    overlap_est_saved_s: float = 0.0  # probe-calibrated estimate of the true
                                      # saving: n * (uncontended solo prepare)
                                      # minus the phase's actual wall-clock
    overlap_disabled: bool = False    # adaptive backoff tripped: contention
                                      # ate the savings, overlap is off for
                                      # the rest of this evaluator's life

    @property
    def measurements_saved(self) -> int:
        return (self.cache_hits + self.persistent_hits
                + self.inflight_hits + self.screened_out)

    @property
    def compile_overlap_saved_s(self) -> float:
        """Wall-clock the compile-parallel phase saved over serial warm-up."""
        return max(0.0, self.compile_serial_s - self.compile_wall_s)

    def as_dict(self) -> dict:
        return {
            "measurements": self.measurements,
            "cache_hits": self.cache_hits,
            "persistent_hits": self.persistent_hits,
            "inflight_hits": self.inflight_hits,
            "screened_out": self.screened_out,
            "measurements_saved": self.measurements_saved,
            "eval_wall_s": self.eval_wall_s,
            "overlapped_compiles": self.overlapped_compiles,
            "compile_serial_s": self.compile_serial_s,
            "compile_wall_s": self.compile_wall_s,
            "compile_overlap_saved_s": self.compile_overlap_saved_s,
            "overlap_est_saved_s": self.overlap_est_saved_s,
            "overlap_disabled": self.overlap_disabled,
        }


class Evaluator:
    """Measurement scheduler for the GA: dedup -> screen -> dispatch.

    Parameters
    ----------
    fitness_fn:
        ``bits -> Evaluation`` — the verification-environment measurement.
    workers:
        0 or 1 = serial (required for wall-clock timing fidelity); N > 1 =
        thread pool of N for compile-bound fitness.
    cache_dir / fingerprint:
        when both given, measurements persist to
        ``{cache_dir}/measurements_{fingerprint}.jsonl`` and prior runs'
        results are loaded on construction.
    surrogate:
        optional ``bits -> float`` static cost estimate (lower = better),
        used only to *rank* unmeasured offspring when ``screen_top_k`` caps
        how many are measured per batch.
    screen_top_k:
        measure at most this many unmeasured chromosomes per batch (the
        rest are deferred: reported invalid/unmeasured, never cached, so a
        later generation may still measure them).
    phenotype_key:
        optional ``bits -> hashable`` canonicalization.  Chromosomes with
        equal keys are *phenotype duplicates* — they decode to the same
        program (clamped ``impl_index`` on short implementation menus,
        predicate fallbacks) — and share one measurement: dedup, the
        in-memory/persistent caches, and in-flight joining all key on it.
        Results are re-labelled with the requesting chromosome's bits, so
        the GA's bookkeeping is unaffected.  Default: identity (key by raw
        bits, the historical behavior).
    compile_workers:
        thread count for the compile-parallel/time-serial phase, used only
        when the fitness is two-phase (``prepare``/``measure``) and the
        timing loop is serial (``workers <= 1``).  0/1/None disables
        overlap (the historical serial warm-up).  Opt-in because it only
        pays when a chromosome's prepare is one big GIL-releasing compile
        (the jaxpr substitution path: ``engine.substitute()`` +
        ``jax.jit``); a prepare dominated by many small compiles or
        GIL-held interpretation contends instead of overlapping.  Timing
        fidelity is preserved either way: all warm-up compiles finish
        before the first chromosome is timed.
    """

    def __init__(self, fitness_fn: Optional[Callable[[tuple], Evaluation]],
                 workers: int = 0,
                 cache_dir: Optional[str] = None,
                 fingerprint: str = "",
                 surrogate: Optional[Callable[[tuple], float]] = None,
                 screen_top_k: Optional[int] = None,
                 executor: Optional[Any] = None,
                 dispatch_fn: Optional[Callable[[tuple], Evaluation]] = None,
                 phenotype_key: Optional[Callable[[tuple], Any]] = None,
                 compile_workers: Optional[int] = None,
                 annotate: Optional[Callable[[Evaluation], Evaluation]]
                 = None):
        self.fitness_fn = fitness_fn
        # post-measurement hook: enrich an Evaluation's detail dict before it
        # is cached/persisted (multi-objective search stamps per-objective
        # fields — energy_j, transfer_bytes — so journal rows carry them;
        # see repro.core.objectives.annotate_objectives)
        self.annotate = annotate
        self.workers = max(0, int(workers))
        self.compile_workers = max(0, int(compile_workers or 0))
        self._key = phenotype_key or (lambda bits: bits)
        # external executor (e.g. a spawn-based ProcessPoolExecutor whose
        # workers rebuilt the fitness in an initializer): XLA serializes LLVM
        # compilation process-wide, so compile-bound measurement only scales
        # across *processes*; dispatch_fn must be picklable, and the engine
        # keeps ownership of caching/dedup/persistence in the parent
        self._executor = executor
        self._dispatch_fn = dispatch_fn
        if executor is not None and dispatch_fn is None:
            raise ValueError("executor requires a picklable dispatch_fn")
        if fitness_fn is None and executor is None:
            raise ValueError("need fitness_fn or (executor, dispatch_fn)")
        if screen_top_k is not None and surrogate is None:
            raise ValueError(
                "screen_top_k requires a surrogate ranking function; use "
                "ga_search (which derives one from the region graph) "
                "or pass surrogate= explicitly")
        self.surrogate = surrogate
        self.screen_top_k = screen_top_k
        self.stats = EvalStats()
        # (surrogate score, measured time) per finite measurement — the data
        # behind surrogate_rank_correlation(), which calibrates screen_top_k
        self._surrogate_pairs: list[tuple[float, float]] = []
        self._cache: dict[tuple, Evaluation] = {}
        self._lock = threading.Lock()
        self._inflight: dict[tuple, Future] = {}
        self._pool: Optional[ThreadPoolExecutor] = None
        self._compile_pool: Optional[ThreadPoolExecutor] = None
        self._overlap_batches = 0     # batches charged against the probe
        self._overlap_probe_s: Optional[float] = None   # mean cost of one
        self._overlap_solo_n = 0                        # solo prepare
        self._store: Optional[MeasurementCache] = None
        if cache_dir:
            self._store = MeasurementCache(cache_dir, fingerprint or "anon")
            persisted = self._store.load()
            for bits, ev in persisted.items():
                self._cache[self._key(bits)] = ev
            self._persisted_unseen = set(self._cache)
        else:
            self._persisted_unseen = set()

    # -- cache interface ----------------------------------------------------

    def is_measured(self, bits: Sequence[int]) -> bool:
        """True if this chromosome (or a phenotype-equivalent one) already
        has a measurement (memory or disk).  Used by duplicate-avoiding
        offspring generation."""
        return self._key(tuple(bits)) in self._cache

    @property
    def unique_measured(self) -> int:
        return len(self._cache)

    def _lookup(self, key) -> Optional[Evaluation]:
        ev = self._cache.get(key)
        if ev is None:
            return None
        if key in self._persisted_unseen:
            self._persisted_unseen.discard(key)
            self.stats.persistent_hits += 1
        else:
            self.stats.cache_hits += 1
        return ev

    # -- measurement --------------------------------------------------------

    def _record(self, bits: tuple, ev: Evaluation) -> Evaluation:
        if self.annotate is not None:
            try:
                ev = self.annotate(ev)
            except Exception:  # noqa: BLE001 — annotation must never cost a
                pass           # measurement; the objective fn recomputes
        score = None
        if self.surrogate is not None and math.isfinite(ev.time_s):
            try:
                score = float(self.surrogate(bits))
            except Exception:  # noqa: BLE001 — a broken surrogate only
                score = None   # loses calibration data, never a measurement
        with self._lock:
            self.stats.measurements += 1
            self._cache[self._key(bits)] = ev
            if score is not None:
                self._surrogate_pairs.append((score, ev.time_s))
        if self._store is not None:
            self._store.store(ev)
        return ev

    def surrogate_rank_correlation(self) -> float:
        """Spearman rank correlation between the surrogate's static score and
        the measured time across this engine's finite measurements.

        +1 means the surrogate orders offspring exactly as measurement would
        (screening is nearly free); ~0 means screening is a coin flip — the
        number that lets ``screen_top_k`` be set from data instead of faith.
        nan with fewer than 3 points or a constant ranking.
        """
        from repro.core.surrogate import spearman_rank_corr

        with self._lock:
            pairs = list(self._surrogate_pairs)
        return spearman_rank_corr([p[0] for p in pairs],
                                  [p[1] for p in pairs])

    def _measure(self, bits: tuple,
                 parent: Optional[int] = None) -> Evaluation:
        fn = self.fitness_fn
        key = _bits_key(bits)
        if (self.workers <= 1 and hasattr(fn, "prepare")
                and hasattr(fn, "measure")):
            # serial two-phase measurement (baseline chromosome, single-item
            # batches, post-backoff batches): an uncontended prepare — time
            # it to calibrate the overlap phase's saving estimate for free
            t0 = time.perf_counter()
            with obs_trace.span("eval.prepare", parent=parent, bits=key):
                prep = fn.prepare(bits)
            dt = time.perf_counter() - t0
            with self._lock:
                n = self._overlap_solo_n
                prev = self._overlap_probe_s or 0.0
                self._overlap_probe_s = (prev * n + dt) / (n + 1)
                self._overlap_solo_n = n + 1
            with obs_trace.span("eval.measure", parent=parent, bits=key):
                ev = fn.measure(prep)
            return self._record(bits, ev)
        with obs_trace.span("eval.measure", parent=parent, bits=key):
            ev = self.fitness_fn(bits)
        return self._record(bits, ev)

    def _run_measure(self, bits: tuple, fut: Future,
                     parent: Optional[int] = None) -> None:
        try:
            ev = self._measure(bits, parent=parent)
        except BaseException as e:  # fitness fns normally catch their own
            try:
                fut.set_exception(e)
            except Exception:  # future already resolved by an aborted batch
                pass
            return
        try:
            fut.set_result(ev)
        except Exception:  # future already resolved by an aborted batch;
            pass           # the measurement itself is cached either way

    def evaluate(self, bits: Sequence[int]) -> Evaluation:
        """Evaluate one chromosome (cache -> in-flight -> measure)."""
        return self.evaluate_batch([tuple(bits)])[0]

    #: EvalStats fields mirrored into the process metrics registry as
    #: ``eval.<field>`` counters after every batch (delta accounting).
    _METRIC_FIELDS = ("measurements", "cache_hits", "persistent_hits",
                      "inflight_hits", "screened_out", "overlapped_compiles")

    def _publish_metrics(self, before: EvalStats, span) -> None:
        st = self.stats
        deltas = {f: getattr(st, f) - getattr(before, f)
                  for f in self._METRIC_FIELDS}
        deltas["compile_overlap_saved_s"] = (st.compile_overlap_saved_s
                                             - before.compile_overlap_saved_s)
        for name, d in deltas.items():
            if d:
                obs_metrics.counter(f"eval.{name}").inc(d)
        span.set(**{k: round(v, 6) if isinstance(v, float) else v
                    for k, v in deltas.items()})

    def evaluate_batch(self, population: Sequence[Sequence[int]]
                       ) -> list[Evaluation]:
        """Evaluate a whole population; results in population order.

        Duplicates within the batch, chromosomes already measured (this run
        or a persisted one), and chromosomes being measured concurrently by
        another caller are all deduped to a single measurement.
        """
        before = dataclasses.replace(self.stats)
        with obs_trace.span("eval.batch", size=len(population)) as sp:
            out = self._evaluate_batch(population)
            self._publish_metrics(before, sp)
            return out

    def _evaluate_batch(self, population: Sequence[Sequence[int]]
                        ) -> list[Evaluation]:
        t0 = time.perf_counter()
        pop = [tuple(int(b) for b in p) for p in population]
        # everything below keys on the phenotype key (identity by default):
        # decode-equivalent chromosomes share one measurement
        keys = [self._key(bits) for bits in pop]
        results: dict[Any, Evaluation] = {}
        to_measure: list[tuple] = []   # representative bits per unique key,
        measure_keys: list = []        # in first-appearance order
        joined: dict[Any, Future] = {}
        seen: set = set()

        dup_pending: dict[Any, int] = {}
        with self._lock:
            for bits, key in zip(pop, keys):
                if key in seen:
                    # within-batch duplicate: one measurement serves all.
                    # Attribution for still-pending keys waits until we know
                    # whether they were measured or screened out (a screened
                    # chromosome has no measurement to save).
                    if key in results:
                        self.stats.cache_hits += 1
                    else:
                        dup_pending[key] = dup_pending.get(key, 0) + 1
                    continue
                seen.add(key)
                ev = self._lookup(key)
                if ev is not None:
                    results[key] = ev
                elif key in self._inflight:
                    self.stats.inflight_hits += 1
                    joined[key] = self._inflight[key]
                else:
                    to_measure.append(bits)
                    measure_keys.append(key)

        # --- surrogate pre-screen: rank, measure only the top-k ------------
        deferred: list[tuple[Any, tuple]] = []
        if (self.screen_top_k is not None and self.surrogate is not None
                and len(to_measure) > self.screen_top_k):
            ranked = sorted(range(len(to_measure)),
                            key=lambda i: (self.surrogate(to_measure[i]), i))
            keep = set(ranked[: self.screen_top_k])
            deferred = [(k, b) for i, (k, b)
                        in enumerate(zip(measure_keys, to_measure))
                        if i not in keep]
            to_measure = [b for i, b in enumerate(to_measure) if i in keep]
            measure_keys = [k for i, k in enumerate(measure_keys) if i in keep]
            self.stats.screened_out += len(deferred)

        # --- dispatch -------------------------------------------------------
        # every measurement is announced in _inflight before it starts, so
        # concurrent callers (serial or pooled) join it instead of repeating
        # it.  The screen above ran outside the lock, so re-check here: a
        # concurrent batch may have announced (or finished) one of ours.
        futures: dict[Any, Future] = {}
        fut_bits: dict[Any, tuple] = {}
        with self._lock:
            announced: list[tuple] = []
            for bits, key in zip(to_measure, measure_keys):
                ev = self._lookup(key)
                if ev is not None:
                    results[key] = ev
                elif key in self._inflight:
                    self.stats.inflight_hits += 1
                    joined[key] = self._inflight[key]
                else:
                    fut: Future = Future()
                    self._inflight[key] = fut
                    futures[key] = fut
                    fut_bits[key] = bits
                    announced.append(bits)
            to_measure = announced
        try:
            if self._executor is not None:
                # cross-process dispatch: workers measure, parent records.
                # Only results the worker actually returned are recorded and
                # persisted — a dead worker / broken pool is transient infra
                # failure, not a measurement, and must not poison the cache.
                raw = [(key, bits,
                        self._executor.submit(self._dispatch_fn, bits))
                       for key, bits in fut_bits.items()]
                for key, bits, rf in raw:
                    try:
                        ev = self._record(bits, rf.result())
                    except Exception as e:  # noqa: BLE001 — worker died etc.
                        ev = Evaluation(bits, float("inf"), False,
                                        {"error": f"{type(e).__name__}: {e}"[:300],
                                         "transient": True})
                    futures[key].set_result(ev)
            elif self.workers > 1 and len(to_measure) > 1:
                pool = self._ensure_pool()
                # pool threads have their own (empty) span stacks: hand them
                # this thread's span id so their spans nest under the batch
                parent = obs_trace.current_span_id()
                for key, bits in fut_bits.items():
                    pool.submit(self._run_measure, bits, futures[key],
                                parent)
            elif (self.compile_workers > 1 and len(fut_bits) > 1
                  and not self.stats.overlap_disabled
                  and hasattr(self.fitness_fn, "prepare")
                  and hasattr(self.fitness_fn, "measure")):
                # compile-parallel / time-serial: warm-up compiles overlap
                # on threads (they release the GIL into XLA), then the
                # timing loop runs strictly serially in batch order
                self._run_overlapped(fut_bits, futures)
            else:
                for key, bits in fut_bits.items():
                    self._run_measure(bits, futures[key])
            # let every dispatched measurement finish before collecting, so a
            # stored exception can't abort the batch while siblings still run
            # (the abandoned-future cleanup below must never race a worker)
            _wait_futures(list(futures.values()))
            for key, fut in futures.items():
                results[key] = fut.result()
        finally:
            with self._lock:
                for key, fut in futures.items():
                    # resolve anything still pending (e.g. the serial loop
                    # aborted on an earlier chromosome) so concurrent
                    # callers joined on these futures don't hang forever
                    if not fut.done():
                        fut.set_exception(
                            RuntimeError("measurement abandoned: batch "
                                         "aborted before this chromosome"))
                    self._inflight.pop(key, None)

        for key, fut in joined.items():
            results[key] = fut.result()
        for key, bits in deferred:
            # deferred chromosomes are NOT measurements: zero fitness this
            # generation, absent from the cache so they can be measured later
            results[key] = Evaluation(
                bits, float("inf"), False, {"screened": True})

        if dup_pending:
            with self._lock:
                for key, n in dup_pending.items():
                    ev = results.get(key)
                    if ev is not None and not ev.detail.get("screened"):
                        self.stats.inflight_hits += n

        self.stats.eval_wall_s += time.perf_counter() - t0
        out: list[Evaluation] = []
        for bits, key in zip(pop, keys):
            ev = results[key]
            # a phenotype hit carries the measured sibling's bits: re-label
            # with the requesting chromosome so GA bookkeeping stays exact
            out.append(ev if tuple(ev.bits) == bits
                       else dataclasses.replace(ev, bits=bits))
        return out

    def _run_overlapped(self, fut_bits: dict, futures: dict) -> None:
        """Two-phase dispatch: every chromosome's ``prepare`` (build +
        warm-up compile + verification) runs concurrently; once all have
        finished, ``measure`` (the timing loop) runs serially in batch
        order.  Results — including prepare-time failures — are identical
        to the serial path; only the wall-clock spent compiling shrinks.

        The phase watches its own worth: serial two-phase measurements
        (the baseline chromosome, single-item batches) time their prepare
        as free *uncontended* probes, calibrating what one solo warm-up
        truly costs — the naive ``compile_serial_s`` sum is inflated by
        contention waits.  An overlapped batch charges
        ``n * t_probe - wall`` against that calibration (when no solo
        sample exists yet, the batch's first prepare runs alone to
        bootstrap one).  When the cumulative estimate goes negative after
        at least two charged batches — contention is eating more than the
        overlap saves — overlap disables itself for the evaluator's
        lifetime and later batches warm up serially."""
        pool = self._ensure_compile_pool()
        items = list(fut_bits.items())
        # compile-pool threads parent their spans on the dispatching
        # thread's batch span (their own stacks are empty)
        parent = obs_trace.current_span_id()

        def timed_prepare(bits: tuple):
            t0 = time.perf_counter()
            with obs_trace.span("eval.prepare", parent=parent,
                                bits=_bits_key(bits), overlapped=True):
                prep = self.fitness_fn.prepare(bits)
            return prep, time.perf_counter() - t0

        t0 = time.perf_counter()
        if self._overlap_probe_s is None:
            # no solo sample yet: serialize one prepare to bootstrap the
            # calibration, overlap the rest
            first = pool.submit(timed_prepare, items[0][1])
            _wait_futures([first])
            t_probe = time.perf_counter() - t0
            rest = [pool.submit(timed_prepare, bits) for _, bits in items[1:]]
            _wait_futures(rest)
            prep_futs = [first] + rest
            if first.exception() is None:
                with self._lock:
                    self._overlap_probe_s = t_probe
                    self._overlap_solo_n = 1
        else:
            prep_futs = [pool.submit(timed_prepare, bits)
                         for _, bits in items]
            _wait_futures(prep_futs)
        compile_wall = time.perf_counter() - t0
        with self._lock:
            self.stats.overlapped_compiles += len(items)
            self.stats.compile_wall_s += compile_wall
            if self._overlap_probe_s is not None:
                self.stats.overlap_est_saved_s += \
                    self._overlap_probe_s * len(items) - compile_wall
                self._overlap_batches += 1
                if (self._overlap_batches >= 2
                        and self.stats.overlap_est_saved_s < 0):
                    self.stats.overlap_disabled = True
        for (key, bits), pf in zip(items, prep_futs):
            try:
                prep, dt = pf.result()
                with self._lock:
                    self.stats.compile_serial_s += dt
                with obs_trace.span("eval.measure", bits=_bits_key(bits)):
                    ev = self.fitness_fn.measure(prep)
                ev = self._record(bits, ev)
            except BaseException as e:  # fitness fns normally catch their own
                try:
                    futures[key].set_exception(e)
                except Exception:  # future resolved by an aborted batch
                    pass
                continue
            try:
                futures[key].set_result(ev)
            except Exception:  # future resolved by an aborted batch;
                pass           # the measurement itself is cached either way

    def _ensure_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix="ga-eval")
            return self._pool

    def _ensure_compile_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._compile_pool is None:
                self._compile_pool = ThreadPoolExecutor(
                    max_workers=self.compile_workers,
                    thread_name_prefix="ga-compile")
            return self._compile_pool

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        if self._compile_pool is not None:
            self._compile_pool.shutdown(wait=True)
            self._compile_pool = None

    def __enter__(self) -> "Evaluator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# static surrogate: transfer-cost ranking (pre-screen only, never a score)
# ---------------------------------------------------------------------------


def transfer_cost_surrogate(graph, coding, var_bytes: Optional[dict] = None,
                            base_impl: Optional[dict] = None
                            ) -> Callable[[tuple], float]:
    """Rank chromosomes by estimated dynamic transfer volume.

    Decodes ``bits`` through ``coding``, runs the (pure-IR) transfer planner
    and weights the resulting transfer count by per-variable byte sizes when
    known.  Patterns that offload more while transferring less rank first —
    a roofline-style prior, used *only* to order offspring for measurement.

    Destination-aware: genes on cost-only destinations decode to the
    reference path (zero transfers), so their modeled device cost is folded
    into the rank instead — otherwise stub-parked chromosomes would rank
    *best* while the fitness charges them the stub's modeled latency, and
    screening would invert.  Only genes on executable accelerator
    destinations count as "more offloaded work" for the tiebreak.
    """
    from repro.core.genes import get_destination, modeled_cost_s
    from repro.core.transfer_planner import plan_transfers

    var_bytes = var_bytes or {}
    dests = [get_destination(d) for d in coding.destinations]
    # any placement that charges a model (stub devices, mesh genes) folds
    # its modeled seconds into the rank so screening can't invert
    any_charged = any(d.placement_tag is not None for d in dests)
    #: rank-units per modeled second — arbitrary but monotone: it only has
    #: to make stub-parked genes rank behind the free reference path
    _COST_ONLY_SCALE = 1e6
    memo: dict[tuple, float] = {}

    def cost(bits: tuple) -> float:
        bits = tuple(bits)
        if bits in memo:
            return memo[bits]
        impl = dict(base_impl or {})
        impl.update(coding.decode(bits))
        plan = plan_transfers(graph, impl, hoist=True,
                              destinations=coding.destinations_of(bits))
        total = 0.0
        for t in plan.transfers:
            trips = 1
            if t.per_iteration:
                r = graph.by_name(t.at_region)
                while r is not None:
                    trips *= (r.trip_count or 1) if r.kind == "loop" else 1
                    r = graph.by_name(r.parent) if r.parent else None
            total += (trips * float(var_bytes.get(t.var, 1.0))
                      / max(t.shards, 1))
        if any_charged:
            total += _COST_ONLY_SCALE * modeled_cost_s(graph, coding, bits)
        # prefer more offloaded work at equal transfer cost (paper intuition:
        # offload wins when transfers are amortized); for the binary alphabet
        # this is exactly the historical sum(bits)
        offloaded = sum(1 for v in bits
                        if not dests[int(v)].is_cost_only and int(v) != 0)
        memo[bits] = total - 1e-9 * offloaded
        return memo[bits]

    return cost


# ---------------------------------------------------------------------------
# process-pool dispatch: fitness-factory registry + reusable spawn pool
# ---------------------------------------------------------------------------

#: name -> zero-state factory returning a ``bits -> Evaluation`` callable.
#: Factories must be module-level (picklable by reference) so spawn workers
#: can rebuild the fitness in their initializer.
_FITNESS_FACTORIES: dict[str, Callable[..., Callable[[tuple], Evaluation]]] = {}


def register_fitness_factory(name: str, factory: Callable,
                             replace: bool = False) -> None:
    """Register a fitness factory under ``name`` for pool-based evaluation
    (``GAConfig.pool = name``).  The factory runs once per worker process."""
    if name in _FITNESS_FACTORIES and not replace:
        raise ValueError(f"fitness factory {name!r} already registered")
    _FITNESS_FACTORIES[name] = factory


def fitness_factory(name: str) -> Callable:
    try:
        return _FITNESS_FACTORIES[name]
    except KeyError:
        raise KeyError(f"unknown fitness factory {name!r}; registered: "
                       f"{sorted(_FITNESS_FACTORIES)}") from None


def fitness_factory_names() -> tuple[str, ...]:
    return tuple(sorted(_FITNESS_FACTORIES))


def _smoke_fitness_factory(scale: float = 0.1) -> Callable[[tuple], Evaluation]:
    """Shipped example factory (also the cross-process test fixture): a
    deterministic synthetic fitness with no heavy dependencies."""
    def fit(bits: tuple) -> Evaluation:
        return Evaluation(tuple(bits), 1.0 + scale * sum(bits), True)
    return fit


register_fitness_factory("smoke", _smoke_fitness_factory)


_POOL_FITNESS: Optional[Callable[[tuple], Evaluation]] = None


def _pool_worker_init(factory, args: tuple, kwargs: dict) -> None:
    global _POOL_FITNESS
    _POOL_FITNESS = factory(*args, **(kwargs or {}))


def _pool_worker_eval(bits: tuple) -> Evaluation:
    assert _POOL_FITNESS is not None, "worker initializer did not run"
    return _POOL_FITNESS(bits)


class ProcessPool:
    """Spawn-based measurement pool built from a registered fitness factory.

    XLA serializes LLVM compilation process-wide, so compile-bound fitness
    only scales across *processes*.  Each worker rebuilds the fitness once in
    its initializer (the factory must be a module-level callable); the parent
    keeps ownership of caching / dedup / persistence through the
    :class:`Evaluator` it plugs into via :meth:`evaluator_kwargs`.

    ``warm(chromosomes)`` pays every worker's one-time first-compile cost up
    front (results are measured in the parent's Evaluator-free context and
    discarded), so timed searches see a warm pool.
    """

    def __init__(self, factory: str | Callable, workers: Optional[int] = None,
                 args: tuple = (), kwargs: Optional[dict] = None):
        import multiprocessing as mp

        if isinstance(factory, str):
            factory = fitness_factory(factory)
        self.workers = int(workers or min(4, (os.cpu_count() or 2)))
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        self.executor: ProcessPoolExecutor = ProcessPoolExecutor(
            max_workers=self.workers,
            mp_context=mp.get_context("spawn"),
            initializer=_pool_worker_init,
            initargs=(factory, tuple(args), dict(kwargs or {})))

    #: what Evaluator dispatches through the pool — module-level, picklable.
    dispatch_fn = staticmethod(_pool_worker_eval)

    def evaluator_kwargs(self) -> dict:
        """Plug-in kwargs for :class:`Evaluator`: cross-process dispatch."""
        return {"executor": self.executor, "dispatch_fn": _pool_worker_eval}

    def warm(self, chromosomes: Sequence[tuple],
             rounds_per_worker: int = 2) -> None:
        """Run throwaway measurements so every worker initializes + compiles
        before anything is timed.  ``chromosomes`` cycle round-robin."""
        if not chromosomes:
            return
        futs = [self.executor.submit(
                    _pool_worker_eval,
                    tuple(chromosomes[i % len(chromosomes)]))
                for i in range(rounds_per_worker * self.workers)]
        for f in futs:
            f.result()

    def close(self) -> None:
        self.executor.shutdown(wait=True)

    def __enter__(self) -> "ProcessPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
