"""CPU↔accelerator transfer planning (paper §3.2.1 / §4.2.2).

Def/use rule, verbatim from the paper:
  * a variable set on the CPU side and referenced on the accelerator side
    needs an H2D transfer;
  * a variable set on the accelerator side and referenced/set on the CPU
    side needs a D2H transfer.

Hoisting rule: a transfer inside a loop nest moves to the outermost level at
which the variable is still loop-invariant on the producing side (上位で
まとめて転送).  The planner is pure IR analysis — the ast-frontend executor
realizes the schedule with its versioned device cache, and the module
frontend maps the same decision onto FSDP all-gather placement
(``gather_mode``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.ir import Region, RegionGraph


@dataclass
class Transfer:
    var: str
    direction: str          # "h2d" | "d2h"
    at_region: str          # program point (region whose entry hosts it)
    hoisted_from: Optional[str] = None   # loop it was pulled out of
    per_iteration: bool = False


@dataclass
class TransferPlan:
    transfers: list[Transfer] = field(default_factory=list)

    @property
    def n_hoisted(self) -> int:
        return sum(1 for t in self.transfers if t.hoisted_from)

    @property
    def n_per_iteration(self) -> int:
        return sum(1 for t in self.transfers if t.per_iteration)

    def estimated_count(self, graph: RegionGraph) -> int:
        """Total dynamic transfer count, using static trip counts."""
        total = 0
        for t in self.transfers:
            if not t.per_iteration:
                total += 1
                continue
            trips = 1
            r = graph.by_name(t.at_region)
            while r.parent is not None:
                p = graph.by_name(r.parent)
                trips *= (p.trip_count or 1)
                r = p
            trips *= (graph.by_name(t.at_region).trip_count or 1) \
                if graph.by_name(t.at_region).kind == "loop" else 1
            total += trips
        return total


#: fallback implementation ids that place a region's COMPUTE on the
#: accelerator side *when the impl does not appear in the region's own
#: implementation menu* (``region.alternatives``): the ast frontend's jit
#: path, a library substitution, the jaxpr frontend's legacy auto-kernel
#: choice, the kernel registry's named variants, and the module frontend's
#: accelerated *compute* plan values (repro.models.plan — impl knobs incl.
#: the fused-QKV boolean).  Device-ness is decided **per site** first: an
#: impl's position in ``region.alternatives`` (index 0 = the reference =
#: host, 1+ = accelerated) — generic names like "chunked"/"fused" are
#: shared across frontend namespaces, so a global name set cannot tell one
#: region's accelerated variant from another region's reference value.
#: Schedule knobs (remat, gather_mode; ``region.meta["schedule_knob"]``)
#: deliberately stay host-side: they move recomputation/gather placement,
#: not data onto a device, so charging them transfers would distort the
#: static cost.
DEVICE_IMPLS = frozenset({
    "jit", "lib", "kernel", "fused_jnp", "pallas",
    "chunked", "assoc", "fused", "scatter_ep", "chunked_vocab",
})


def _alt_index(alternatives: tuple, impl_id) -> Optional[int]:
    """Position of ``impl_id`` in a region's implementation menu, matched
    by identity or same-type equality — so the integer 1 can never alias
    the boolean True of a flag-valued knob like qkv_fused."""
    for i, alt in enumerate(alternatives):
        if alt is impl_id:
            return i
        if type(alt) is type(impl_id) and alt == impl_id:
            return i
    return None


def plan_transfers(graph: RegionGraph, impl: dict[str, str],
                   hoist: bool = True) -> TransferPlan:
    """impl: region -> an implementation id.  A region computes on the
    accelerator when its id sits at position >= 1 of the region's own
    ``alternatives`` menu (position 0 is the reference path); ids outside
    the menu fall back to the global :data:`DEVICE_IMPLS` name set, or the
    boolean True (a flag-valued knob on its accelerated setting — matched
    by identity so an integer impl id 1 can never alias it).  Regions
    marked ``meta["schedule_knob"]`` never count as device placements."""

    def on_device(r: Region) -> bool:
        impl_id = impl.get(r.name)
        if impl_id is None:
            return False
        if r.meta.get("schedule_knob"):
            return False
        idx = _alt_index(r.alternatives, impl_id)
        if idx is not None:
            return idx >= 1
        return impl_id is True or impl_id in DEVICE_IMPLS

    plan = TransferPlan()
    device_vars: set = set()      # vars whose current value lives on device
    host_dirty: set = set()       # vars (re)written by host since last upload

    def walk(regions: list[Region]):
        for r in regions:
            if r.parent is not None:
                continue  # children handled through their parents below
            _visit(r)

    def _visit(r: Region):
        children = graph.children(r.name)
        if on_device(r):
            for v in sorted(r.uses):
                if v in device_vars and v not in host_dirty:
                    continue  # already resident — hoisted/cached
                target = _hoist_point(r, v) if hoist else r.name
                plan.transfers.append(Transfer(
                    v, "h2d", target,
                    hoisted_from=r.parent if (hoist and target != r.name) else None,
                    per_iteration=not (hoist and target != r.name) and r.parent is not None))
                device_vars.add(v)
                host_dirty.discard(v)
            device_vars.update(r.defs)
            for v in r.defs:
                host_dirty.discard(v)
        else:
            # host region: device-resident vars it reads must come back
            for v in sorted(r.uses & device_vars):
                plan.transfers.append(Transfer(
                    v, "d2h", r.name,
                    per_iteration=r.parent is not None))
            host_dirty.update(r.defs)
            for v in r.defs:
                device_vars.discard(v)
            for c in children:
                _visit(c)

    def _hoist_point(r: Region, var: str) -> str:
        """Climb ancestors while no sibling (host side) writes `var`."""
        at = r.name
        node = r
        while node.parent is not None:
            parent = graph.by_name(node.parent)
            siblings = [s for s in graph.children(parent.name) if s.name != node.name]
            written = any(var in s.defs and not on_device(s) for s in siblings)
            if var in parent.defs and parent.kind == "loop":
                # loop target or header writes it each iteration
                written = written or (var in parent.defs - node.defs)
            if written:
                break
            at = parent.name
            node = parent
        return at

    walk([r for r in graph.regions])
    return plan
