"""CPU↔accelerator transfer planning (paper §3.2.1 / §4.2.2).

Def/use rule, verbatim from the paper:
  * a variable set on the CPU side and referenced on the accelerator side
    needs an H2D transfer;
  * a variable set on the accelerator side and referenced/set on the CPU
    side needs a D2H transfer.

Hoisting rule: a transfer inside a loop nest moves to the outermost level at
which the variable is still loop-invariant on the producing side (上位で
まとめて転送).  The planner is pure IR analysis — the ast-frontend executor
realizes the schedule with its versioned device cache, and the module
frontend maps the same decision onto FSDP all-gather placement
(``gather_mode``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.ir import Region, RegionGraph


@dataclass
class Transfer:
    var: str
    direction: str          # "h2d" | "d2h"
    at_region: str          # program point (region whose entry hosts it)
    hoisted_from: Optional[str] = None   # loop it was pulled out of
    per_iteration: bool = False
    #: mesh fan-out: the transfer splits across this many parallel device
    #: links (1 = a scalar device).  Byte-volume consumers divide by it —
    #: each link carries 1/shards of the variable.
    shards: int = 1


@dataclass
class TransferPlan:
    transfers: list[Transfer] = field(default_factory=list)

    @property
    def n_hoisted(self) -> int:
        return sum(1 for t in self.transfers if t.hoisted_from)

    @property
    def n_per_iteration(self) -> int:
        return sum(1 for t in self.transfers if t.per_iteration)

    def estimated_count(self, graph: RegionGraph) -> int:
        """Total dynamic transfer count, using static trip counts."""
        total = 0
        for t in self.transfers:
            if not t.per_iteration:
                total += 1
                continue
            trips = 1
            r = graph.by_name(t.at_region)
            while r.parent is not None:
                p = graph.by_name(r.parent)
                trips *= (p.trip_count or 1)
                r = p
            trips *= (graph.by_name(t.at_region).trip_count or 1) \
                if graph.by_name(t.at_region).kind == "loop" else 1
            total += trips
        return total


#: fallback implementation ids that place a region's COMPUTE on the
#: accelerator side *when the impl does not appear in the region's own
#: implementation menu* (``region.alternatives``): the ast frontend's jit
#: path, a library substitution, the jaxpr frontend's legacy auto-kernel
#: choice, the kernel registry's named variants, and the module frontend's
#: accelerated *compute* plan values (repro.models.plan — impl knobs incl.
#: the fused-QKV boolean).  Device-ness is decided **per site** first: an
#: impl's position in ``region.alternatives`` (index 0 = the reference =
#: host, 1+ = accelerated) — generic names like "chunked"/"fused" are
#: shared across frontend namespaces, so a global name set cannot tell one
#: region's accelerated variant from another region's reference value.
#: Schedule knobs (remat, gather_mode; ``region.meta["schedule_knob"]``)
#: deliberately stay host-side: they move recomputation/gather placement,
#: not data onto a device, so charging them transfers would distort the
#: static cost.
DEVICE_IMPLS = frozenset({
    "jit", "lib", "kernel", "fused_jnp", "pallas",
    "chunked", "assoc", "fused", "scatter_ep", "chunked_vocab",
})


def _alt_index(alternatives: tuple, impl_id) -> Optional[int]:
    """Position of ``impl_id`` in a region's implementation menu, matched
    by identity or same-type equality — so the integer 1 can never alias
    the boolean True of a flag-valued knob like qkv_fused."""
    for i, alt in enumerate(alternatives):
        if alt is impl_id:
            return i
        if type(alt) is type(impl_id) and alt == impl_id:
            return i
    return None


def plan_transfers(graph: RegionGraph, impl: dict[str, str],
                   hoist: bool = True,
                   destinations: Optional[dict[str, str]] = None
                   ) -> TransferPlan:
    """impl: region -> an implementation id.  A region computes on the
    accelerator when its id sits at position >= 1 of the region's own
    ``alternatives`` menu (position 0 is the reference path); ids outside
    the menu fall back to the global :data:`DEVICE_IMPLS` name set, or the
    boolean True (a flag-valued knob on its accelerated setting — matched
    by identity so an integer impl id 1 can never alias it).  Regions
    marked ``meta["schedule_knob"]`` never count as device placements.

    ``destinations`` (region -> destination name, from
    :meth:`GeneCoding.destinations_of`) refines the per-site decision with
    the Destination API: a region assigned to a mesh destination counts as
    a device placement regardless of its decoded impl (mesh genes decode to
    the reference implementation), and its transfers carry
    ``shards = mesh.n`` — each of the n links moves one shard."""

    def _mesh_shards(r: Region) -> int:
        """0 = not mesh-assigned; otherwise the mesh's device count."""
        name = (destinations or {}).get(r.name)
        if not name or not name.startswith("mesh:"):
            return 0
        from repro.core.genes import get_destination
        return get_destination(name).device_count

    def on_device(r: Region) -> bool:
        if r.meta.get("schedule_knob"):
            return False
        if _mesh_shards(r):
            return True
        impl_id = impl.get(r.name)
        if impl_id is None:
            return False
        idx = _alt_index(r.alternatives, impl_id)
        if idx is not None:
            return idx >= 1
        return impl_id is True or impl_id in DEVICE_IMPLS

    plan = TransferPlan()
    device_vars: dict = {}        # var -> shard count of its resident copy
    host_dirty: set = set()       # vars (re)written by host since last upload

    def walk(regions: list[Region]):
        for r in regions:
            if r.parent is not None:
                continue  # children handled through their parents below
            _visit(r)

    def _visit(r: Region):
        children = graph.children(r.name)
        if on_device(r):
            shards = _mesh_shards(r) or 1
            for v in sorted(r.uses):
                if v in device_vars and v not in host_dirty:
                    continue  # already resident — hoisted/cached
                target = _hoist_point(r, v) if hoist else r.name
                plan.transfers.append(Transfer(
                    v, "h2d", target,
                    hoisted_from=r.parent if (hoist and target != r.name) else None,
                    per_iteration=not (hoist and target != r.name) and r.parent is not None,
                    shards=shards))
                device_vars[v] = shards
                host_dirty.discard(v)
            for v in r.defs:
                device_vars[v] = shards
                host_dirty.discard(v)
        else:
            # host region: device-resident vars it reads must come back
            for v in sorted(r.uses & device_vars.keys()):
                plan.transfers.append(Transfer(
                    v, "d2h", r.name,
                    per_iteration=r.parent is not None,
                    shards=device_vars.get(v, 1)))
            host_dirty.update(r.defs)
            for v in r.defs:
                device_vars.pop(v, None)
            for c in children:
                _visit(c)

    def _hoist_point(r: Region, var: str) -> str:
        """Climb ancestors while no sibling (host side) writes `var`."""
        at = r.name
        node = r
        while node.parent is not None:
            parent = graph.by_name(node.parent)
            siblings = [s for s in graph.children(parent.name) if s.name != node.name]
            written = any(var in s.defs and not on_device(s) for s in siblings)
            if var in parent.defs and parent.kind == "loop":
                # loop target or header writes it each iteration
                written = written or (var in parent.defs - node.defs)
            if written:
                break
            at = parent.name
            node = parent
        return at

    walk([r for r in graph.regions])
    return plan


# ---------------------------------------------------------------------------
# mesh cost model (deterministic priors for MeshDestination genes)
# ---------------------------------------------------------------------------

#: per-link host<->device bandwidth prior (PCIe-class, bytes/s) — each of a
#: mesh's n links moves its own shard, so h2d/d2h volume divides by n.
MESH_LINK_BYTES_PER_S = 12e9
#: intra-mesh collective bandwidth prior (NVLink/ICI-class, bytes/s).
MESH_COLLECTIVE_BYTES_PER_S = 50e9
#: fixed per-launch mesh dispatch cost, charged once per device per trip.
MESH_LAUNCH_OVERHEAD_S = 5e-5


def collective_factor(axis: str, n: int) -> float:
    """Modeled collective volume as a multiple of the region's output bytes.

    The ring bound: an all-gather (data axis, assembling sharded outputs)
    moves (n-1)/n of the tensor per device; a model-axis placement pays a
    reduce-scatter *and* an all-gather to recombine partials — twice that.
    """
    if n <= 1:
        return 0.0
    base = (n - 1) / n
    return base * (2.0 if axis == "model" else 1.0)


def modeled_mesh_cost_s(h2d_bytes: float, d2h_bytes: float, trips: int,
                        axis: str, n: int) -> float:
    """Deterministic modeled seconds for running a region on an n-mesh.

    Per-shard transfers (volume / n over the per-link bandwidth) + the
    axis's collective term over output bytes + a per-device launch
    overhead, all scaled by the static trip estimate.  This is the mesh
    analogue of the fpga_stub launch/per-trip model: what
    :func:`repro.core.genes.modeled_cost_s` charges when a mesh gene is
    not genuinely executed on this host."""
    if n <= 0:
        return 0.0
    per_trip = ((h2d_bytes + d2h_bytes) / max(n, 1) / MESH_LINK_BYTES_PER_S
                + collective_factor(axis, n) * d2h_bytes
                / MESH_COLLECTIVE_BYTES_PER_S)
    return trips * per_trip + n * MESH_LAUNCH_OVERHEAD_S
