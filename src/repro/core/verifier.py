"""Result verification — the PCAST analogue (paper §4.2.2: PGI コンパイラの
PCAST 機能等を用いて並列処理した場合の計算結果が、元のコードと大きく差分が
ないかチェックし、許容外の場合は、処理時間を∞とする).

Compares the offloaded execution's outputs against the reference path on the
same inputs; out-of-tolerance -> the caller assigns time = inf (fitness 0).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import numpy as np


@dataclass
class VerifyResult:
    ok: bool
    max_abs: float
    max_rel: float
    detail: str = ""


def _leaves(x: Any) -> list[np.ndarray]:
    return [np.asarray(l, dtype=np.float64)
            for l in jax.tree_util.tree_leaves(x)
            if hasattr(l, "dtype") and np.issubdtype(np.asarray(l).dtype, np.number)]


def verify(reference: Any, candidate: Any, rtol: float = 1e-2,
           atol: float = 1e-2) -> VerifyResult:
    """Tolerant allclose over arbitrary pytrees of numerics."""
    ref_l, cand_l = _leaves(reference), _leaves(candidate)
    if len(ref_l) != len(cand_l):
        return VerifyResult(False, float("inf"), float("inf"),
                            f"structure mismatch: {len(ref_l)} vs {len(cand_l)} leaves")
    max_abs = 0.0
    max_rel = 0.0
    for r, c in zip(ref_l, cand_l):
        if r.shape != c.shape:
            return VerifyResult(False, float("inf"), float("inf"),
                                f"shape mismatch: {r.shape} vs {c.shape}")
        if not (np.all(np.isfinite(r)) and np.all(np.isfinite(c))):
            if not np.array_equal(np.isfinite(r), np.isfinite(c)):
                return VerifyResult(False, float("inf"), float("inf"), "non-finite mismatch")
        d = np.abs(r - c)
        max_abs = max(max_abs, float(np.max(d)) if d.size else 0.0)
        denom = np.maximum(np.abs(r), 1e-9)
        max_rel = max(max_rel, float(np.max(d / denom)) if d.size else 0.0)
    ok = max_abs <= atol or max_rel <= rtol
    return VerifyResult(ok, max_abs, max_rel)
