"""Region IR: the language-independent program representation.

The paper's common core manages "loops and variables" and "function blocks"
abstractly, independent of the source language (§3.3: ループと変数の把握に
ついては…言語に非依存に抽象的に管理できる).  Every frontend (Python-ast,
jaxpr, module-graph) lowers to this IR; the GA, the pattern DB, and the
transfer planner operate only on it.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional


@dataclass
class Region:
    """One offload candidate: a loop statement, a call, or a function block."""

    name: str                          # unique within the graph
    kind: str                          # "loop" | "call" | "block" | "stmt"
    depth: int = 0                     # loop-nest depth (0 = top level)
    parent: Optional[str] = None
    defs: frozenset = frozenset()      # variables written
    uses: frozenset = frozenset()      # variables read
    callees: tuple = ()                # called function/library names
    feature_vector: dict = field(default_factory=dict)  # Deckard char. vector
    offloadable: bool = False          # has an accelerated alternative
    alternatives: tuple = ()           # implementation ids; [0] is the ref
    trip_count: Optional[int] = None   # static trip count if known
    meta: dict = field(default_factory=dict)

    @property
    def live_in(self) -> frozenset:
        return self.uses

    @property
    def live_out(self) -> frozenset:
        return self.defs


@dataclass
class RegionGraph:
    """Ordered list of regions (program order) + frontend identity."""

    regions: list[Region]
    frontend: str                      # "python_ast" | "jaxpr" | "module"
    source_name: str = ""
    meta: dict = field(default_factory=dict)

    def __iter__(self):
        return iter(self.regions)

    def by_name(self, name: str) -> Region:
        for r in self.regions:
            if r.name == name:
                return r
        raise KeyError(name)

    def offloadable(self) -> list[Region]:
        return [r for r in self.regions if r.offloadable]

    def loops(self) -> list[Region]:
        return [r for r in self.regions if r.kind == "loop"]

    def blocks(self) -> list[Region]:
        return [r for r in self.regions if r.kind in ("block", "call")]

    def children(self, name: str) -> list[Region]:
        return [r for r in self.regions if r.parent == name]

    def summary(self) -> dict:
        return {
            "frontend": self.frontend,
            "n_regions": len(self.regions),
            "n_loops": len(self.loops()),
            "n_offloadable": len(self.offloadable()),
        }

    def fingerprint(self, extra: str = "") -> str:
        """Stable content hash of the graph structure — the persistent
        measurement cache's program key: same program (same regions, same
        def/use sets, same offloadable alternatives) -> same fingerprint, so
        measurements recorded by one process are valid for another.  `extra`
        folds in caller context the graph can't see (e.g. input shapes,
        mesh/device count) that changes what a measurement means."""
        h = hashlib.sha256()
        h.update(f"{self.frontend}|{self.source_name}|{extra}".encode())
        for r in self.regions:
            h.update((
                f"{r.name}|{r.kind}|{r.depth}|{r.parent}|"
                f"{sorted(r.defs)}|{sorted(r.uses)}|{r.callees}|"
                f"{r.offloadable}|{r.alternatives}|{r.trip_count}"
            ).encode())
        return h.hexdigest()[:16]
