"""Function-block offload pass (paper §3.2.2 / §4.2.1).

Step 1  parse: the frontend already produced the RegionGraph.
Step 2  search the code-pattern DB: name matching on callees first, then
        Deckard/CloneDigger-style similarity on characteristic vectors.
Step 3  substitute: return the replacement bindings — ExecPlan field updates
        for the module frontend, library-call adapters for the ast frontend.
        When the replacement's interface differs the match is surfaced as
        ``needs_confirmation`` (the paper asks the user before changing
        interfaces); ``confirm`` decides (default: accept and log).

The planner then measures each replacement on/off, and combinations when
multiple blocks matched (paper: 置換機能ブロック一つずつに対してオフロード
するしないを性能測定し…複数ある場合はその組み合わせ対しても検証).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.ir import Region, RegionGraph
from repro.core.pattern_db import Match, PatternDB


@dataclass
class BlockOffload:
    region: str
    pattern: str
    how: str                  # "name" | "similarity"
    score: float
    replacement: str
    plan_field: Optional[tuple]
    confirmed: bool
    interface_note: str = ""


@dataclass
class BlockOffloadResult:
    offloads: list[BlockOffload] = field(default_factory=list)
    rejected: list[BlockOffload] = field(default_factory=list)

    @property
    def claimed_regions(self) -> tuple:
        return tuple(o.region for o in self.offloads)

    @property
    def plan_updates(self) -> dict:
        return {o.plan_field[0]: o.plan_field[1]
                for o in self.offloads if o.plan_field}


def block_offload_pass(
        graph: RegionGraph, db: PatternDB,
        confirm: Callable[[Match], bool] | bool = True,
        min_similarity: Optional[float] = None) -> BlockOffloadResult:
    result = BlockOffloadResult()
    claimed_parents: set = set()
    for region in graph.regions:
        if region.kind == "stmt":
            continue
        # skip regions nested inside an already-claimed block
        p = region.parent
        nested = False
        while p is not None:
            if p in claimed_parents:
                nested = True
                break
            p = graph.by_name(p).parent
        if nested:
            continue
        matches = db.match_region(region, graph.frontend,
                                  min_similarity=min_similarity)
        if not matches:
            continue
        m = matches[0]
        ok = True
        if m.needs_confirmation:
            ok = confirm(m) if callable(confirm) else bool(confirm)
        bo = BlockOffload(
            region=region.name, pattern=m.record.name, how=m.how,
            score=m.score, replacement=m.record.replacement,
            plan_field=m.record.plan_field, confirmed=ok,
            interface_note=m.record.interface_note)
        if ok:
            result.offloads.append(bo)
            claimed_parents.add(region.name)
        else:
            result.rejected.append(bo)
    return result
