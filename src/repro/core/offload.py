"""The unified offload pipeline: one entry point for every frontend.

The paper's claim is a *common* automatic offloading method across source
languages (§3.3): parse each language into the common loop/structure
representation, then run one GA-based search over it.  This module is that
method as an API: :meth:`Offloader.plan` takes any target — Python source, a
parsed :class:`PyProgram`, a jax-traceable callable, an :class:`ArchConfig`,
or a bare :class:`RegionGraph` — resolves the registered frontend for it,
and drives the same pipeline for all of them:

  normalize -> build RegionGraph -> function-block pass (pattern DB)
     -> gene coding over a destination alphabet (CPU/GPU/FPGA-stub, §genes)
     -> seed the GA population (pattern-DB hits + similarity neighbors)
     -> evaluate through the batching engine (cache, dedup, screening,
        workers / process pool)  -> verify  -> one unified OffloadResult.

The one-liner path is :func:`plan`: ``plan(target, inputs)`` builds an
:class:`Offloader` with default config and returns its
:class:`OffloadResult` (the successor of the retired ``plan_python_offload``
/ ``plan_module_offload`` / ``loop_offload_pass`` shims).
"""
from __future__ import annotations

import dataclasses
import math
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

from repro.core import similarity as sim
from repro.core.evaluator import (Evaluator, ProcessPool, last_rank_corr,
                                  record_search_meta,
                                  transfer_cost_surrogate)
from repro.core.journal import Journal
from repro.core.frontends.registry import (FitnessBundle, OffloadConfig,
                                           decoded_pattern, detect_frontend,
                                           get_frontend, resolve_alphabet)
from repro.core.ga import Evaluation, GAConfig, GAResult, run_ga
from repro.core.genes import (GeneCoding, coding_from_graph,
                              get_destination, modeled_cost_s)
from repro.core.ir import RegionGraph
from repro.core.transfer_planner import TransferPlan, plan_transfers
from repro.core.variants import generic_plan_report
from repro.obs import trace as obs_trace

__all__ = ["OffloadConfig", "OffloadResult", "Offloader", "PlanContext",
           "SeedBank", "ga_search", "phenotype_key", "plan", "plan_offload",
           "resolve_alphabet", "search_fingerprint"]


def search_fingerprint(graph: RegionGraph, coding: Optional[GeneCoding] = None,
                       exclude: Sequence[str] = (),
                       cache_extra: str = "") -> str:
    """The persistent-cache fingerprint ``ga_search`` keys a search by —
    exposed so benches/tools can open the same measurement journal and
    fitted-surrogate records a search wrote."""
    if coding is None:
        coding = coding_from_graph(graph, exclude=exclude)
    return graph.fingerprint(f"{cache_extra}|exclude={sorted(exclude)}"
                             f"|dest={coding.destinations}")


# ---------------------------------------------------------------------------
# GA search stage
# ---------------------------------------------------------------------------


def phenotype_key(coding: GeneCoding,
                  resolver: Optional[Callable[[str, Any], Any]] = None
                  ) -> Callable[[tuple], Any]:
    """Canonicalize a chromosome to its *phenotype*: the decoded
    region -> implementation map plus any placement-tagged destination
    assignment (``Destination.placement_tag``).

    Chromosomes that decode to the same program (clamped ``impl_index`` on
    regions with short implementation menus, alphabet entries aliasing the
    same impl) are measured once per *program*, not once per bit string —
    the ROADMAP's phenotype-dedup.  Destinations whose assignment changes
    the phenotype beyond the decoded impl map carry a placement tag:
    cost-only stubs (reference impl + a modeled charge) and mesh
    destinations (reference impl, but sharded execution or a modeled mesh
    charge), so parking a gene there is a different phenotype than leaving
    it on the reference path.

    ``resolver`` folds the frontend's *bind results* into the key
    (ROADMAP's resolution-fallback slice): ``resolver(region, impl_id)``
    returns the implementation that would actually run — e.g. the jaxpr
    engine's eager variant resolution, where two variants that both fall
    back to ref at a site are the same program and share one measurement.
    Resolution must be static per (region, impl) for the search's lifetime
    (true of eager binds over fixed avals); a resolver error keeps the
    decoded id, never loses a measurement.
    """
    dests = [get_destination(d) for d in coding.destinations]

    def resolve(region: str, impl_id: Any) -> Any:
        if resolver is None:
            return impl_id
        try:
            out = resolver(region, impl_id)
        except Exception:  # noqa: BLE001 — a broken resolver only weakens
            return impl_id  # dedup, it must never lose a measurement
        return impl_id if out is None else out

    def key(bits: tuple) -> Any:
        bits = tuple(bits)
        if len(bits) != coding.length:     # foreign bits (stale cache line)
            return ("raw", bits)
        impl = coding.decode(bits)
        # regions claimed by an active block gene are inert: their decoded
        # impl is already forced to ref by decode(), and a stub destination
        # parked on them charges nothing (modeled_cost_s skips them), so
        # they must not split phenotypes either
        claimed = coding.claimed_members(bits)
        tags = tuple((s.region, dests[int(v)].placement_tag)
                     for s, v in zip(coding.sites, bits)
                     if dests[int(v)].placement_tag is not None
                     and s.region not in claimed)
        return (tuple((s.region, str(resolve(s.region, impl[s.region])))
                      for s in coding.sites),
                tags)

    return key


def ga_search(graph: RegionGraph, fitness_fn: Callable[[tuple], Evaluation],
              ga_cfg: Optional[GAConfig] = None,
              *, coding: Optional[GeneCoding] = None,
              exclude: Sequence[str] = (),
              log: Optional[Callable[[str], None]] = None,
              cache_extra: str = "",
              evaluator: Optional[Evaluator] = None,
              seeds: Sequence[Sequence[int]] = (),
              impl_resolver: Optional[Callable[[str, Any], Any]] = None,
              objective_fn: Optional[Callable[[Evaluation], tuple]] = None
              ) -> tuple[GeneCoding, GAResult]:
    """Run the GA over a graph's unclaimed offloadable regions.

    Owns the evaluation engine unless one is passed in: persistent cache
    keyed by the graph's content fingerprint (plus ``cache_extra`` for
    measurement context the graph can't see), a screening surrogate
    (always attached, so every search reports its surrogate rank
    correlation; screening additionally requires ``screen_top_k``), and —
    when ``ga_cfg.pool`` names a registered fitness factory — a spawn
    :class:`ProcessPool` for cross-process measurement.

    The surrogate is *learned where the evidence allows*: with a
    ``cache_dir``, the fingerprint's measurement journal is fitted
    (:func:`repro.core.surrogate.fit_surrogate`, hand formula as prior)
    and the fitted model replaces the static transfer-cost formula
    whenever its journal rank correlation is strictly better — so
    screening improves with every search instead of merely being measured.
    ``GAResult.surrogate_kind`` records which model ranked the offspring.

    ``impl_resolver`` (usually ``FitnessBundle.impl_resolver``) folds the
    frontend's bind results into the phenotype key, so chromosomes whose
    variants fall back to the same implementation share one measurement.

    A multi-axis ``cfg.objectives`` tuple (e.g.
    :data:`repro.core.objectives.OBJECTIVES`) switches ``run_ga`` to
    NSGA-style Pareto selection: an objective-vector function is built from
    the graph/coding (or taken from ``objective_fn``), every new
    measurement is annotated with per-objective detail fields so the
    journal learns them, and — with a ``cache_dir`` — one ridge surrogate
    per extra objective is fitted and persisted after the search (screening
    itself stays latency-ranked).
    """
    from repro.core import objectives as objmod

    cfg = ga_cfg or GAConfig()
    if coding is None:
        # bare ga_search has no config/frontend in scope: the precedence
        # helper resolves to the default alphabet (one rule everywhere)
        coding = coding_from_graph(graph, exclude=exclude,
                                   destinations=resolve_alphabet(None))
    multi = len(tuple(cfg.objectives)) > 1 or objective_fn is not None
    if multi and objective_fn is None:
        objective_fn = objmod.make_objective_fn(graph, coding,
                                                cfg.objectives)
    owns = evaluator is None
    pool: Optional[ProcessPool] = None
    fingerprint = ""
    surrogate_kind = "static"
    if evaluator is None:
        surrogate = transfer_cost_surrogate(graph, coding)
        fingerprint = search_fingerprint(graph, coding, exclude, cache_extra)
        if cfg.cache_dir and cfg.fit_surrogate:
            # journal-fitted surrogate (ROADMAP: *fit* the surrogate
            # against measurement journals): prefer the regression over
            # the hand formula only when the journal proves it ranks this
            # program's patterns strictly better
            from repro.core.surrogate import fit_surrogate
            fitted = fit_surrogate(graph, coding, cfg.cache_dir,
                                   fingerprint, prior=surrogate,
                                   min_records=cfg.surrogate_min_records)
            if fitted is not None and fitted.beats_static:
                surrogate = fitted
                surrogate_kind = "fitted"
                if log:
                    log(f"surrogate: journal fit over {fitted.n_records} "
                        f"records (rank corr {fitted.rank_corr:.2f} > "
                        f"static {fitted.static_rank_corr:.2f}) replaces "
                        f"the hand formula")
        top_k = cfg.screen_top_k
        if top_k is None and cfg.auto_screen and cfg.cache_dir:
            # surrogate auto-screening (ROADMAP): a prior search of this
            # exact program recorded how well the surrogate ranked its
            # offspring — when that correlation clears the bar (and is
            # fresh enough to trust), screening is evidence-backed and
            # switches itself on
            corr = last_rank_corr(cfg.cache_dir, fingerprint,
                                  max_age_s=cfg.auto_screen_horizon_s)
            if corr is not None and corr >= cfg.auto_screen_corr:
                top_k = max(2, cfg.population // 2)
                if log:
                    log(f"auto-screen: prior surrogate rank corr "
                        f"{corr:.2f} >= {cfg.auto_screen_corr:.2f} -> "
                        f"screen_top_k={top_k}")
        common = dict(cache_dir=cfg.cache_dir, fingerprint=fingerprint,
                      surrogate=surrogate, screen_top_k=top_k,
                      phenotype_key=phenotype_key(coding,
                                                  resolver=impl_resolver),
                      compile_workers=cfg.compile_workers,
                      annotate=objmod.annotate_objectives(graph, coding)
                      if multi else None)
        if cfg.pool is not None:
            pool = ProcessPool(cfg.pool, workers=cfg.workers or None)
            evaluator = Evaluator(None, **pool.evaluator_kwargs(), **common)
        else:
            evaluator = Evaluator(fitness_fn, workers=cfg.workers, **common)
    try:
        ga = run_ga(coding.length, fitness_fn, cfg, log=log,
                    evaluator=evaluator, arity=coding.arity, seeds=seeds,
                    objective_fn=objective_fn if multi else None)
        ga = dataclasses.replace(ga, surrogate_kind=surrogate_kind)
        if owns and cfg.cache_dir and ga.screened_out == 0:
            # only unscreened searches are evidence: a screened search
            # measures the correlation on surrogate-selected survivors
            # (range-restricted), which would let auto-screening justify
            # itself with its own output
            record_search_meta(cfg.cache_dir, fingerprint,
                               ga.surrogate_rank_corr,
                               horizon_s=cfg.auto_screen_horizon_s,
                               kind=surrogate_kind)
        if owns and multi and cfg.cache_dir and cfg.fit_surrogate:
            # per-objective ridge fits from the (now annotated) journal —
            # persisted for inspection/screening evidence, one model per
            # extra objective from the same measurement rows
            from repro.core.surrogate import fit_surrogate
            for obj in tuple(cfg.objectives):
                if obj != "latency":
                    fit_surrogate(graph, coding, cfg.cache_dir, fingerprint,
                                  min_records=cfg.surrogate_min_records,
                                  objective=obj)
    finally:
        if owns:
            evaluator.close()
            if pool is not None:
                pool.close()
    return coding, ga


# ---------------------------------------------------------------------------
# seed bank: similarity-based warm starts across programs
# ---------------------------------------------------------------------------


def _map_destination_value(value: int, rec_destinations: Sequence[str],
                           coding: GeneCoding) -> int:
    """Translate one recorded gene value into the current alphabet.

    Cross-destination mapping (ROADMAP): a neighbor searched over a
    *different* alphabet (a GPU gene seeding an FPGA search, a binary gene
    seeding a variant search).  The recorded *destination name* is looked up
    in the current alphabet; a name the alphabet lacks maps by intent —
    reference stays reference, anything offloaded maps to the current
    primary accelerator (index 1) so the warm start preserves the on/off
    shape of the neighbor's pattern.  Legacy records without destination
    names clamp, preserving historical behavior.
    """
    value = int(value)
    if not rec_destinations:
        return min(max(value, 0), coding.arity - 1)
    if not (0 <= value < len(rec_destinations)):
        return 0
    name = rec_destinations[value]
    if name in coding.destinations:
        return coding.destinations.index(name)
    if value == 0:
        return 0
    return 1 if coding.arity > 1 else 0


class SeedBank:
    """Persistent (frontend, graph-vector) -> best-pattern store.

    The measurement cache only helps the *same* program; the seed bank helps
    a *near*-identical one (ROADMAP: similarity-based reuse): after every
    search the winning pattern is recorded with the program's Deckard-style
    characteristic vector, and a new search seeds its GA population from the
    best patterns of its nearest neighbors (mapped by region name and by
    destination *name* across alphabets, unknown regions defaulting to the
    reference destination).

    Hygiene: the journal is append-only (concurrent writers share it), with
    line order as the recency order.  A record that contributes a seed is
    re-appended ("touched"), and when the file outgrows ``2 * max_records``
    lines it is compacted — duplicates collapse to their most recent
    occurrence and only the newest ``max_records`` survive — an LRU bound
    instead of unbounded growth.  Writes (appends and the
    read-rewrite-replace compaction) serialize on a sidecar lock file so a
    concurrent writer's append can't vanish mid-compaction; reads stay
    lock-free (torn trailing lines are skipped by the loader).
    """

    def __init__(self, cache_dir: str, max_records: int = 128):
        os.makedirs(cache_dir, exist_ok=True)
        self.path = os.path.join(cache_dir, "seed_bank.jsonl")
        self._journal = Journal(self.path)
        self.max_records = max(1, int(max_records))

    @staticmethod
    def _key(rec: dict) -> tuple:
        return (rec.get("frontend"), rec.get("source"),
                tuple(rec.get("sites", ())), tuple(rec.get("values", ())),
                tuple(rec.get("destinations", ())))

    def _live(self) -> list[dict]:
        """Journal collapsed to unique records, oldest -> newest, bounded."""
        by_key: dict[tuple, dict] = {}
        for rec in self._journal.records():
            by_key.pop(self._key(rec), None)
            by_key[self._key(rec)] = rec      # reinsert: moves to the tail
        live = list(by_key.values())
        return live[-self.max_records:]

    def _append(self, recs: list[dict]) -> None:
        self._journal.append(recs)

    def _maybe_compact(self) -> None:
        # re-reads under the lock (Journal.compact), so a concurrent
        # writer's append can't land between read and replace
        self._journal.compact(lambda _recs: self._live(),
                              threshold=2 * self.max_records)

    def record(self, graph: RegionGraph, coding: GeneCoding,
               values: Sequence[int]) -> None:
        rec = {
            "frontend": graph.frontend,
            "source": graph.source_name,
            "vector": sim.graph_vector(graph),
            "sites": [s.region for s in coding.sites],
            "values": [int(v) for v in values],
            "destinations": list(coding.destinations),
        }
        self._append([rec])
        self._maybe_compact()

    def neighbor_seeds(self, graph: RegionGraph, coding: GeneCoding,
                       min_similarity: float = 0.75,
                       limit: int = 3) -> list[tuple]:
        vec = sim.graph_vector(graph)
        scored: list[tuple[float, dict]] = []
        for rec in self._live():
            if rec.get("frontend") != graph.frontend:
                continue
            s = sim.similarity(vec, rec.get("vector") or {})
            if s >= min_similarity:
                scored.append((s, rec))
        scored.sort(key=lambda sr: -sr[0])
        seeds: list[tuple] = []
        seen: set = set()
        used: list[dict] = []
        for _, rec in scored:
            site_vals = dict(zip(rec.get("sites", ()), rec.get("values", ())))
            dests = list(rec.get("destinations", ()))
            seed = tuple(
                _map_destination_value(site_vals.get(s.region, 0), dests,
                                       coding)
                for s in coding.sites)
            if seed not in seen:
                seeds.append(seed)
                seen.add(seed)
                used.append(rec)
            if len(seeds) >= limit:
                break
        if used:
            self._append(used)            # LRU touch: contributors stay fresh
            self._maybe_compact()
        return seeds


def _pattern_db_seed(graph: RegionGraph, coding: GeneCoding,
                     db) -> list[tuple]:
    """One warm-start chromosome: every gene whose region name-matches a
    pattern-DB record starts on the primary accelerator."""
    values = []
    any_hit = False
    for site in coding.sites:
        region = graph.by_name(site.region)
        hit = any(m.how == "name"
                  for m in db.match_region(region, graph.frontend))
        values.append(1 if hit else 0)
        any_hit |= hit
    return [tuple(values)] if any_hit else []


# ---------------------------------------------------------------------------
# the unified result
# ---------------------------------------------------------------------------


@dataclass
class OffloadResult:
    """What every frontend's planning run returns."""

    frontend: str
    graph: RegionGraph
    coding: GeneCoding
    block: Any                        # BlockOffloadResult
    ga: GAResult
    pattern: dict                     # region -> implementation (incl. blocks)
    destinations: dict                # gene region -> destination name
    baseline: Evaluation              # the all-reference program
    best: Evaluation
    transfer_plan: TransferPlan
    artifact: Any                     # frontend deliverable (impl map,
                                      # PyOffloadArtifact, ExecPlan, ...)
    verification: dict                # {"mode": ..., "verified": bool}
    report: Any = None                # SubstitutionReport — the uniform
                                      # what-runs-where record every
                                      # frontend produces (ground truth for
                                      # fallbacks; see repro.core.variants)
    details: dict = field(default_factory=dict)  # frontend-private extras

    @property
    def speedup(self) -> float:
        if not self.baseline.valid or not math.isfinite(self.best.time_s) \
                or self.best.time_s <= 0:
            return float("nan")
        return self.baseline.time_s / self.best.time_s

    @property
    def savings(self) -> dict:
        """The measurement-economy report (arXiv:2002.12115 accounting)."""
        g = self.ga
        return {
            "measurements": g.evaluations,
            "cache_hits": g.cache_hits,
            "persistent_hits": g.persistent_hits,
            "screened_out": g.screened_out,
            "duplicates_avoided": g.duplicates_avoided,
            "measurements_saved": g.measurements_saved,
            "surrogate_rank_corr": g.surrogate_rank_corr,
            "surrogate_kind": g.surrogate_kind,
            "wall_s": g.wall_s,
            "eval_wall_s": g.eval_wall_s,
            "compile_overlap_saved_s": g.compile_overlap_saved_s,
        }

    @property
    def front(self) -> list[Evaluation]:
        """The search's Pareto-optimal Evaluations (multi-objective mode;
        single-objective searches report just the best)."""
        return self.ga.front

    def front_summary(self) -> list[dict]:
        """JSON-safe Pareto front: one dict per non-dominated pattern with
        its bits and per-objective values (persisted into PlanRecord so a
        service can swap operating points without a new search).  Latency
        comes from the measurement; energy/transfer prefer the annotated
        detail fields and fall back to the objective models."""
        from repro.core import objectives as objmod

        out = []
        for ev in self.ga.front:
            vals = objmod.objective_values(ev, self.graph, self.coding)
            out.append({
                "bits": [int(v) for v in ev.bits],
                "latency_s": float(vals[0]),
                "energy_j": float(vals[1]),
                "transfer_bytes": float(vals[2]),
            })
        return out

    def operating_point(self, objective: str = "latency") -> Evaluation:
        """The front point optimal on one axis (an operating point a
        service picks per traffic level: ``latency`` under load,
        ``energy`` when idle).  Ties break toward lower latency; an empty
        front (single-objective search) returns ``best``."""
        from repro.core import objectives as objmod

        if not self.ga.front:
            return self.best
        try:
            ax = objmod.OBJECTIVES.index(objective)
        except ValueError:
            raise ValueError(f"unknown objective {objective!r}; known: "
                             f"{objmod.OBJECTIVES}") from None
        key = {}
        for ev in self.ga.front:
            key[id(ev)] = objmod.objective_values(ev, self.graph,
                                                  self.coding)
        return min(self.ga.front,
                   key=lambda e: (key[id(e)][ax], key[id(e)][0]))

    def summary(self) -> dict:
        return {
            "frontend": self.frontend,
            "gene_length": self.coding.length,
            "destinations": self.coding.destinations,
            "best": "".join(str(int(v)) for v in self.best.bits),
            "speedup": self.speedup,
            "verified": self.verification.get("verified", False),
            "substituted": dict(self.report.substituted) if self.report
            else {},
            "front_size": len(self.ga.front),
            **self.savings,
        }


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------


class _DestinationCostFitness:
    """Charge cost-only destinations' modeled time on top of measurements,
    preserving the inner fitness's two-phase (prepare/measure) protocol so
    the compile-overlap path still applies."""

    def __init__(self, graph: RegionGraph, coding: GeneCoding,
                 inner: Callable, mesh_executed: bool = False):
        self._graph, self._coding, self._inner = graph, coding, inner
        self._mesh_executed = mesh_executed

    def _charge(self, ev: Evaluation) -> Evaluation:
        pen = modeled_cost_s(self._graph, self._coding, ev.bits,
                             mesh_executed=self._mesh_executed)
        if pen > 0 and math.isfinite(ev.time_s):
            ev = Evaluation(ev.bits, ev.time_s + pen, ev.valid,
                            {**ev.detail, "modeled_cost_s": pen})
        return ev

    def __call__(self, values: tuple) -> Evaluation:
        return self._charge(self._inner(tuple(values)))


class _TwoPhaseDestinationCostFitness(_DestinationCostFitness):
    def prepare(self, values: tuple):
        return self._inner.prepare(tuple(values))

    def measure(self, prepared) -> Evaluation:
        return self._charge(self._inner.measure(prepared))


def _with_destination_costs(graph: RegionGraph, coding: GeneCoding,
                            fitness_fn: Callable,
                            mesh_executed: bool = False) -> Callable:
    """Charge modeled destination time on top of measurements: cost-only
    stubs always, mesh genes unless the frontend's measured path genuinely
    decodes them to shard_map execution (``mesh_executed``, from
    :attr:`FitnessBundle.mesh_executed`)."""
    dests = [get_destination(d) for d in coding.destinations]

    def may_charge(d) -> bool:
        if d.placement_tag is None:
            return False               # plain executable device: measured
        return not (mesh_executed and not d.is_cost_only)

    if not any(may_charge(d) for d in dests):
        return fitness_fn
    cls = _TwoPhaseDestinationCostFitness \
        if hasattr(fitness_fn, "prepare") and hasattr(fitness_fn, "measure") \
        else _DestinationCostFitness
    return cls(graph, coding, fitness_fn, mesh_executed=mesh_executed)


@dataclass
class PlanContext:
    """The search-free front half of a planning run.

    ``Offloader.prepare`` normalizes a target through its frontend — graph,
    fitness bundle, gene coding, and the persistent-cache ``fingerprint``
    the search would key its journals by — **without running any search**.
    The context is everything the execution side needs: ``Offloader.apply``
    decodes stored winner bits into the frontend artifact (a pure artifact
    load), and ``Offloader.search`` runs the GA over it.  The plan service
    uses prepare for request admission (fingerprint lookup / coalescing)
    and apply for warm plan-store hits.
    """

    frontend: str
    target: Any
    inputs: Optional[dict]
    config: OffloadConfig
    graph: RegionGraph
    bundle: FitnessBundle
    coding: GeneCoding
    fingerprint: str

    @property
    def sites(self) -> tuple[str, ...]:
        """Gene-site region names, in gene order — the plan-store
        compatibility check (bits only make sense against these)."""
        return tuple(s.region for s in self.coding.sites)


@dataclass
class Offloader:
    """The unified multi-frontend offload planner.

    ``plan`` is the one-shot pipeline; it is literally
    ``search(prepare(target))``.  The halves are public because the
    persistent planning service needs them apart: ``prepare`` admits a
    request (fingerprint, no search), ``apply`` loads a stored plan's
    artifact (no search), ``search`` is the only place measurements run.
    """

    config: OffloadConfig = field(default_factory=OffloadConfig)

    def prepare(self, target: Any, inputs: Optional[dict] = None,
                config: Optional[OffloadConfig] = None) -> PlanContext:
        """Frontend half of planning: normalize -> graph -> fitness bundle
        -> gene coding -> search fingerprint.  Runs no search and takes no
        measurement (frontends may run the *reference* program once to have
        something to verify against)."""
        cfg = config or self.config
        log = cfg.log or (lambda s: None)
        name = cfg.frontend or detect_frontend(target, cfg)
        fe = get_frontend(name)
        log(f"frontend: {name}")

        with obs_trace.maybe_tracing(cfg.trace), \
                obs_trace.span("plan.prepare", frontend=name) as sp:
            if hasattr(fe, "normalize_target"):
                target = fe.normalize_target(target, inputs, cfg)
            with obs_trace.span("prepare.build_graph"):
                graph = fe.build_graph(target, inputs, cfg)
            with obs_trace.span("prepare.make_fitness"):
                bundle: FitnessBundle = fe.make_fitness(graph, target,
                                                        inputs, cfg)
            destinations = resolve_alphabet(cfg, bundle.destinations)
            coding = coding_from_graph(graph, exclude=bundle.claimed,
                                       destinations=destinations)
            log(f"graph: {graph.summary()} gene_length={coding.length} "
                f"alphabet={coding.destinations}")
            fingerprint = search_fingerprint(graph, coding, bundle.claimed,
                                             bundle.cache_extra)
            sp.set(fingerprint=fingerprint, gene_length=coding.length,
                   regions=len(graph.regions))
        return PlanContext(frontend=name, target=target, inputs=inputs,
                           config=cfg, graph=graph, bundle=bundle,
                           coding=coding, fingerprint=fingerprint)

    def apply(self, ctx: PlanContext, values: Sequence[int]) -> Any:
        """Pure artifact loader: decode ``values`` (a stored winner
        chromosome) into the frontend deliverable — ``SubstitutedCallable``,
        ``PyOffloadArtifact``, ``ExecPlan``, or an impl map.  No search, no
        measurement: this is the execution side of the split, what a warm
        plan-store hit runs instead of a GA."""
        values = tuple(int(v) for v in values)
        if len(values) != ctx.coding.length:
            raise ValueError(
                f"plan has {len(values)} genes but the program codes "
                f"{ctx.coding.length} — stored plan does not fit this target")
        fe = get_frontend(ctx.frontend)
        with obs_trace.maybe_tracing(ctx.config.trace), \
                obs_trace.span("plan.apply", frontend=ctx.frontend,
                               bits="".join(str(v) for v in values)):
            return fe.apply_plan(ctx.graph, ctx.coding, values, ctx.bundle)

    def plan(self, target: Any, inputs: Optional[dict] = None,
             config: Optional[OffloadConfig] = None) -> OffloadResult:
        """Plan offloading for any supported target; see module docstring."""
        cfg = config or self.config
        with obs_trace.maybe_tracing(cfg.trace), \
                obs_trace.span("offload.plan") as sp:
            ctx = self.prepare(target, inputs, config)
            sp.set(frontend=ctx.frontend, fingerprint=ctx.fingerprint)
            return self.search(ctx)

    def search(self, ctx: PlanContext,
               ga: Optional[GAConfig] = None,
               extra_seeds: Sequence[Sequence[int]] = ()) -> OffloadResult:
        """Measurement half of planning: compose the fitness, warm-start the
        population, run the GA, and assemble the unified result.

        ``ga`` overrides ``ctx.config.ga`` (the refinement loop bumps seed /
        generations); ``extra_seeds`` are prepended warm starts (the
        refinement loop seeds with the deployed plan's chromosome).
        """
        with obs_trace.maybe_tracing(ctx.config.trace), \
                obs_trace.span("plan.search", frontend=ctx.frontend,
                               fingerprint=ctx.fingerprint) as sp:
            res = self._search(ctx, ga, extra_seeds)
            sp.set(best_time_s=res.best.time_s,
                   evaluations=res.ga.evaluations,
                   generations=len(res.ga.history),
                   front_size=len(res.ga.front))
            return res

    def _search(self, ctx: PlanContext, ga: Optional[GAConfig],
                extra_seeds: Sequence[Sequence[int]]) -> OffloadResult:
        from repro.core.pattern_db import default_db

        cfg = ctx.config
        log = cfg.log or (lambda s: None)
        graph, bundle, coding = ctx.graph, ctx.bundle, ctx.coding

        fitness = cfg.fitness_fn or bundle.fitness_factory(coding)
        fitness = _with_destination_costs(graph, coding, fitness,
                                          mesh_executed=bundle.mesh_executed)

        ga_cfg = ga or cfg.ga
        if bundle.serial_only and (ga_cfg.workers > 1
                                   or ga_cfg.pool is not None):
            # wall-clock measurements interleave on shared hardware —
            # parallel timing is meaningless
            log("wall-clock fitness: forcing serial evaluation (workers=0)")
            ga_cfg = dataclasses.replace(ga_cfg, workers=0, pool=None)
        if ga_cfg.compile_workers is None and bundle.overlap_compiles:
            # the frontend vouches that a chromosome's warm-up is one big
            # GIL-releasing compile: overlap different chromosomes' compiles
            # ahead of the (still strictly serial) timing loop
            cw = min(4, os.cpu_count() or 1)
            if cw > 1:
                log(f"compile-parallel/time-serial warm-ups: "
                    f"compile_workers={cw}")
                ga_cfg = dataclasses.replace(ga_cfg, compile_workers=cw)
        if ga_cfg.pool is not None:
            # pool workers rebuild their fitness from the registered factory
            # and cannot see the fitness this pipeline just composed (block
            # claims folded into base_impl, gene exclusions, destination
            # costs, cfg.fitness_fn) — measuring one function while planning
            # another would silently corrupt the result
            raise ValueError(
                "GAConfig.pool cannot be used through Offloader.plan: the "
                "factory-built worker fitness cannot match the pipeline-"
                "composed fitness. Drive ga_search directly with a factory "
                "that reproduces your fitness, or use thread workers "
                "(GAConfig.workers) here")

        # --- GA population warm starts ---------------------------------
        seeds: list[tuple] = [tuple(int(v) for v in s) for s in extra_seeds]
        if cfg.seed_from_db and coding.length:
            seeds += _pattern_db_seed(graph, coding, cfg.db or default_db())
        bank: Optional[SeedBank] = None
        if cfg.seed_from_neighbors and ga_cfg.cache_dir:
            bank = SeedBank(ga_cfg.cache_dir)
            if coding.length:
                neigh = bank.neighbor_seeds(graph, coding)
                if neigh:
                    log(f"seed bank: {len(neigh)} neighbor seed(s)")
                seeds += neigh

        coding, ga_res = ga_search(
            graph, fitness, ga_cfg, coding=coding, exclude=bundle.claimed,
            log=log, cache_extra=bundle.cache_extra, seeds=seeds,
            impl_resolver=bundle.impl_resolver)

        best = ga_res.best
        artifact = self.apply(ctx, best.bits)
        if bank is not None and coding.length:
            bank.record(graph, coding, best.bits)
        return self._assemble(ctx, ga_res, artifact)

    def _assemble(self, ctx: PlanContext, ga_res: GAResult,
                  artifact: Any) -> OffloadResult:
        """Package search output (or a loaded plan) as the unified result."""
        cfg, graph, bundle, coding = (ctx.config, ctx.graph, ctx.bundle,
                                      ctx.coding)
        best = ga_res.best
        pattern = decoded_pattern(coding, best.bits, bundle.base_impl)
        # the uniform substitution report: frontends with a real resolution
        # step supply one (the jaxpr engine / ast variant menus); everyone
        # else gets the generic decode-level record — same shape either way
        report = bundle.context.get("substitution_report") \
            or getattr(artifact, "report", None)
        if report is None:
            patterns = {o.region: o.pattern
                        for o in (bundle.block.offloads if bundle.block
                                  else ())}
            for r in graph.offloadable():
                if r.meta.get("pattern"):
                    patterns.setdefault(r.name, r.meta["pattern"])
            report = generic_plan_report(coding, best.bits,
                                         base_impl=bundle.base_impl,
                                         patterns=patterns)
        tp = plan_transfers(graph, pattern, hoist=cfg.hoist_transfers)

        baseline = bundle.context.get("baseline") or ga_res.baseline or best
        verification = {
            "mode": "measured" if bundle.measured else "static-cost",
            "verified": bool(best.valid) and bundle.measured,
        }
        return OffloadResult(
            frontend=ctx.frontend, graph=graph, coding=coding,
            block=bundle.block, ga=ga_res, pattern=pattern,
            destinations=coding.destinations_of(best.bits),
            baseline=baseline, best=best, transfer_plan=tp,
            artifact=artifact, verification=verification,
            report=report, details=dict(bundle.context))


def plan(target: Any, inputs: Optional[dict] = None,
         config: Optional[OffloadConfig] = None,
         **config_kwargs) -> OffloadResult:
    """The module-level one-liner: ``plan(src, inputs, ga=GAConfig(...))``.

    Builds an :class:`Offloader` around an :class:`OffloadConfig` (either
    passed whole via ``config=`` or assembled from keyword fields) and runs
    the full pipeline — the convenience path that replaced the retired
    ``plan_python_offload`` / ``plan_module_offload`` shims.  Frontend
    detection, alphabet resolution (:func:`resolve_alphabet`), seeding,
    search, and verification all behave exactly as :meth:`Offloader.plan`.
    """
    if config is not None and config_kwargs:
        raise ValueError("pass either config= or keyword fields, not both")
    cfg = config or OffloadConfig(**config_kwargs)
    return Offloader(cfg).plan(target, inputs)


#: historical alias of :func:`plan` — same signature, same behavior.
plan_offload = plan
