"""Jaxpr kernel-substitution engine: plans become runnable programs.

The paper's pipeline ends with *converted code* — matched functional blocks
replaced by library implementations, the converted program measured and
verified on the target.  This module closes that loop for the jaxpr
frontend: given the traced program, its :class:`~repro.core.ir.RegionGraph`
(whose regions carry equation spans from the frontend) and a
region -> implementation map decoded from a chromosome, it re-emits the
program with each matched region routed through the chosen variant from the
kernel registry (:mod:`repro.kernels.registry`).

Interception is equation-group based: the engine walks the jaxpr in program
order, and at a substituted region's span it feeds the span's free inputs to
the variant's bound adapter and binds the adapter's outputs to the span's
outputs, skipping the original equations; everything else executes through
``primitive.bind`` exactly as ``jax.core.eval_jaxpr`` would.  Variant
binding happens *eagerly* against the jaxpr's abstract values (plus an
``eval_shape`` output check), so every fallback decision is recorded in the
:class:`SubstitutionReport` before anything runs — and a variant whose
predicate rejects the concrete shapes silently degrades to the reference
equations instead of failing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
from jax import core as jcore

from repro.core.ir import RegionGraph
# the resolve/check/fallback rule and the report types are frontend-neutral
# (repro.core.variants) — re-exported here for compatibility with PR-3 users
from repro.core.variants import (_REF_IMPLS, SubstitutionChoice,  # noqa: F401
                                 SubstitutionReport, check_adapter,
                                 resolve_variant)
from repro.kernels.registry import (CallSite, KernelRegistry,
                                    default_registry)

__all__ = ["SiteBinding", "SubstitutionChoice", "SubstitutionReport",
           "SubstitutedCallable", "SubstitutionEngine"]


class SubstitutedCallable:
    """A runnable substituted program: same signature as the traced source.

    ``fn`` is the raw (traceable) callable; calling the object runs a
    cached ``jax.jit`` of it.  ``report`` says which regions were
    substituted with which variant and why the rest fell back.
    """

    def __init__(self, fn: Callable, report: SubstitutionReport,
                 name: str = "substituted"):
        self.fn = fn
        self.report = report
        self.name = name
        self._jitted: Optional[Callable] = None

    def __call__(self, *args):
        if self._jitted is None:
            self._jitted = jax.jit(self.fn)
        return self._jitted(*args)

    def __repr__(self) -> str:
        return (f"SubstitutedCallable({self.name!r}, "
                f"substituted={self.report.substituted}, "
                f"fallbacks={list(self.report.fallbacks)})")


# ---------------------------------------------------------------------------
# sites: regions concretized against the jaxpr
# ---------------------------------------------------------------------------


@dataclass
class SiteBinding:
    """One substitutable region resolved to jaxpr vars."""

    region: str
    pattern: Optional[str]
    kind: str                          # "span" | "call" | "scan" | "block"
    span: tuple                       # (start, end) eqn indices
    in_vars: tuple                     # free inputs (first-use order for spans)
    out_vars: tuple                    # outputs (DropVar-preserving for eqns)
    params: dict = field(default_factory=dict)

    def call_site(self, out_used: Sequence[bool], backend: str,
                  eqns: tuple = ()) -> CallSite:
        return CallSite(
            pattern=self.pattern or "",
            kind=self.kind,
            in_avals=tuple(v.aval for v in self.in_vars),
            out_avals=tuple(v.aval for v in self.out_vars),
            out_used=tuple(out_used),
            params=dict(self.params),
            backend=backend,
            eqns=tuple(eqns),
            in_vars=tuple(self.in_vars))


def _span_io(eqns: Sequence, used_later: Callable) -> tuple[tuple, tuple]:
    """Free inputs (first-use order) and live outputs of an equation group."""
    defined: set = set()
    ins: list = []
    for eqn in eqns:
        for v in eqn.invars:
            if isinstance(v, jcore.Literal) or v in defined or v in ins:
                continue
            ins.append(v)
        defined.update(o for o in eqn.outvars
                       if not isinstance(o, jcore.DropVar))
    outs = [o for eqn in eqns for o in eqn.outvars
            if not isinstance(o, jcore.DropVar) and used_later(o)]
    return tuple(ins), tuple(outs)


#: call-like primitives whose inner jaxpr can be inlined during per-shard
#: re-interpretation.  Loop primitives (scan/while/cond) stay bound — their
#: body shapes are part of the loop semantics, not just trace residue.
_INLINE_CALL_PRIMS = {"pjit", "custom_jvp_call", "custom_vjp_call", "remat",
                      "checkpoint", "closed_call", "core_call"}


def _inline_closed(eqn):
    """The inner ClosedJaxpr of a call-like equation, or None.  Used by the
    mesh adapter to interpret call bodies with per-shard shapes instead of
    re-binding the call (whose stored jaxpr is specialized to the global
    trace shapes)."""
    if eqn.primitive.name not in _INLINE_CALL_PRIMS:
        return None
    for k in ("jaxpr", "call_jaxpr"):
        j = eqn.params.get(k)
        if j is None:
            continue
        if hasattr(j, "jaxpr"):                  # already a ClosedJaxpr
            return j
        if hasattr(j, "eqns"):                   # raw Jaxpr: close it
            return jcore.ClosedJaxpr(j, ())
    return None


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class SubstitutionEngine:
    """Re-emit a traced function with matched regions routed to variants.

    The graph must come from the jaxpr frontend with ``meta["eqn_span"]``
    populated.  The frontend's own trace (``graph.meta["closed_jaxpr"]`` /
    ``["out_tree"]``) is reused when present — the spans then index this
    engine's jaxpr by construction; otherwise ``fn`` is re-traced with the
    same example arguments.
    """

    def __init__(self, fn: Callable, example_args: tuple,
                 graph: RegionGraph,
                 registry: Optional[KernelRegistry] = None,
                 backend: Optional[str] = None):
        self.fn = fn
        self.example_args = tuple(example_args)
        self.graph = graph
        self.registry = registry or default_registry()
        self.backend = backend or jax.default_backend()
        self.closed = graph.meta.get("closed_jaxpr")
        self._out_tree = graph.meta.get("out_tree")
        if self.closed is None or self._out_tree is None:
            self.closed, out_shape = jax.make_jaxpr(
                fn, return_shape=True)(*self.example_args)
            self._out_tree = jax.tree_util.tree_structure(out_shape)
        self._sites = self._resolve_sites()
        self._reference: Any = None
        self._resolved: dict = {}      # (region, requested) -> resolution
        self._mesh_resolved: dict = {}  # (region, mesh name) -> (adapter, why)

    # -- site resolution ----------------------------------------------------

    def _resolve_sites(self) -> list[SiteBinding]:
        jaxpr = self.closed.jaxpr
        eqns = jaxpr.eqns
        # var -> last eqn index that reads it (or +inf if a program output)
        last_use: dict = {}
        for i, eqn in enumerate(eqns):
            for v in eqn.invars:
                if not isinstance(v, jcore.Literal):
                    last_use[v] = i
        program_outs = {v for v in jaxpr.outvars
                        if not isinstance(v, jcore.Literal)}

        sites: list[SiteBinding] = []
        for region in self.graph.offloadable():
            span = region.meta.get("eqn_span")
            if span is None:
                continue
            s, e = span
            if not (0 <= s < e <= len(eqns)):
                continue
            pattern = region.meta.get("pattern")
            if e - s == 1 and region.meta.get("primitive"):
                # a loop/call region wrapping exactly one closed equation
                eqn = eqns[s]
                pname = eqn.primitive.name
                kind = "scan" if pname == "scan" else "call"
                params = {}
                if kind == "scan":
                    params = {k: eqn.params.get(k)
                              for k in ("num_consts", "num_carry", "length",
                                        "reverse")}
                sites.append(SiteBinding(
                    region.name, pattern, kind, (s, e),
                    in_vars=tuple(v for v in eqn.invars
                                  if not isinstance(v, jcore.Literal)),
                    out_vars=tuple(eqn.outvars), params=params))
            else:
                def used_later(v, _e=e):
                    return v in program_outs or last_use.get(v, -1) >= _e
                ins, outs = _span_io(eqns[s:e], used_later)
                # fnblock regions (merged multi-region spans from the block
                # pass) bind block-level variants; plain spans stay spans
                kind = "block" if region.meta.get("block_members") else "span"
                sites.append(SiteBinding(
                    region.name, pattern, kind, (s, e), ins, outs))
        return sites

    @property
    def sites(self) -> tuple[SiteBinding, ...]:
        return tuple(self._sites)

    # -- variant resolution -------------------------------------------------

    def _out_used(self, site: SiteBinding) -> list[bool]:
        if site.kind in ("span", "block"):
            return [True] * len(site.out_vars)   # spans keep live outs only
        jaxpr = self.closed.jaxpr
        last_use: set = set()
        for eqn in jaxpr.eqns[site.span[1]:]:
            last_use.update(v for v in eqn.invars
                            if not isinstance(v, jcore.Literal))
        last_use.update(v for v in jaxpr.outvars
                        if not isinstance(v, jcore.Literal))
        return [not isinstance(v, jcore.DropVar) and v in last_use
                for v in site.out_vars]

    def _resolve_variant(self, site: SiteBinding, requested: str
                         ) -> tuple[Optional[Callable], str, str]:
        """-> (adapter or None, chosen name, why).  Resolution depends only
        on (region, requested) for the engine's lifetime, and substitute()
        runs once per GA chromosome — memoized."""
        key = (site.region, requested)
        hit = self._resolved.get(key)
        if hit is not None:
            return hit
        self._resolved[key] = out = self._resolve_variant_uncached(
            site, requested)
        return out

    def _resolve_variant_uncached(self, site: SiteBinding, requested: str
                                  ) -> tuple[Optional[Callable], str, str]:
        """Concretize the site to a CallSite and apply the shared
        frontend-neutral resolution rule (repro.core.variants)."""
        if requested not in _REF_IMPLS and site.pattern is not None:
            out_used = self._out_used(site)
            eqns = self.closed.jaxpr.eqns[site.span[0]:site.span[1]] \
                if site.kind in ("span", "block") else ()
            call_site = site.call_site(out_used, self.backend, eqns=eqns)
        else:                          # resolution needs no concretization
            call_site = site.call_site([True] * len(site.out_vars),
                                       self.backend)
        return resolve_variant(call_site, requested, registry=self.registry,
                               backend=self.backend)

    # -- mesh destinations --------------------------------------------------

    def _mesh_adapter(self, site: SiteBinding, dest
                      ) -> tuple[Optional[Callable], str]:
        """-> (shard_map'd span adapter or None, why).  Memoized: the
        decision depends only on (site, mesh destination) for the engine's
        lifetime — avals and the device set are fixed."""
        key = (site.region, dest.name)
        hit = self._mesh_resolved.get(key)
        if hit is not None:
            return hit
        self._mesh_resolved[key] = out = self._mesh_adapter_uncached(site,
                                                                     dest)
        return out

    def _mesh_adapter_uncached(self, site: SiteBinding, dest
                               ) -> tuple[Optional[Callable], str]:
        """Build the genuine mesh execution of a site: the span's own
        equations re-interpreted under ``shard_map`` on an n-device mesh.

        The sharding heuristic is deliberately conservative and shape-
        checked: the destination's spec names a dimension (batch = leading,
        feature = trailing), every output must carry the same extent on it
        (a reduction over the sharded dim cannot recombine by
        concatenation), inputs that carry it are sharded and the rest
        replicated.  Anything the heuristic cannot place — or that
        shard_map rejects at trace time — falls back to the normal variant
        path with the reason reported; a placement that type-checks but
        computes wrong partials is caught by the search's numeric
        verification and discarded as an invalid chromosome (the paper's
        environment-adaptive trial-and-error)."""
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import make_destination_mesh
        from repro.runtime.pspec import shard_map_compat

        if any(isinstance(v, jcore.DropVar) for v in site.out_vars):
            return None, "site has dropped outputs"
        out_shapes = [tuple(getattr(v.aval, "shape", ()))
                      for v in site.out_vars]
        if not out_shapes:
            return None, "site has no outputs"
        dim = dest.shard_dim

        def dim_of(shape: tuple) -> Optional[int]:
            d = dim + len(shape) if dim < 0 else dim
            return d if 0 <= d < len(shape) else None

        d0 = dim_of(out_shapes[0])
        if d0 is None:
            return None, "output lacks the sharded dimension"
        extent = out_shapes[0][d0]
        if extent == 0 or extent % dest.n != 0:
            return None, (f"output dim {extent} not divisible by "
                          f"n={dest.n}")
        for shape in out_shapes:
            d = dim_of(shape)
            if d is None or shape[d] != extent:
                return None, "outputs disagree on the sharded dimension"

        def spec_for(shape: tuple):
            d = dim_of(shape)
            if d is not None and shape[d] == extent:
                parts: list = [None] * len(shape)
                parts[d] = dest.axis
                return P(*parts)
            return P()

        in_specs = tuple(spec_for(tuple(getattr(v.aval, "shape", ())))
                         for v in site.in_vars)
        out_specs = tuple(spec_for(s) for s in out_shapes)
        if all(sp == P() for sp in in_specs):
            return None, "no input carries the sharded dimension"

        eqns = tuple(self.closed.jaxpr.eqns[site.span[0]:site.span[1]])
        in_vars, out_vars = tuple(site.in_vars), tuple(site.out_vars)

        def span_fn(*ins):
            # Re-interpret the span with *per-shard* inputs.  Nested call
            # primitives (pjit, custom_jvp_call, ...) must be inlined rather
            # than bound: their stored jaxprs are specialized to the global
            # trace shapes and would re-impose them on the shards, while
            # their member equations are shape-polymorphic.
            def eval_eqns(eqns_, env):
                def read(v):
                    return v.val if isinstance(v, jcore.Literal) else env[v]

                for eqn in eqns_:
                    inner = _inline_closed(eqn)
                    if inner is not None \
                            and len(inner.jaxpr.invars) == len(eqn.invars):
                        ienv: dict = dict(zip(inner.jaxpr.constvars,
                                              inner.consts))
                        ienv.update(zip(inner.jaxpr.invars,
                                        [read(v) for v in eqn.invars]))
                        eval_eqns(inner.jaxpr.eqns, ienv)
                        outs = [v.val if isinstance(v, jcore.Literal)
                                else ienv[v] for v in inner.jaxpr.outvars]
                    else:
                        subfuns, bind_params = \
                            eqn.primitive.get_bind_params(eqn.params)
                        ans = eqn.primitive.bind(
                            *subfuns, *[read(v) for v in eqn.invars],
                            **bind_params)
                        outs = ans if eqn.primitive.multiple_results \
                            else [ans]
                    for v, a in zip(eqn.outvars, outs):
                        if not isinstance(v, jcore.DropVar):
                            env[v] = a

            env: dict = dict(zip(in_vars, ins))
            eval_eqns(eqns, env)
            return tuple(env[v] for v in out_vars)

        try:
            mesh = make_destination_mesh(dest.n, dest.axis)
            sharded = shard_map_compat(span_fn, mesh=mesh,
                                       in_specs=in_specs,
                                       out_specs=out_specs)
            got = jax.eval_shape(
                sharded, *[jax.ShapeDtypeStruct(v.aval.shape, v.aval.dtype)
                           for v in in_vars])
        except Exception as e:  # noqa: BLE001 — any trace-time rejection
            return None, f"shard_map build failed: {type(e).__name__}: {e}"
        for g, v in zip(got, out_vars):
            if (tuple(g.shape) != tuple(v.aval.shape)
                    or g.dtype != v.aval.dtype):
                return None, "sharded span changes output shape/dtype"
        return sharded, (f"shard_map over {dest.n}x{dest.axis} "
                         f"(spec {dest.spec})")

    # -- substitution -------------------------------------------------------

    def substitute(self, impl: dict,
                   destinations: Optional[dict] = None
                   ) -> SubstitutedCallable:
        """``impl``: region -> implementation id ("ref", a variant name, or
        the legacy "kernel" auto choice).  Returns the runnable program.

        ``destinations`` (region -> destination name, from
        :meth:`GeneCoding.destinations_of`) routes mesh-assigned sites
        through :meth:`_mesh_adapter`: on hosts with enough devices the
        site's span genuinely runs under shard_map; otherwise (or when the
        heuristic rejects the shapes) the site falls back to the normal
        variant resolution with the reason reported."""
        from repro.core.genes import get_destination, probed_device_count

        report = SubstitutionReport()
        actions: dict[int, tuple[SiteBinding, Callable]] = {}
        skip_until: dict[int, int] = {}
        # widest-first: when a block site substitutes, its adapter computes
        # the whole merged span — member sites inside it are claimed and any
        # variant requested on them falls back to ref (reported as such)
        accepted: list[tuple[int, int, str]] = []
        for site in sorted(self._sites,
                           key=lambda s: s.span[0] - s.span[1]):
            requested = str(impl.get(site.region, "ref"))
            owner = next((r for s0, e0, r in accepted
                          if site.span[0] < e0 and s0 < site.span[1]), None)
            if owner is not None:
                report.choices.append(SubstitutionChoice(
                    site.region, site.pattern, requested, "ref",
                    f"claimed by block {owner}"))
                continue
            dname = (destinations or {}).get(site.region)
            if dname and dname.startswith("mesh:"):
                mesh_dest = get_destination(dname)
                if mesh_dest.is_cost_only:
                    adapter, chosen, why = self._resolve_variant(site,
                                                                 requested)
                    why = (f"mesh {mesh_dest.name!r} unavailable "
                           f"({probed_device_count()} device(s) < "
                           f"{mesh_dest.n}): modeled cost charged; {why}")
                else:
                    adapter, mesh_why = self._mesh_adapter(site, mesh_dest)
                    if adapter is not None:
                        chosen, why = mesh_dest.name, mesh_why
                    else:
                        adapter, chosen, why = self._resolve_variant(
                            site, requested)
                        why = (f"mesh {mesh_dest.name!r} rejected "
                               f"({mesh_why}); {why}")
                report.choices.append(SubstitutionChoice(
                    site.region, site.pattern, mesh_dest.name, chosen, why))
            else:
                adapter, chosen, why = self._resolve_variant(site, requested)
                report.choices.append(SubstitutionChoice(
                    site.region, site.pattern, requested, chosen, why))
            if adapter is not None:
                actions[site.span[0]] = (site, adapter)
                skip_until[site.span[0]] = site.span[1]
                accepted.append((site.span[0], site.span[1], site.region))

        closed, out_tree = self.closed, self._out_tree
        n_in = len(closed.jaxpr.invars)

        def run(*args):
            flat = jax.tree_util.tree_leaves(args)
            if len(flat) != n_in:
                raise TypeError(f"expected {n_in} input leaves, got "
                                f"{len(flat)}")
            jaxpr = closed.jaxpr
            env: dict = {}

            def read(v):
                return v.val if isinstance(v, jcore.Literal) else env[v]

            def write(v, val):
                if not isinstance(v, jcore.DropVar):
                    env[v] = val

            for v, c in zip(jaxpr.constvars, closed.consts):
                env[v] = c
            for v, a in zip(jaxpr.invars, flat):
                env[v] = a

            i = 0
            eqns = jaxpr.eqns
            while i < len(eqns):
                act = actions.get(i)
                if act is not None:
                    site, adapter = act
                    outs = adapter(*[read(v) for v in site.in_vars])
                    for v, o in zip(site.out_vars, outs):
                        if o is not None:
                            write(v, o)
                    i = skip_until[i]
                    continue
                eqn = eqns[i]
                subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
                ans = eqn.primitive.bind(
                    *subfuns, *[read(v) for v in eqn.invars], **bind_params)
                if eqn.primitive.multiple_results:
                    for v, a in zip(eqn.outvars, ans):
                        write(v, a)
                else:
                    write(eqn.outvars[0], ans)
                i += 1

            outvals = [read(v) for v in jaxpr.outvars]
            return jax.tree_util.tree_unflatten(out_tree, outvals)

        return SubstitutedCallable(run, report, self.graph.source_name)

    # -- convenience --------------------------------------------------------

    def resolved_impl(self, region: str, impl_id) -> str:
        """The implementation that would actually run at ``region`` under
        ``impl_id``, after the eager bind/fallback rule — ``"ref"`` when
        the variant cannot bind (or the region has no site).  This is the
        frontend's contribution to the phenotype key: two plans whose
        variants both fall back at a site are the same program and share
        one measurement.  Resolution is memoized per (region, impl) and
        static for the engine's lifetime (avals are fixed)."""
        requested = str(impl_id)
        site = next((s for s in self._sites if s.region == region), None)
        if site is None:
            # substitute() leaves regions without a site untouched — any
            # requested impl there runs the reference path
            return "ref"
        _adapter, chosen, _why = self._resolve_variant(site, requested)
        return chosen

    def _site_values(self, site: SiteBinding) -> tuple[list, list]:
        """One reference interpretation up to the site's span end, capturing
        the concrete values of its free inputs and live outputs."""
        closed = self.closed
        jaxpr = closed.jaxpr
        flat = jax.tree_util.tree_leaves(self.example_args)
        env: dict = dict(zip(jaxpr.constvars, closed.consts))
        env.update(zip(jaxpr.invars, flat))

        def read(v):
            return v.val if isinstance(v, jcore.Literal) else env[v]

        for eqn in jaxpr.eqns[:site.span[1]]:
            subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
            ans = eqn.primitive.bind(
                *subfuns, *[read(v) for v in eqn.invars], **bind_params)
            outs = ans if eqn.primitive.multiple_results else [ans]
            for v, a in zip(eqn.outvars, outs):
                if not isinstance(v, jcore.DropVar):
                    env[v] = a
        return ([read(v) for v in site.in_vars],
                [env.get(v) for v in site.out_vars])

    def verify_block(self, region: str, impl_id,
                     rtol: float = 1e-2, atol: float = 1e-2):
        """Block-granularity verification: allclose of the bound adapter's
        outputs against the reference interpretation *over the whole span*
        (not just the program outputs), on the example arguments.  Returns
        ``(VerifyResult, chosen_impl)``; a predicate rejection verifies
        trivially as the reference path with ``chosen == "ref"``."""
        from repro.core.verifier import VerifyResult, verify as _verify

        from repro.core.pattern_db import record_pattern_outcome

        site = next((s for s in self._sites if s.region == region), None)
        if site is None:
            raise KeyError(f"no substitutable site for region {region!r}")
        adapter, chosen, why = self._resolve_variant(site, str(impl_id))
        if adapter is None:
            if chosen == "ref" and str(impl_id) not in ("ref", "interp",
                                                        "host", "cpu"):
                record_pattern_outcome(None, site.pattern, str(impl_id),
                                       "bind_fail", region=region)
            return VerifyResult(True, 0.0, 0.0, why), chosen
        ins, ref_outs = self._site_values(site)
        got = adapter(*ins)
        used = self._out_used(site)
        ref_used = [o for o, u in zip(ref_outs, used) if u]
        got_used = [o for o, u in zip(got, used) if u]
        res = _verify(ref_used, got_used, rtol=rtol, atol=atol)
        record_pattern_outcome(None, site.pattern, chosen,
                               "ok" if res.ok else "verify_fail",
                               region=region)
        return res, chosen

    def reference(self) -> Any:
        """The unsubstituted program's outputs on the example arguments
        (computed once, then cached)."""
        if self._reference is None:
            self._reference = self.fn(*self.example_args)
        return self._reference

    def verify(self, impl, rtol: float = 1e-2, atol: float = 1e-2):
        """Numeric equivalence of a substituted program vs the reference
        (:func:`repro.core.verifier.verify`).  ``impl`` is a region -> impl
        map, or a :class:`SubstitutedCallable` already built from one."""
        from repro.core.verifier import verify as _verify

        sub = impl if isinstance(impl, SubstitutedCallable) \
            else self.substitute(impl)
        return _verify(self.reference(), sub(*self.example_args),
                       rtol=rtol, atol=atol)
