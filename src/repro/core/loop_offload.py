"""Loop-statement offload pass (paper §3.2.1 / §4.2.2): GA over the loops the
function-block pass did not claim."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from repro.core.ga import GAConfig, GAResult, run_ga
from repro.core.genes import GeneCoding, coding_from_graph
from repro.core.ir import RegionGraph


@dataclass
class LoopOffloadResult:
    coding: GeneCoding
    ga: GAResult

    @property
    def best_impl(self) -> dict:
        return self.coding.decode(self.ga.best.bits)


def loop_offload_pass(graph: RegionGraph,
                      fitness_fn: Callable,
                      ga_cfg: Optional[GAConfig] = None,
                      exclude: Sequence[str] = (),
                      log: Optional[Callable[[str], None]] = None) -> LoopOffloadResult:
    coding = coding_from_graph(graph, exclude=exclude)
    ga = run_ga(coding.length, fitness_fn, ga_cfg or GAConfig(), log=log)
    return LoopOffloadResult(coding, ga)
