"""Loop-statement offload pass (paper §3.2.1 / §4.2.2) — now a thin shim.

The GA-over-unclaimed-regions search lives in the unified pipeline
(:func:`repro.core.offload.ga_search`): gene coding from the region graph,
an :class:`~repro.core.evaluator.Evaluator` keyed by the graph's content
fingerprint (persistent measurement cache), the static transfer-cost
surrogate (always attached, so every search reports surrogate rank
correlation), optional pre-screening and process-pool dispatch.  This module
keeps the historical entry point and result type.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from repro.core.evaluator import Evaluator
from repro.core.ga import GAConfig, GAResult
from repro.core.genes import GeneCoding
from repro.core.ir import RegionGraph


@dataclass
class LoopOffloadResult:
    coding: GeneCoding
    ga: GAResult

    @property
    def best_impl(self) -> dict:
        return self.coding.decode(self.ga.best.bits)


def loop_offload_pass(graph: RegionGraph,
                      fitness_fn: Callable,
                      ga_cfg: Optional[GAConfig] = None,
                      exclude: Sequence[str] = (),
                      log: Optional[Callable[[str], None]] = None,
                      cache_extra: str = "",
                      evaluator: Optional[Evaluator] = None,
                      seeds: Sequence[Sequence[int]] = ()
                      ) -> LoopOffloadResult:
    """Run the GA over the unclaimed offloadable regions.

    ``cache_extra`` folds measurement-relevant context the graph cannot see
    (input shapes, device count) into the persistent-cache fingerprint.
    A pre-built ``evaluator`` overrides the GAConfig-derived one.
    """
    from repro.core.offload import ga_search  # deferred: keeps the shim light

    warnings.warn(
        "loop_offload_pass is deprecated; use repro.core.offload.ga_search "
        "(same search, (coding, GAResult) tuple) or Offloader.plan",
        DeprecationWarning, stacklevel=2)
    coding, ga = ga_search(graph, fitness_fn, ga_cfg, exclude=exclude,
                           log=log, cache_extra=cache_extra,
                           evaluator=evaluator, seeds=seeds)
    return LoopOffloadResult(coding, ga)
