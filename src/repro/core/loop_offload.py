"""Loop-statement offload pass (paper §3.2.1 / §4.2.2): GA over the loops the
function-block pass did not claim.

This pass is where the GA meets the evaluation engine
(:mod:`repro.core.evaluator`): it derives the gene coding from the region
graph, builds an :class:`~repro.core.evaluator.Evaluator` keyed by the
graph's content fingerprint (so the persistent measurement cache survives
process restarts and is shared between benchmark runs of the same program),
optionally attaches the static transfer-cost surrogate for offspring
pre-screening, and hands both to :func:`repro.core.ga.run_ga`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from repro.core.evaluator import Evaluator, transfer_cost_surrogate
from repro.core.ga import GAConfig, GAResult, run_ga
from repro.core.genes import GeneCoding, coding_from_graph
from repro.core.ir import RegionGraph


@dataclass
class LoopOffloadResult:
    coding: GeneCoding
    ga: GAResult

    @property
    def best_impl(self) -> dict:
        return self.coding.decode(self.ga.best.bits)


def loop_offload_pass(graph: RegionGraph,
                      fitness_fn: Callable,
                      ga_cfg: Optional[GAConfig] = None,
                      exclude: Sequence[str] = (),
                      log: Optional[Callable[[str], None]] = None,
                      cache_extra: str = "",
                      evaluator: Optional[Evaluator] = None) -> LoopOffloadResult:
    """Run the GA over the unclaimed offloadable regions.

    ``cache_extra`` folds measurement-relevant context the graph cannot see
    (input shapes, device count) into the persistent-cache fingerprint.
    A pre-built ``evaluator`` overrides the GAConfig-derived one.
    """
    cfg = ga_cfg or GAConfig()
    coding = coding_from_graph(graph, exclude=exclude)
    if evaluator is None:
        surrogate = None
        if cfg.screen_top_k is not None:
            surrogate = transfer_cost_surrogate(graph, coding)
        evaluator = Evaluator(
            fitness_fn, workers=cfg.workers, cache_dir=cfg.cache_dir,
            fingerprint=graph.fingerprint(
                f"{cache_extra}|exclude={sorted(exclude)}"),
            surrogate=surrogate, screen_top_k=cfg.screen_top_k)
        try:
            ga = run_ga(coding.length, fitness_fn, cfg, log=log,
                        evaluator=evaluator)
        finally:
            evaluator.close()
    else:
        ga = run_ga(coding.length, fitness_fn, cfg, log=log,
                    evaluator=evaluator)
    return LoopOffloadResult(coding, ga)
