"""The paper's primary contribution: common automatic offload for diverse
source-language frontends — GA loop offload + pattern-DB function-block
offload + transfer hoisting over a language-independent Region IR, behind
one pipeline (`repro.core.offload.Offloader`) and a frontend registry.
"""
from repro.core.block_offload import BlockOffloadResult, block_offload_pass
from repro.core.evaluator import (EvalStats, Evaluator, ProcessPool,
                                  fitness_factory, fitness_factory_names,
                                  last_rank_corr, record_search_meta,
                                  register_fitness_factory,
                                  transfer_cost_surrogate)
from repro.core.fitness import CostModelFitness, WallClockFitness
from repro.core.frontends import (Frontend, FitnessBundle, detect_frontend,
                                  frontend_names, get_frontend,
                                  register_frontend)
from repro.core.ga import Evaluation, GAConfig, GAResult, run_ga
from repro.core.genes import (DEFAULT_ALPHABET, EXTENDED_ALPHABET,
                              VARIANT_ALPHABET, CPU, FPGA_STUB, GPU,
                              GPU_FUSED, GPU_PALLAS, Destination, Device,
                              GeneCoding, MeshDestination, Site,
                              coding_from_graph, destination_names,
                              get_destination, mesh_proposals,
                              modeled_cost_s, register_destination,
                              site_modeled_cost_s, with_mesh_destinations)
from repro.core.ir import Region, RegionGraph
from repro.core.offload import (OffloadConfig, OffloadResult, Offloader,
                                SeedBank, ga_search, phenotype_key, plan,
                                plan_offload, resolve_alphabet,
                                search_fingerprint)
from repro.core.pattern_db import Match, PatternDB, PatternRecord, default_db
from repro.core.substitution import (SubstitutedCallable, SubstitutionEngine,
                                     SubstitutionReport)
from repro.core.surrogate import (FeatureExtractor, FittedSurrogate,
                                  fit_surrogate, load_fit,
                                  spearman_rank_corr)
from repro.core.variants import (SubstitutionChoice, generic_plan_report,
                                 resolve_variant)
from repro.core.transfer_planner import Transfer, TransferPlan, plan_transfers
from repro.core.verifier import VerifyResult, verify

__all__ = [
    "BlockOffloadResult", "block_offload_pass",
    "CostModelFitness", "WallClockFitness",
    "EvalStats", "Evaluator", "ProcessPool", "transfer_cost_surrogate",
    "fitness_factory", "fitness_factory_names", "register_fitness_factory",
    "last_rank_corr", "record_search_meta",
    "Frontend", "FitnessBundle", "detect_frontend", "frontend_names",
    "get_frontend", "register_frontend",
    "Evaluation", "GAConfig", "GAResult", "run_ga",
    "DEFAULT_ALPHABET", "EXTENDED_ALPHABET", "VARIANT_ALPHABET",
    "CPU", "GPU", "FPGA_STUB", "GPU_FUSED", "GPU_PALLAS",
    "Destination", "Device", "MeshDestination", "GeneCoding", "Site",
    "coding_from_graph", "destination_names", "get_destination",
    "mesh_proposals", "modeled_cost_s", "register_destination",
    "site_modeled_cost_s", "with_mesh_destinations",
    "SubstitutedCallable", "SubstitutionEngine", "SubstitutionReport",
    "SubstitutionChoice", "generic_plan_report", "resolve_variant",
    "FeatureExtractor", "FittedSurrogate", "fit_surrogate", "load_fit",
    "spearman_rank_corr",
    "Region", "RegionGraph",
    "OffloadConfig", "OffloadResult", "Offloader", "SeedBank",
    "ga_search", "phenotype_key", "plan", "plan_offload",
    "resolve_alphabet", "search_fingerprint",
    "Match", "PatternDB", "PatternRecord", "default_db",
    "Transfer", "TransferPlan", "plan_transfers",
    "VerifyResult", "verify",
]
