"""The paper's primary contribution: common automatic offload for diverse
source-language frontends — GA loop offload + pattern-DB function-block
offload + transfer hoisting over a language-independent Region IR.
"""
from repro.core.block_offload import BlockOffloadResult, block_offload_pass
from repro.core.evaluator import (EvalStats, Evaluator,
                                  transfer_cost_surrogate)
from repro.core.fitness import CostModelFitness, WallClockFitness
from repro.core.ga import Evaluation, GAConfig, GAResult, run_ga
from repro.core.genes import GeneCoding, Site, coding_from_graph
from repro.core.ir import Region, RegionGraph
from repro.core.loop_offload import LoopOffloadResult, loop_offload_pass
from repro.core.pattern_db import Match, PatternDB, PatternRecord, default_db
from repro.core.planner import (ModulePlanResult, PythonPlanResult,
                                plan_module_offload, plan_python_offload)
from repro.core.transfer_planner import Transfer, TransferPlan, plan_transfers
from repro.core.verifier import VerifyResult, verify

__all__ = [
    "BlockOffloadResult", "block_offload_pass",
    "CostModelFitness", "WallClockFitness",
    "EvalStats", "Evaluator", "transfer_cost_surrogate",
    "Evaluation", "GAConfig", "GAResult", "run_ga",
    "GeneCoding", "Site", "coding_from_graph",
    "Region", "RegionGraph",
    "LoopOffloadResult", "loop_offload_pass",
    "Match", "PatternDB", "PatternRecord", "default_db",
    "ModulePlanResult", "PythonPlanResult",
    "plan_module_offload", "plan_python_offload",
    "Transfer", "TransferPlan", "plan_transfers",
    "VerifyResult", "verify",
]
