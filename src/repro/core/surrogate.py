"""Journal-fitted surrogate cost model: screening that *learns* from the
measurement journals instead of merely being measured.

The paper spends nearly all of its search budget on verification-environment
measurements; Yamato's mixed-destination follow-up (arXiv:2011.12431) shows
the search only scales to many destinations when cheap predicted costs can
stand in for most measurements, and the function-block work (arXiv:2004.09883)
argues offload decisions should be driven by *recorded performance evidence*,
not static heuristics.  This module is that evidence loop closed:

* :class:`FeatureExtractor` — per-chromosome features from the same pure-IR
  machinery the hand formula uses (the transfer planner), but kept separate
  per signal instead of collapsed into one number: per-destination gene
  counts, H2D/D2H transfer counts, byte volume, round-trip products of
  per-iteration transfers, offloaded-region trip products, modeled stub
  cost — plus the hand formula's own score as the *prior feature*.
* :func:`fit_surrogate` — ridge / least-squares regression of those features
  against the persisted measurement journal
  (``measurements_{fingerprint}.jsonl``, written by
  :class:`repro.core.evaluator.MeasurementCache`).  With fewer than
  ``min_records`` journal rows the fit abstains and the caller keeps the
  hand formula (the prior *is* the fallback); with enough rows the fitted
  model can only lean away from the prior where the data supports it.
* :class:`FittedSurrogate` — the resulting ``bits -> score`` ranking
  callable, carrying its *leave-one-out* journal rank correlation next to
  the static formula's on the same rows, so ``ga_search`` can prefer
  whichever model demonstrably ranks this program's offspring better
  (LOO, so an overfit of journal noise cannot win the comparison).
* coefficient persistence — fits journal to ``surrogate_fit.jsonl`` beside
  ``search_meta.jsonl`` (newest-per-fingerprint compaction under the same
  flock idiom), so fitted models are inspectable and survive the process.

Like the static formula, a fitted surrogate only ever *ranks* offspring for
the pre-screen — measurement stays the final arbiter (the paper's
anti-static-prediction stance).
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.genes import (GeneCoding, MeshDestination, _trip_product,
                              get_destination, modeled_cost_s)
from repro.core.ir import RegionGraph
from repro.core.transfer_planner import plan_transfers

__all__ = ["FeatureExtractor", "FittedSurrogate", "fit_surrogate",
           "load_fit", "spearman_rank_corr", "SURROGATE_FIT_FILE"]

SURROGATE_FIT_FILE = "surrogate_fit.jsonl"
_FIT_MAX_LINES = 256


# ---------------------------------------------------------------------------
# rank correlation (shared with the evaluator's calibration report)
# ---------------------------------------------------------------------------


def _rank(x: np.ndarray) -> np.ndarray:
    order = np.argsort(x, kind="stable")
    r = np.empty(len(x))
    r[order] = np.arange(len(x), dtype=float)
    # average ties so equal scores can't fake correlation
    for v in np.unique(x):
        m = x == v
        r[m] = r[m].mean()
    return r


def spearman_rank_corr(score: Sequence[float], t: Sequence[float]) -> float:
    """Spearman rank correlation between a surrogate's scores and measured
    times.  +1 = the surrogate orders exactly as measurement would; ~0 =
    screening is a coin flip.  nan with fewer than 3 points or a constant
    ranking."""
    score = np.asarray(score, dtype=float)
    t = np.asarray(t, dtype=float)
    if len(score) < 3 or np.ptp(score) == 0 or np.ptp(t) == 0:
        return float("nan")
    rs, rt = _rank(score), _rank(t)
    rs -= rs.mean()
    rt -= rt.mean()
    denom = float(np.sqrt((rs ** 2).sum() * (rt ** 2).sum()))
    return float((rs * rt).sum() / denom) if denom else float("nan")


# ---------------------------------------------------------------------------
# feature extraction
# ---------------------------------------------------------------------------


class FeatureExtractor:
    """chromosome -> feature vector, from the same pure-IR signals the hand
    formula collapses into one score.

    Features (``feature_names`` gives the fitted-coefficient labels):

    * ``prior``          — the static transfer-cost surrogate's score (the
      hand formula as a regression prior: a fit on few records shrinks to
      it, a fit on many can overrule it where the journal disagrees)
    * ``h2d`` / ``d2h``  — static transfer counts from the planner
    * ``bytes``          — transfer volume, per-variable bytes × trip products
    * ``round_trips``    — dynamic trip product summed over per-iteration
      transfers (the paper's CPU↔accelerator round-trip penalty)
    * ``hoisted``        — transfers the planner pulled out of loops
    * ``offload_trips``  — trip products of regions placed on an executable
      accelerator destination (how much work the pattern offloads)
    * ``stub_cost``      — modeled seconds charged by cost-only destinations
    * ``block_active``   — function-block genes on an accelerated variant
      (each replaces its whole member span with one library call)
    * ``block_claimed``  — regions claimed by active block genes: their own
      genes are inert, so the effective search space is smaller than the
      chromosome length suggests
    * ``mesh_genes``     — genes placed on a mesh destination
    * ``mesh_devices``   — total devices those mesh genes span (Σ n)
    * ``mesh_model_axis``— mesh genes on the ``model`` axis (whose doubled
      collective makes them systematically dearer than ``data`` placements)
    * ``dest{k}``        — genes per non-reference alphabet value (variant
      impl-index counts: how many sites run alphabet entry k)
    * ``site{i}@{k}``    — per-site one-hot: site i on alphabet value k
      (what lets the fit learn that one region's variant is slow even when
      the aggregates look identical)
    """

    def __init__(self, graph: RegionGraph, coding: GeneCoding,
                 prior: Callable[[tuple], float],
                 var_bytes: Optional[dict] = None,
                 base_impl: Optional[dict] = None):
        self.graph = graph
        self.coding = coding
        self.prior = prior       # bound here: the memo below caches whole
        self.var_bytes = dict(var_bytes or {})  # vectors, prior score incl.
        self.base_impl = dict(base_impl or {})
        self._dests = [get_destination(d) for d in coding.destinations]
        self._trip = {s.region: _trip_product(graph, graph.by_name(s.region))
                      for s in coding.sites}
        self.feature_names: tuple[str, ...] = tuple(
            ["prior", "h2d", "d2h", "bytes", "round_trips", "hoisted",
             "offload_trips", "stub_cost", "block_active", "block_claimed",
             "mesh_genes", "mesh_devices", "mesh_model_axis"]
            + [f"dest{k}" for k in range(1, coding.arity)]
            + [f"site{i}@{k}" for i in range(coding.length)
               for k in range(1, coding.arity)])
        self._memo: dict[tuple, np.ndarray] = {}

    def __call__(self, bits: Sequence[int]) -> np.ndarray:
        bits = tuple(int(b) for b in bits)
        hit = self._memo.get(bits)
        if hit is not None:
            return hit
        coding, graph = self.coding, self.graph
        impl = dict(self.base_impl)
        impl.update(coding.decode(bits))
        plan = plan_transfers(graph, impl, hoist=True,
                              destinations=coding.destinations_of(bits))
        n_h2d = n_d2h = n_hoist = 0
        total_bytes = 0.0
        round_trips = 0.0
        for t in plan.transfers:
            if t.direction == "h2d":
                n_h2d += 1
            else:
                n_d2h += 1
            if t.hoisted_from:
                n_hoist += 1
            trips = 1
            if t.per_iteration:
                trips = _trip_product(graph, graph.by_name(t.at_region))
                round_trips += trips
            total_bytes += (trips * float(self.var_bytes.get(t.var, 1.0))
                            / max(t.shards, 1))
        claimed = coding.claimed_members(bits)
        offload_trips = sum(
            self._trip[s.region] for s, v in zip(coding.sites, bits)
            if int(v) != 0 and not self._dests[int(v)].is_cost_only
            and s.region not in claimed)
        n_block = sum(1 for s in coding.sites
                      if s.members and impl.get(s.region) != s.ref_impl)
        stub = modeled_cost_s(graph, coding, bits) \
            if any(d.placement_tag is not None for d in self._dests) else 0.0
        mesh_genes = mesh_devices = mesh_model = 0.0
        for s, v in zip(coding.sites, bits):
            d = self._dests[int(v)]
            if isinstance(d, MeshDestination) and s.region not in claimed:
                mesh_genes += 1.0
                mesh_devices += float(d.n)
                mesh_model += 1.0 if d.axis == "model" else 0.0
        dest_counts = [sum(1 for v in bits if int(v) == k)
                       for k in range(1, coding.arity)]
        onehot = [1.0 if int(v) == k else 0.0
                  for v in bits for k in range(1, coding.arity)]
        vec = np.asarray(
            [float(self.prior(bits)), float(n_h2d), float(n_d2h),
             total_bytes,
             round_trips, float(n_hoist), float(offload_trips), stub,
             float(n_block), float(len(claimed)),
             mesh_genes, mesh_devices, mesh_model]
            + [float(c) for c in dest_counts] + onehot)
        self._memo[bits] = vec
        return vec


# ---------------------------------------------------------------------------
# the fitted model
# ---------------------------------------------------------------------------


@dataclass
class FittedSurrogate:
    """A ``bits -> score`` ranking callable fitted to this fingerprint's
    measurement journal, carrying the evidence for preferring it."""

    extractor: FeatureExtractor           # holds the bound prior
    coef: np.ndarray                      # feature weights
    intercept: float
    mean: np.ndarray                      # feature standardization
    scale: np.ndarray
    n_records: int
    rank_corr: float                      # out-of-sample journal Spearman:
                                          # held-out validation rows when
                                          # the journal is big enough,
                                          # leave-one-out otherwise — an
                                          # honest generalization estimate,
                                          # never the training fit
    static_rank_corr: float               # same rows, hand formula
    n_val: int = 0                        # held-out rows (0 = LOO was used)
    fingerprint: str = ""
    kind: str = "fitted"
    objective: str = "latency"            # which journal column the fit
                                          # predicts: measured seconds
                                          # ("latency") or a per-objective
                                          # detail field ("energy",
                                          # "transfer") — one ridge model
                                          # per objective, same journal

    def __call__(self, bits: tuple) -> float:
        x = (self.extractor(bits) - self.mean) / self.scale
        return float(self.intercept + x @ self.coef)

    @property
    def beats_static(self) -> bool:
        """True when the journal says this fit ranks strictly better than
        the hand formula — the activation rule ``ga_search`` applies.
        ``rank_corr`` is leave-one-out, so a fit that merely interpolates
        journal noise cannot clear the bar; and it must be positively
        correlated at all — an inverted ranker never activates, even
        against a static formula with no measurable correlation."""
        return (math.isfinite(self.rank_corr) and self.rank_corr > 0
                and (not math.isfinite(self.static_rank_corr)
                     or self.rank_corr > self.static_rank_corr))

    def coefficients(self) -> dict[str, float]:
        """feature name -> fitted weight (standardized space) — the
        inspection surface ``docs/api.md`` documents."""
        return {n: float(c)
                for n, c in zip(self.extractor.feature_names, self.coef)}


#: objective name -> journal detail field holding its measured value
#: (``None`` = the row's ``time_s`` itself).  Rows written before PR 9
#: carry no per-objective fields; they simply drop out of non-latency
#: fits (graceful latency-only degradation) instead of poisoning them.
_OBJECTIVE_FIELDS: dict[str, Optional[str]] = {
    "latency": None, "energy": "energy_j", "transfer": "transfer_bytes",
}


def _journal_rows(cache_dir: str, fingerprint: str, coding: GeneCoding,
                  objective: str = "latency") -> list[tuple[tuple, float]]:
    """(bits, measured objective value) for every finite valid measurement
    of this fingerprint whose chromosome fits the current coding.  Unknown
    objective names read the detail field of that name directly."""
    from repro.core.evaluator import MeasurementCache

    field_name = _OBJECTIVE_FIELDS.get(objective, objective)
    rows: list[tuple[tuple, float]] = []
    for bits, ev in MeasurementCache(cache_dir, fingerprint).load().items():
        if not (ev.valid and math.isfinite(ev.time_s)
                and len(bits) == coding.length
                and all(0 <= int(v) < coding.arity for v in bits)):
            continue
        y = ev.time_s if field_name is None else ev.detail.get(field_name)
        if isinstance(y, (int, float)) and math.isfinite(y):
            rows.append((bits, float(y)))
    return rows


def fit_surrogate(graph: RegionGraph, coding: GeneCoding, cache_dir: str,
                  fingerprint: str,
                  prior: Optional[Callable[[tuple], float]] = None,
                  min_records: int = 10, ridge: float = 1e-2,
                  var_bytes: Optional[dict] = None,
                  base_impl: Optional[dict] = None,
                  persist: bool = True,
                  objective: str = "latency") -> Optional[FittedSurrogate]:
    """Fit a ridge regression of chromosome features against the persisted
    measurement journal for ``fingerprint``.

    Returns ``None`` (caller keeps the hand formula) when the journal has
    fewer than ``min_records`` usable rows or the measured times carry no
    ranking signal.  Otherwise the fit is journaled to
    ``{cache_dir}/surrogate_fit.jsonl`` (beside ``search_meta.jsonl``) and
    returned with both models' journal rank correlations attached.

    ``objective`` selects the journal column predicted: the default
    ``"latency"`` fits measured seconds (the historical behavior); the
    multi-objective search additionally fits ``"energy"`` / ``"transfer"``
    against the per-objective detail fields the annotate hook journals —
    one ridge model per objective from the same measurement rows.
    """
    from repro.core.evaluator import transfer_cost_surrogate

    if prior is None:
        prior = transfer_cost_surrogate(graph, coding,
                                        var_bytes=var_bytes,
                                        base_impl=base_impl)
    rows = _journal_rows(cache_dir, fingerprint, coding, objective)
    if len(rows) < max(3, int(min_records)):
        return None
    extractor = FeatureExtractor(graph, coding, prior,
                                 var_bytes=var_bytes,
                                 base_impl=base_impl)
    X = np.stack([extractor(bits) for bits, _ in rows])
    y = np.asarray([t for _, t in rows])
    if np.ptp(y) == 0:
        return None                     # constant journal: nothing to rank
    # out-of-sample guard: with enough journal, hold out every 4th row as a
    # validation set the fit never sees — rank_corr is then a true held-out
    # comparison against the hand formula.  Smaller journals keep the
    # closed-form leave-one-out estimate instead of wasting rows.
    val = np.zeros(len(rows), dtype=bool)
    if len(rows) >= 12:
        val[3::4] = True
    tr = ~val
    n_tr = int(tr.sum())
    mean = X[tr].mean(axis=0)
    scale = X[tr].std(axis=0)
    scale[scale == 0] = 1.0             # constant features drop out cleanly
    Xs = (X - mean) / scale
    y_mean = float(y[tr].mean())
    # ridge on the standardized features; the intercept is the journal mean
    # and stays unpenalized.  lam scales with n so more data loosens the
    # shrinkage toward the prior-feature direction.
    lam = float(ridge) * n_tr
    p = Xs.shape[1]
    A = Xs[tr].T @ Xs[tr] + lam * np.eye(p)
    b = Xs[tr].T @ (y[tr] - y_mean)
    try:
        inv_A = np.linalg.inv(A)
    except np.linalg.LinAlgError:       # pragma: no cover — lam>0 makes A PD
        inv_A = np.linalg.pinv(A)
    coef = inv_A @ b
    pred = y_mean + Xs @ coef
    n_val = int(val.sum())
    if n_val >= 3 and np.ptp(y[val]) > 0:
        idx = np.where(val)[0]
        rank_corr = spearman_rank_corr(pred[val], y[val])
        static_rank_corr = spearman_rank_corr(
            [prior(rows[i][0]) for i in idx], y[val])
    else:
        # leave-one-out predictions, closed form for ridge: the honest fit
        # quality.  With per-site one-hot features p can approach (or
        # exceed) the journal size, where the training fit near-
        # interpolates noise and its in-sample Spearman would "beat" the
        # static formula every time — LOO residuals e_i / (1 - h_i) are
        # what the activation rule may trust.
        n_val = 0
        Xt = Xs[tr]
        leverage = np.einsum("ij,jk,ik->i", Xt, inv_A, Xt) + 1.0 / n_tr
        leverage = np.clip(leverage, 0.0, 1.0 - 1e-6)
        loo_pred = y[tr] - (y[tr] - pred[tr]) / (1.0 - leverage)
        rank_corr = spearman_rank_corr(loo_pred, y[tr])
        static_rank_corr = spearman_rank_corr(
            [prior(bits) for bits, _ in rows], y)
    fitted = FittedSurrogate(
        extractor=extractor, coef=coef, intercept=y_mean,
        mean=mean, scale=scale, n_records=len(rows),
        rank_corr=rank_corr, static_rank_corr=static_rank_corr,
        n_val=n_val, fingerprint=fingerprint, objective=objective)
    if persist:
        _save_fit(cache_dir, fitted)
    return fitted


# ---------------------------------------------------------------------------
# coefficient persistence (same journal idiom as search_meta.jsonl)
# ---------------------------------------------------------------------------


def _save_fit(cache_dir: str, fit: FittedSurrogate) -> None:
    from repro.core.journal import Journal, newest_per_key

    os.makedirs(cache_dir, exist_ok=True)
    journal = Journal(os.path.join(cache_dir, SURROGATE_FIT_FILE))
    rec = {
        "fingerprint": fit.fingerprint,
        "objective": fit.objective,
        "n_records": fit.n_records,
        "n_val": fit.n_val,
        "rank_corr": fit.rank_corr if math.isfinite(fit.rank_corr) else None,
        "static_rank_corr": fit.static_rank_corr
        if math.isfinite(fit.static_rank_corr) else None,
        "intercept": fit.intercept,
        "feature_names": list(fit.extractor.feature_names),
        "coef": [float(c) for c in fit.coef],
        "mean": [float(m) for m in fit.mean],
        "scale": [float(s) for s in fit.scale],
    }
    with journal.lock():
        journal.append([rec], locked=False)
        if journal.line_count() <= _FIT_MAX_LINES:
            return
        journal.rewrite(
            newest_per_key(journal.records(),
                           key=lambda r: (r.get("fingerprint"),
                                          r.get("objective", "latency")),
                           max_records=_FIT_MAX_LINES),
            locked=False)


def load_fit(cache_dir: str, fingerprint: str,
             objective: str = "latency") -> Optional[dict]:
    """Most recent persisted fit record for a (fingerprint, objective)
    (coefficients by feature name, journal size, both rank correlations) —
    the inspection entry point; returns None when nothing was ever fitted.
    Records from before per-objective fits count as latency fits."""
    from repro.core.journal import Journal

    out: Optional[dict] = None
    for rec in Journal(os.path.join(cache_dir, SURROGATE_FIT_FILE)).records():
        if rec.get("fingerprint") == fingerprint \
                and rec.get("objective", "latency") == objective:
            out = rec
    if out is not None:
        out = dict(out)
        out["coefficients"] = dict(zip(out.get("feature_names", ()),
                                       out.get("coef", ())))
    return out
