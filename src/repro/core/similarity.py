"""Deckard-style structural similarity (paper §3.2.2: 類似性検出ツール).

Deckard (ICSE'07) maps AST subtrees to *characteristic vectors* of node-type
counts and clusters near vectors.  We retarget the exact algorithm at our two
IRs:

  * Python ``ast`` subtrees  -> counts of ast node types      (CloneDigger role)
  * ``jaxpr`` equation lists -> counts of primitive names     (Deckard role)

Similarity = cosine between count vectors; a match needs similarity >= the
pattern's threshold.  This catches "copied then modified" implementations
that exact name matching misses — e.g. a hand-written softmax-attention with
an extra mask still matches the flash-attention pattern at ~0.9.
"""
from __future__ import annotations

import ast as pyast
import math
from collections import Counter
from typing import Any, Callable

import jax
import numpy as np


# ---------------------------------------------------------------------------
# characteristic vectors
# ---------------------------------------------------------------------------


_CALL_WEIGHT = 6   # call identities discriminate far better than node types


def ast_vector(node: pyast.AST) -> dict[str, int]:
    """Characteristic vector over a Python AST subtree.

    Features: node-type counts, weighted call names (cos/exp/dot identify a
    block much more strongly than generic loop scaffolding), binary-op kinds,
    and a loop-nesting histogram (Deckard's stratified vectors analogue).
    """
    counts: Counter = Counter()

    def walk(n: pyast.AST, loop_depth: int) -> None:
        counts[type(n).__name__] += 1
        if isinstance(n, pyast.Call):
            name = _call_name(n)
            if name:
                counts[f"call:{name.split('.')[-1]}"] += _CALL_WEIGHT
        if isinstance(n, pyast.BinOp):
            counts[f"op:{type(n.op).__name__}"] += 1
        d = loop_depth
        if isinstance(n, (pyast.For, pyast.While)):
            counts[f"nest:{loop_depth}"] += 2
            d += 1
        for c in pyast.iter_child_nodes(n):
            walk(c, d)

    walk(node, 0)
    return dict(counts)


def _call_name(node: pyast.Call) -> str:
    f = node.func
    parts: list[str] = []
    while isinstance(f, pyast.Attribute):
        parts.append(f.attr)
        f = f.value
    if isinstance(f, pyast.Name):
        parts.append(f.id)
    return ".".join(reversed(parts))


#: dtype plumbing, not structure: counting these would make the same block
#: in bf16 look unlike its f32 comparison code.
_IGNORED_PRIMS = {"convert_element_type"}


def jaxpr_vector(jaxpr: Any) -> dict[str, int]:
    """Primitive counts over a (Closed)Jaxpr, recursing into sub-jaxprs."""
    counts: Counter = Counter()

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name not in _IGNORED_PRIMS:
                counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                inner = _sub_jaxpr(v)
                for sub in inner:
                    walk(sub)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return dict(counts)


def eqns_vector(eqns: Any) -> dict[str, int]:
    """Primitive counts over a list of jaxpr equations, recursing into
    sub-jaxprs (glue calls like a pjit'd ``tril`` contribute their inner
    primitives, so region vectors stay comparable with the whole-trace
    vectors the pattern DB stores)."""
    counts: Counter = Counter()

    def walk_eqns(es):
        for eqn in es:
            if eqn.primitive.name not in _IGNORED_PRIMS:
                counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                for sub in _sub_jaxpr(v):
                    walk_eqns(sub.eqns)

    walk_eqns(eqns)
    return dict(counts)


def _sub_jaxpr(v: Any) -> list:
    out = []
    if hasattr(v, "jaxpr"):        # ClosedJaxpr
        out.append(v.jaxpr)
    elif hasattr(v, "eqns"):       # Jaxpr
        out.append(v)
    elif isinstance(v, (list, tuple)):
        for x in v:
            out.extend(_sub_jaxpr(x))
    return out


def vector_of_callable(fn: Callable, *example_args) -> dict[str, int]:
    """Trace a callable to a jaxpr and take its characteristic vector."""
    jx = jax.make_jaxpr(fn)(*example_args)
    return jaxpr_vector(jx)


# ---------------------------------------------------------------------------
# similarity
# ---------------------------------------------------------------------------


def cosine(a: dict[str, int], b: dict[str, int]) -> float:
    if not a or not b:
        return 0.0
    keys = set(a) | set(b)
    va = np.array([a.get(k, 0) for k in keys], dtype=np.float64)
    vb = np.array([b.get(k, 0) for k in keys], dtype=np.float64)
    na, nb = np.linalg.norm(va), np.linalg.norm(vb)
    if na == 0 or nb == 0:
        return 0.0
    return float(va @ vb / (na * nb))


def similarity(a: dict[str, int], b: dict[str, int]) -> float:
    """Cosine over characteristic vectors (Deckard uses euclidean LSH; cosine
    is scale-invariant which suits loop-trip-count differences)."""
    return cosine(a, b)


def graph_vector(graph) -> dict[str, int]:
    """Whole-program characteristic vector of a RegionGraph: the sum of the
    regions' vectors plus weighted callee names — what the offload seed bank
    compares to find *near*-identical programs whose best patterns can warm-
    start a new search (ROADMAP: similarity-based measurement reuse)."""
    counts: Counter = Counter()
    for r in graph.regions:
        for k, v in r.feature_vector.items():
            counts[k] += v
        for name in r.callees:
            counts[f"call:{name.split('.')[-1]}"] += _CALL_WEIGHT
        counts[f"kind:{r.kind}"] += 1
    return dict(counts)
