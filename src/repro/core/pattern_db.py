"""Code-pattern DB for function-block offload (paper §3.2.2, §4.1: 照合に
用いるコードパターン DB は、MySQL8 を用いる。ライブラリ等を類似性検出技術で
検出するための、比較用コードとの対応関係等が保持される).

Each record holds:
  * ``callee_names`` — library-call names for exact name matching,
  * per-frontend *comparison code* characteristic vectors (the 比較用コード)
    for Deckard/CloneDigger-style similarity matching,
  * the replacement implementation id (our "CUDA library": a Pallas kernel
    wrapper or a fused-jnp rewrite) and the ExecPlan field it drives,
  * an interface note — when the replacement's interface differs from the
    matched block the result is flagged ``needs_confirmation`` (the paper
    asks the user before changing interfaces).

The DB persists as JSON (the MySQL stand-in); ``default_db()`` builds the
shipped patterns by tracing canonical reference implementations.
"""
from __future__ import annotations

import ast as pyast
import dataclasses
import json
import os
import textwrap
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import similarity as sim
from repro.core.ir import Region
from repro.core.journal import Journal
from repro.obs import metrics as obs_metrics

# ---------------------------------------------------------------------------
# records
# ---------------------------------------------------------------------------


@dataclass
class PatternRecord:
    name: str
    callee_names: tuple = ()
    vectors: dict = field(default_factory=dict)   # frontend -> char. vector
    replacement: str = ""                         # implementation id
    plan_field: Optional[tuple] = None            # (ExecPlan field, value)
    threshold: float = 0.85
    interface_note: str = ""
    interface_changes: bool = False
    #: block records describe a whole function block (several adjacent
    #: regions merged); they are matched by :meth:`PatternDB.match_block`
    #: over merged windows and never by per-region ``match_region``.
    block: bool = False

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["callee_names"] = list(self.callee_names)
        d["plan_field"] = list(self.plan_field) if self.plan_field else None
        return d

    @classmethod
    def from_json(cls, d: dict) -> "PatternRecord":
        d = dict(d)
        d["callee_names"] = tuple(d.get("callee_names", ()))
        pf = d.get("plan_field")
        d["plan_field"] = tuple(pf) if pf else None
        return cls(**d)


@dataclass
class Match:
    record: PatternRecord
    how: str         # "name" | "similarity"
    score: float
    region: str
    needs_confirmation: bool = False


# ---------------------------------------------------------------------------
# per-pattern verifier-outcome journal (ROADMAP: match precision from
# verifier outcomes — a pattern whose substitutions keep failing
# verification should raise its own threshold)
# ---------------------------------------------------------------------------

PRECISION_FILE = "pattern_precision.jsonl"
_PRECISION_MAX_LINES = 4096

#: outcome vocabulary.  ``ok`` / ``verify_fail`` / ``error`` are verifier
#: verdicts on a substitution that ran; ``bind_fail`` means the matched
#: variant refused to bind (predicate/aval rejection) so nothing ran —
#: recorded, but excluded from the precision denominator by default.
PRECISION_OUTCOMES = ("ok", "verify_fail", "error", "bind_fail")


def record_pattern_outcome(cache_dir: Optional[str], pattern: Optional[str],
                           variant: str, outcome: str,
                           region: str = "") -> None:
    """Journal one verifier outcome for a (pattern, variant) substitution
    into ``{cache_dir}/pattern_precision.jsonl`` and mirror it into the
    process metrics registry (``patterns.outcomes``).  ``cache_dir=None``
    keeps the metrics side only; records without a pattern are dropped."""
    if not pattern:
        return
    obs_metrics.counter("patterns.outcomes", pattern=pattern,
                        variant=variant, outcome=outcome).inc()
    if not cache_dir:
        return
    journal = Journal(os.path.join(cache_dir, PRECISION_FILE))
    journal.append([{"pattern": pattern, "variant": str(variant),
                     "outcome": str(outcome), "region": region,
                     "ts": time.time()}])
    journal.compact(lambda recs: recs[-_PRECISION_MAX_LINES:],
                    threshold=2 * _PRECISION_MAX_LINES)


def load_pattern_precision(cache_dir: str) -> dict[str, dict[str, int]]:
    """The journal aggregated: ``pattern -> {outcome: count}``."""
    out: dict[str, dict[str, int]] = {}
    journal = Journal(os.path.join(cache_dir, PRECISION_FILE))
    for rec in journal.records():
        pattern, outcome = rec.get("pattern"), rec.get("outcome")
        if not pattern or not outcome:
            continue
        counts = out.setdefault(pattern, {})
        counts[outcome] = counts.get(outcome, 0) + 1
    return out


class PatternDB:
    def __init__(self, records: list[PatternRecord],
                 precision_dir: Optional[str] = None):
        self.records = records
        #: where this DB reads verifier-outcome journals from
        #: (:func:`record_pattern_outcome` writers pass their own cache_dir)
        self.precision_dir = precision_dir

    # --- match precision from verifier outcomes -----------------------------
    def precision_evidence(self, pattern: str,
                           cache_dir: Optional[str] = None
                           ) -> tuple[Optional[float], int]:
        """(precision, ran-outcome count) for a pattern — the precision is
        the fraction of *ran* substitutions the verifier accepted,
        ``ok / (ok + verify_fail + error)``; ``bind_fail`` records (the
        variant never ran, so the verifier said nothing) don't enter the
        denominator.  ``(None, 0)`` when no journal directory is configured
        or the pattern has no ran outcomes yet — "no evidence", distinct
        from 0.0 ("all failed")."""
        d = cache_dir or self.precision_dir
        if not d:
            return None, 0
        counts = load_pattern_precision(d).get(pattern)
        if not counts:
            return None, 0
        ran = sum(counts.get(o, 0) for o in ("ok", "verify_fail", "error"))
        if ran == 0:
            return None, 0
        return counts.get("ok", 0) / ran, ran

    def precision(self, pattern: str,
                  cache_dir: Optional[str] = None) -> Optional[float]:
        """Precision alone; see :meth:`precision_evidence`."""
        return self.precision_evidence(pattern, cache_dir)[0]

    #: ran outcomes a pattern needs before precision feedback touches its
    #: threshold — the flakiness floor: one bad measurement (or two) can
    #: never blacklist a pattern by itself.
    PRECISION_MIN_EVIDENCE = 3
    #: how much a fully-failing pattern's threshold tightens: effective
    #: threshold = threshold + (1 - precision) * PRECISION_TIGHTEN ...
    PRECISION_TIGHTEN = 0.12
    #: ... capped here, so a pattern stays matchable by a near-perfect
    #: similarity score even when every recorded substitution failed
    #: (measurement remains the final arbiter; feedback only raises the
    #: evidence bar, it never hard-blacklists).
    PRECISION_CEILING = 0.98

    def effective_threshold(self, rec: PatternRecord) -> float:
        """The record's similarity threshold with precision feedback: a
        pattern whose substitutions keep failing verification demands a
        stricter match (低精度パターンは厳しめに).  No journal, no
        evidence, or fewer than :data:`PRECISION_MIN_EVIDENCE` ran
        outcomes → the static threshold, unchanged."""
        p, ran = self.precision_evidence(rec.name)
        if p is None or ran < self.PRECISION_MIN_EVIDENCE or p >= 1.0:
            return rec.threshold
        return min(self.PRECISION_CEILING,
                   rec.threshold + (1.0 - p) * self.PRECISION_TIGHTEN)

    #: a similarity match must beat the runner-up pattern by this margin,
    #: otherwise it is ambiguous (generic loop scaffolding looks like every
    #: pattern) and is surfaced as needs_confirmation.
    AMBIGUITY_MARGIN = 0.012

    # --- matching (paper: name match first, then similarity detection) -----
    def match_region(self, region: Region, frontend: str,
                     min_similarity: Optional[float] = None) -> list[Match]:
        out: list[Match] = []
        scores: list[tuple[float, PatternRecord]] = []
        callee_set = {c.lower().split(".")[-1] for c in region.callees}
        for rec in self.records:
            if rec.block:
                continue          # block records match windows, not regions
            names = {n.lower() for n in rec.callee_names}
            if callee_set & names:
                out.append(Match(rec, "name", 1.0, region.name,
                                 needs_confirmation=rec.interface_changes))
                continue
            vec = rec.vectors.get(frontend)
            if vec and region.feature_vector:
                scores.append((sim.similarity(region.feature_vector, vec), rec))
        scores.sort(key=lambda sr: -sr[0])
        for i, (score, rec) in enumerate(scores):
            # precision feedback: an explicit caller override always wins;
            # otherwise low-precision patterns demand a stricter score
            thr = min_similarity if min_similarity is not None \
                else self.effective_threshold(rec)
            if score < thr:
                continue
            runner_up = scores[i + 1][0] if i + 1 < len(scores) else 0.0
            ambiguous = (score - runner_up) < self.AMBIGUITY_MARGIN and i == 0
            out.append(Match(rec, "similarity", score, region.name,
                             needs_confirmation=rec.interface_changes or ambiguous))
            break  # only the best similarity match is a candidate
        out.sort(key=lambda m: -m.score)
        return out

    # --- block matching: merged windows of adjacent regions -----------------
    #: a merged window may only match a block record when its total feature
    #: mass is within this factor of the record's — a lone matmul summed
    #: with glue must not pass for a whole attention stack.
    BLOCK_SIZE_GUARD = 2.0

    def match_block(self, regions: list, frontend: str,
                    min_similarity: Optional[float] = None) -> Optional[Match]:
        """Match a window of >= 2 adjacent regions, merged, against the
        ``block`` records: name-first over the union of callees, then
        cosine similarity of the summed feature vectors with a size guard.
        Returns the best match or None."""
        if len(regions) < 2:
            return None
        callee_set = {c.lower().split(".")[-1]
                      for r in regions for c in r.callees}
        merged: dict = {}
        for r in regions:
            for k, v in (r.feature_vector or {}).items():
                merged[k] = merged.get(k, 0) + v
        total = sum(merged.values())
        best: Optional[Match] = None
        for rec in self.records:
            if not rec.block:
                continue
            names = {n.lower() for n in rec.callee_names}
            if callee_set & names:
                return Match(rec, "name", 1.0, regions[0].name,
                             needs_confirmation=rec.interface_changes)
            vec = rec.vectors.get(frontend)
            if not vec or not merged:
                continue
            rtotal = sum(vec.values())
            if rtotal and total and not (
                    1.0 / self.BLOCK_SIZE_GUARD
                    <= total / rtotal <= self.BLOCK_SIZE_GUARD):
                continue
            score = sim.similarity(merged, vec)
            thr = (min_similarity if min_similarity is not None
                   else self.effective_threshold(rec))
            if score >= thr and (best is None or score > best.score):
                best = Match(rec, "similarity", score, regions[0].name,
                             needs_confirmation=rec.interface_changes)
        return best

    # --- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump([r.to_json() for r in self.records], f, indent=1)

    @classmethod
    def load(cls, path: str) -> "PatternDB":
        with open(path) as f:
            return cls([PatternRecord.from_json(d) for d in json.load(f)])


# ---------------------------------------------------------------------------
# shipped comparison code (the 比較用コード) — naive Python forms
# ---------------------------------------------------------------------------

_PY_COMPARISON_CODE = {
    "matmul": """
def matmul(a, b, c, n, m, k):
    for i in range(n):
        for j in range(m):
            acc = 0.0
            for t in range(k):
                acc = acc + a[i][t] * b[t][j]
            c[i][j] = acc
""",
    "softmax_attention": """
def attention(q, k, v, out, n, d):
    for i in range(n):
        m = -1e30
        for j in range(n):
            s = 0.0
            for t in range(d):
                s = s + q[i][t] * k[j][t]
            if s > m:
                m = s
        z = 0.0
        for j in range(n):
            z = z + exp(dot(q[i], k[j]) - m)
        for t in range(d):
            acc = 0.0
            for j in range(n):
                acc = acc + exp(dot(q[i], k[j]) - m) / z * v[j][t]
            out[i][t] = acc
""",
    "fft": """
def dft(re, im, out_re, out_im, n):
    for k in range(n):
        sr = 0.0
        si = 0.0
        for t in range(n):
            ang = -2.0 * pi * k * t / n
            sr = sr + re[t] * cos(ang) - im[t] * sin(ang)
            si = si + re[t] * sin(ang) + im[t] * cos(ang)
        out_re[k] = sr
        out_im[k] = si
""",
    "rmsnorm": """
def rmsnorm(x, scale, out, n, d):
    for i in range(n):
        ss = 0.0
        for t in range(d):
            ss = ss + x[i][t] * x[i][t]
        inv = 1.0 / sqrt(ss / d + 1e-6)
        for t in range(d):
            out[i][t] = x[i][t] * inv * (1.0 + scale[t])
""",
    "linear_recurrence": """
def recurrence(a, b, h, out, n, d):
    for t in range(n):
        for c in range(d):
            h[c] = a[t][c] * h[c] + b[t][c]
            out[t][c] = h[c]
""",
    "attention_stack": """
def attention_stack(x, scale, wq, wk, wv, out, n, d, hd):
    for i in range(n):
        ss = 0.0
        for t in range(d):
            ss = ss + x[i][t] * x[i][t]
        inv = 1.0 / sqrt(ss / d + 1e-6)
        for t in range(d):
            xn[i][t] = x[i][t] * inv * (1.0 + scale[t])
    for i in range(n):
        for h in range(hd):
            aq = 0.0
            ak = 0.0
            av = 0.0
            for t in range(d):
                aq = aq + xn[i][t] * wq[t][h]
                ak = ak + xn[i][t] * wk[t][h]
                av = av + xn[i][t] * wv[t][h]
            q[i][h] = aq
            k[i][h] = ak
            v[i][h] = av
    for i in range(n):
        m = -1e30
        for j in range(n):
            s = 0.0
            for t in range(hd):
                s = s + q[i][t] * k[j][t]
            if s > m:
                m = s
        z = 0.0
        for j in range(n):
            z = z + exp(dot(q[i], k[j]) - m)
        for t in range(hd):
            acc = 0.0
            for j in range(n):
                acc = acc + exp(dot(q[i], k[j]) - m) / z * v[j][t]
            out[i][t] = acc
""",
}


def _py_vector(code: str) -> dict:
    tree = pyast.parse(textwrap.dedent(code))
    return sim.ast_vector(tree)


def _scan_region_vector(fn, *example_args) -> dict:
    """Characteristic vector of a canonical *scan region*: trace the
    reference implementation, find its scan equation, and count the body's
    primitives plus the ``scan`` itself — exactly how the jaxpr frontend
    vectorizes a scan region, so scan-shaped comparison code matches
    scan-shaped user regions instead of whole-program traces."""
    closed = jax.make_jaxpr(fn)(*example_args)
    for eqn in closed.jaxpr.eqns:
        if eqn.primitive.name == "scan":
            vec = sim.jaxpr_vector(eqn.params["jaxpr"])
            vec["scan"] = vec.get("scan", 0) + 1
            return vec
    return sim.jaxpr_vector(closed)


# --- canonical jnp reference blocks (traced -> jaxpr vectors) ---------------


def _jx_attention(q, k, v):
    s = jnp.einsum("qd,kd->qk", q, k) / np.sqrt(q.shape[-1])
    mask = jnp.arange(k.shape[0])[None, :] <= jnp.arange(q.shape[0])[:, None]
    s = jnp.where(mask, s, -1e30)
    return jax.nn.softmax(s, axis=-1) @ v


def _jx_rmsnorm(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * (1 + scale)


def _jx_recurrence(la, b):
    def step(h, ab):
        h = jnp.exp(ab[0]) * h + ab[1]
        return h, h
    _, hs = jax.lax.scan(step, jnp.zeros(la.shape[-1]), (la, b))
    return hs


def _jx_wkv(r, k, v, lw, u):
    def step(s, rkvw):
        rt, kt, vt, lwt = rkvw
        kv = kt[:, None] * vt[None, :]
        y = rt @ (s + u[:, None] * kv)
        return jnp.exp(lwt)[:, None] * s + kv, y
    _, ys = jax.lax.scan(step, jnp.zeros((r.shape[-1], v.shape[-1])), (r, k, v, lw))
    return ys


def _jx_matmul(a, b):
    return a @ b


# --- canonical *block* traces: several regions' worth of work each ----------


def _jx_attention_stack(x, scale, wq, wk, wv):
    xn = _jx_rmsnorm(x, scale)
    q, k, v = xn @ wq, xn @ wk, xn @ wv
    return _jx_attention(q, k, v)


def _jx_moe_dispatch(x, wr, wg, wu, wd):
    logits = x @ wr
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(idx, wr.shape[1])
    combine = jnp.einsum("tk,tke->te", gates, onehot)
    g = jnp.einsum("td,edf->tef", x, wg)
    u = jnp.einsum("td,edf->tef", x, wu)
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, wd)
    return jnp.einsum("ted,te->td", y, combine)


def _jx_fft(x):
    return jnp.fft.fft(x)


def default_db() -> PatternDB:
    f32 = jnp.float32
    q = jnp.zeros((8, 4), f32)
    la = jnp.zeros((8, 4), f32)
    recs = [
        PatternRecord(
            name="softmax_attention",
            callee_names=("attention", "sdpa", "scaled_dot_product_attention",
                          "flash_attention", "multi_head_attention"),
            vectors={"python_ast": _py_vector(_PY_COMPARISON_CODE["softmax_attention"]),
                     "jaxpr": sim.vector_of_callable(_jx_attention, q, q, q)},
            replacement="repro.kernels.ops.flash_attention",
            plan_field=("attn_impl", "chunked"),
            threshold=0.80,
            interface_note="(B,S,H,D) q/kv layout; GQA via head count ratio",
        ),
        PatternRecord(
            name="rmsnorm",
            callee_names=("rmsnorm", "rms_norm", "layer_norm", "layernorm"),
            vectors={"python_ast": _py_vector(_PY_COMPARISON_CODE["rmsnorm"]),
                     "jaxpr": sim.vector_of_callable(_jx_rmsnorm, q, jnp.zeros((4,), f32))},
            replacement="repro.kernels.ops.rmsnorm",
            plan_field=("norm_impl", "fused"),
            threshold=0.90,
        ),
        PatternRecord(
            name="linear_recurrence",
            callee_names=("rglru", "lru", "linear_recurrence", "ssm_scan",
                          "selective_scan"),
            vectors={"python_ast": _py_vector(_PY_COMPARISON_CODE["linear_recurrence"]),
                     "jaxpr": _scan_region_vector(_jx_recurrence, la, la)},
            replacement="repro.kernels.ops.rglru_scan",
            plan_field=("rglru_impl", "chunked"),
            threshold=0.85,
        ),
        PatternRecord(
            name="wkv_recurrence",
            callee_names=("wkv", "wkv6", "rwkv", "time_mix"),
            vectors={"jaxpr": _scan_region_vector(
                _jx_wkv, q, q, q, la, jnp.zeros((4,), f32))},
            replacement="repro.kernels.ops.wkv6",
            plan_field=("wkv_impl", "chunked"),
            threshold=0.85,
        ),
        PatternRecord(
            name="matmul",
            callee_names=("matmul", "dot", "gemm", "mm", "bmm", "einsum"),
            vectors={"python_ast": _py_vector(_PY_COMPARISON_CODE["matmul"]),
                     "jaxpr": sim.vector_of_callable(_jx_matmul, q, q.T)},
            replacement="jnp.matmul",
            plan_field=None,
            threshold=0.88,
        ),
        PatternRecord(
            name="attention_stack",
            callee_names=("attention_stack", "attention_block", "attn_block",
                          "attention", "self_attention", "sdpa"),
            vectors={"python_ast": _py_vector(
                         _PY_COMPARISON_CODE["attention_stack"]),
                     "jaxpr": sim.vector_of_callable(
                         _jx_attention_stack, q, jnp.zeros((4,), f32),
                         q.T @ q, q.T @ q, q.T @ q)},
            replacement="repro.models.attention.attend_chunked",
            plan_field=("attn_impl", "chunked"),
            threshold=0.85,
            interface_note="whole rmsnorm+QKV+causal-attention block over an "
                           "(S, d) residual stream",
            block=True,
        ),
        PatternRecord(
            name="moe_dispatch",
            callee_names=("moe", "moe_dispatch", "moe_block", "router",
                          "mixture_of_experts", "expert_dispatch"),
            vectors={"jaxpr": sim.vector_of_callable(
                         _jx_moe_dispatch, q, q.T @ q,
                         jnp.zeros((4, 4, 8), f32), jnp.zeros((4, 4, 8), f32),
                         jnp.zeros((4, 8, 4), f32))},
            replacement="repro.models.moe.moe_scatter",
            plan_field=("moe_impl", "scatter_ep"),
            threshold=0.85,
            interface_note="router + top-k dispatch + batched expert FFN",
            block=True,
        ),
        PatternRecord(
            name="fft",
            callee_names=("fft", "rfft", "fft2", "ifft", "dft"),
            vectors={"python_ast": _py_vector(_PY_COMPARISON_CODE["fft"])},
            replacement="jnp.fft.fft",
            plan_field=None,
            threshold=0.85,
            interface_note="complex return instead of (re, im) pair",
            interface_changes=True,
        ),
    ]
    return PatternDB(recs)
