"""Language frontends for the common offload core.

Each frontend lowers one "source language" (Python source via ``ast``,
traced JAX via jaxpr, declarative model configs via the module graph) to the
shared Region IR and implements the :class:`~repro.core.frontends.registry.
Frontend` protocol.  Importing this package registers all shipped frontends
plus the generic ``ir`` frontend under their names, so
``repro.core.offload.Offloader`` can resolve any of them.
"""
from repro.core.frontends import (ast_frontend, jaxpr_frontend,
                                  module_frontend)
from repro.core.frontends.registry import (Frontend, FitnessBundle,
                                           IRFrontend, OffloadConfig,
                                           detect_frontend, frontend_names,
                                           get_frontend, register_frontend,
                                           static_cost_fitness_factory)

register_frontend(ast_frontend.AstFrontend())
register_frontend(jaxpr_frontend.JaxprFrontend())
register_frontend(module_frontend.ModuleFrontend())
register_frontend(IRFrontend())

__all__ = [
    "ast_frontend", "jaxpr_frontend", "module_frontend",
    "Frontend", "FitnessBundle", "IRFrontend", "OffloadConfig",
    "detect_frontend", "frontend_names", "get_frontend", "register_frontend",
    "static_cost_fitness_factory",
]
