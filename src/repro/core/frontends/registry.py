"""Frontend protocol + registry: the "common method" switchboard.

The paper's central claim is one offloading method across source languages:
every language parses into the common Region IR, and one GA-based search
runs over it.  A :class:`Frontend` is the per-language adapter that

  * ``build_graph``    — lowers a target (source string, callable, model
    config, …) to a :class:`~repro.core.ir.RegionGraph`,
  * ``make_fitness``   — builds the verification-environment measurement for
    that language (wall-clock interpreter for Python source, AOT cost model
    for module graphs, static transfer cost for graphs with no execution
    path yet), bundled with the function-block pass results, and
  * ``apply_plan``     — decodes the winning chromosome into the language's
    deliverable artifact (an implementation map, an ExecPlan, …).

Frontends register under names (``register_frontend``); the unified
pipeline (:mod:`repro.core.offload`) resolves one per target — explicitly
via ``OffloadConfig.frontend`` or by :func:`detect_frontend` — and drives
the same seed → evaluate → verify loop for all of them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Protocol, runtime_checkable

from repro.core.evaluator import transfer_cost_surrogate
from repro.core.ga import Evaluation, GAConfig
from repro.core.genes import GeneCoding
from repro.core.ir import RegionGraph

__all__ = [
    "Frontend", "FitnessBundle", "OffloadConfig",
    "register_frontend", "get_frontend", "frontend_names", "detect_frontend",
    "static_cost_fitness_factory", "decoded_pattern", "IRFrontend",
    "resolve_alphabet",
]


def decoded_pattern(coding: "GeneCoding", values, base_impl: Optional[dict]
                    = None) -> dict:
    """The one decode-merge rule: block-pass claims (``base_impl``) first,
    gene decode overrides — every frontend's final region -> impl map."""
    impl = dict(base_impl or {})
    impl.update(coding.decode(values))
    return impl


def resolve_alphabet(config: Optional["OffloadConfig"],
                     proposed: Optional[tuple] = None) -> tuple:
    """THE destination-alphabet precedence rule, in one place:

    1. an explicit ``OffloadConfig.destinations`` always wins (the caller
       knows the hardware they are planning for),
    2. else the frontend's proposal (``FitnessBundle.destinations`` — e.g.
       the jaxpr variant alphabet, extended with this host's executable
       mesh destinations),
    3. else :data:`~repro.core.genes.DEFAULT_ALPHABET` (the paper's binary
       cpu/gpu chromosome).

    Every entry is validated against the destination registry (mesh wire
    strings parse on demand), so a typo fails here — before a search — with
    the registry's own error."""
    from repro.core.genes import DEFAULT_ALPHABET, get_destination

    if config is not None and config.destinations is not None:
        alphabet = tuple(config.destinations)
    elif proposed:
        alphabet = tuple(proposed)
    else:
        alphabet = DEFAULT_ALPHABET
    for name in alphabet:
        get_destination(name)        # fail fast on unknown alphabet entries
    return alphabet


# ---------------------------------------------------------------------------
# pipeline configuration (lives here so frontends can type against it
# without importing the pipeline module)
# ---------------------------------------------------------------------------


@dataclass
class OffloadConfig:
    """One knob surface for every frontend's planning run."""

    frontend: Optional[str] = None            # None = detect from the target
    destinations: Optional[tuple] = None      # gene alphabet; None = the
                                              # frontend's proposed alphabet
                                              # (FitnessBundle.destinations)
                                              # or DEFAULT_ALPHABET — an
                                              # explicit value always wins
    ga: GAConfig = field(default_factory=GAConfig)
    db: Optional[Any] = None                  # PatternDB; default_db() if None
    confirm: Callable | bool = True           # interface-change confirmation
    repeats: int = 3                          # wall-clock timing repeats
    hoist_transfers: bool = True
    seed_from_db: bool = True                 # pattern-DB warm-start chromosome
    seed_from_neighbors: bool = True          # similarity-neighbor warm starts
    fitness_fn: Optional[Callable[[tuple], Evaluation]] = None
                                              # override: bypass the frontend's
                                              # fitness (custom verification
                                              # environments, deterministic
                                              # test harnesses)
    log: Optional[Callable[[str], None]] = None
    trace: Optional[str] = None               # JSONL trace file: Offloader
                                              # phases (prepare/search/apply),
                                              # evaluator batches and per-
                                              # chromosome prepare/measure
                                              # spans are recorded there (see
                                              # repro.obs.trace + the
                                              # launch/obsreport CLI); None =
                                              # tracing disabled (near-zero
                                              # cost)
    options: dict = field(default_factory=dict)   # frontend-specific knobs
                                              # (module: lower_fn, n_devices,
                                              #  model_flops, hbm_budget,
                                              #  base_plan; jaxpr:
                                              #  example_args, name)


@dataclass
class FitnessBundle:
    """What a frontend hands the pipeline: measurement + block-pass context.

    ``fitness_factory`` is deferred on the gene coding because the coding is
    derived *after* the block pass claims regions (and carries the
    destination alphabet); the pipeline builds it exactly once.
    """

    fitness_factory: Callable[[GeneCoding], Callable[[tuple], Evaluation]]
    block: Any = None                         # BlockOffloadResult
    claimed: tuple = ()                       # regions excluded from the gene
    base_impl: dict = field(default_factory=dict)  # block-claim impl bindings
    cache_extra: str = ""                     # measurement-context cache key
    serial_only: bool = False                 # wall-clock: timings don't
                                              # interleave; force workers=0
    overlap_compiles: bool = False            # a chromosome's warm-up is one
                                              # big GIL-releasing compile
                                              # (substitute + jax.jit):
                                              # Offloader.plan enables the
                                              # compile-parallel/time-serial
                                              # phase when GAConfig.
                                              # compile_workers is unset.
                                              # Leave False where prepare is
                                              # many small compiles or GIL-
                                              # held interpretation — those
                                              # contend instead of overlapping
    measured: bool = True                     # False = static-cost stub (no
                                              # real execution behind fitness)
    destinations: Optional[tuple] = None      # frontend-proposed gene
                                              # alphabet (e.g. the jaxpr
                                              # variant alphabet); used when
                                              # the config left the default
    mesh_executed: bool = False               # False (default) means mesh
                                              # genes
                                              # are never genuinely decoded
                                              # to shard_map execution by
                                              # this fitness, so the mesh
                                              # cost model must be charged
                                              # on top of measurements even
                                              # when the host has the
                                              # devices (ast/module paths).
                                              # Irrelevant when no mesh
                                              # destination is in play
    impl_resolver: Optional[Callable[[str, Any], Any]] = None
                                              # (region, decoded impl) -> the
                                              # impl that actually runs after
                                              # the frontend's bind/fallback
                                              # rule — folded into the
                                              # phenotype key so chromosomes
                                              # whose variants fall back to
                                              # the same implementation share
                                              # one measurement.  Must be
                                              # static per (region, impl)
                                              # for the search's lifetime

    context: dict = field(default_factory=dict)    # frontend-private state,
                                              # consumed by apply_plan / shims


@runtime_checkable
class Frontend(Protocol):
    """Per-language adapter; see module docstring for the contract."""

    name: str

    def build_graph(self, target: Any, inputs: Optional[dict],
                    config: OffloadConfig) -> RegionGraph: ...

    def make_fitness(self, graph: RegionGraph, target: Any,
                     inputs: Optional[dict],
                     config: OffloadConfig) -> FitnessBundle: ...

    def apply_plan(self, graph: RegionGraph, coding: GeneCoding,
                   values: tuple, bundle: FitnessBundle) -> Any: ...


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Frontend] = {}


def register_frontend(frontend: Frontend, replace: bool = False) -> None:
    if frontend.name in _REGISTRY and not replace:
        raise ValueError(f"frontend {frontend.name!r} already registered")
    _REGISTRY[frontend.name] = frontend


def get_frontend(name: str) -> Frontend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown frontend {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def frontend_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def detect_frontend(target: Any, config: OffloadConfig) -> str:
    """Map a target to a registered frontend name (duck-typed so the
    registry never imports a concrete frontend module)."""
    if isinstance(target, RegionGraph):
        return "ir"
    if isinstance(target, str):
        return "python_ast"
    if hasattr(target, "graph") and hasattr(target, "check_offloadable"):
        return "python_ast"                    # a parsed PyProgram
    if hasattr(target, "arch_id") and hasattr(target, "family"):
        return "module"                        # an ArchConfig
    if callable(target):
        # a callable with example args is jax-traceable; otherwise its
        # source is parsed like any other Python program
        if "example_args" in config.options:
            return "jaxpr"
        return "python_ast"
    raise TypeError(f"cannot detect a frontend for target of type "
                    f"{type(target).__name__}; pass OffloadConfig.frontend")


# ---------------------------------------------------------------------------
# shared static-cost fitness (frontends without an execution path yet)
# ---------------------------------------------------------------------------


def static_cost_fitness_factory(graph: RegionGraph, unit_s: float = 1e-6
                                ) -> Callable[[GeneCoding], Callable]:
    """Deterministic fitness from the transfer planner's static cost.

    The stand-in verification environment for frontends whose offloaded
    implementations don't exist yet (jaxpr kernel substitution, bare region
    graphs): estimated transfer volume decides, more offloaded work breaks
    ties.  Deterministic, so fixed-seed searches reproduce exactly; every
    Evaluation is tagged ``static_cost`` so results are never mistaken for
    measurements.
    """
    def factory(coding: GeneCoding) -> Callable[[tuple], Evaluation]:
        cost = transfer_cost_surrogate(graph, coding)

        def fit(values: tuple) -> Evaluation:
            values = tuple(values)
            # the surrogate's more-offload tiebreak is a tiny negative term;
            # keep it (floor only guards against a pathological surrogate)
            t = unit_s * max(1.0 + cost(values), 1e-9)
            return Evaluation(values, t, True, {"static_cost": True})

        return fit

    return factory


# ---------------------------------------------------------------------------
# the generic IR frontend: plan a bare RegionGraph
# ---------------------------------------------------------------------------


class IRFrontend:
    """Plans any :class:`RegionGraph` directly — the degenerate frontend the
    other three lower into, useful for tests and for callers that built
    their graph elsewhere.  Fitness is the static-cost stub unless the
    config overrides it."""

    name = "ir"

    def build_graph(self, target: RegionGraph, inputs: Optional[dict],
                    config: OffloadConfig) -> RegionGraph:
        if not isinstance(target, RegionGraph):
            raise TypeError(f"ir frontend needs a RegionGraph, got "
                            f"{type(target).__name__}")
        return target

    def make_fitness(self, graph: RegionGraph, target: Any,
                     inputs: Optional[dict],
                     config: OffloadConfig) -> FitnessBundle:
        from repro.core.block_offload import block_offload_pass
        from repro.core.pattern_db import default_db

        block = block_offload_pass(graph, config.db or default_db(),
                                   confirm=config.confirm)
        return FitnessBundle(
            fitness_factory=static_cost_fitness_factory(graph),
            block=block, claimed=block.claimed_regions,
            cache_extra="ir|staticcost", measured=False)

    def apply_plan(self, graph: RegionGraph, coding: GeneCoding,
                   values: tuple, bundle: FitnessBundle) -> dict:
        return decoded_pattern(coding, values, bundle.base_impl)
