"""Module-graph frontend: repro model configs -> Region IR.

The third "source language" (the declarative one, playing Java's role in
the paper's trio): a model described by an :class:`ArchConfig` lowers to
regions named after its offloadable sites — the ExecPlan knobs applicable to
that architecture family.  Gene bit k toggles site k between its reference
and offloaded implementation, exactly as the paper toggles loop statements.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ArchConfig
from repro.core.ir import Region, RegionGraph
from repro.models.plan import ExecPlan

# site -> (applicability predicate, callees exposed for DB name-matching)
_SITE_DEFS = [
    ("attn_impl", lambda c: c.attn_kind != "none",
     ("attention", "softmax", "sdpa")),
    ("norm_impl", lambda c: True, ("rmsnorm", "layer_norm")),
    ("mlp_impl", lambda c: True, ("mlp", "ffn", "geglu", "swiglu")),
    ("qkv_fused", lambda c: c.attn_kind != "none", ("qkv_proj", "matmul")),
    ("rglru_impl", lambda c: bool(c.block_pattern), ("rglru", "linear_recurrence")),
    ("wkv_impl", lambda c: c.family == "ssm", ("wkv", "rwkv", "time_mix")),
    ("moe_impl", lambda c: c.moe is not None, ("moe", "top_k", "dispatch")),
    ("loss_impl", lambda c: True, ("cross_entropy", "softmax", "logsumexp")),
    ("remat", lambda c: True, ("checkpoint", "remat")),
    ("gather_mode", lambda c: True, ("all_gather", "fsdp")),
]

_REF_OFFLOAD = {f: (r, o) for f, r, o in ExecPlan.OFFLOAD_SITES}


def build_graph(cfg: ArchConfig) -> RegionGraph:
    regions: list[Region] = []
    for field, applicable, callees in _SITE_DEFS:
        if not applicable(cfg):
            continue
        ref, off = _REF_OFFLOAD[field]
        regions.append(Region(
            name=field,
            kind="loop" if field in ("attn_impl", "rglru_impl", "wkv_impl",
                                     "loss_impl") else "block",
            defs=frozenset({f"{field}_out"}),
            uses=frozenset({f"{field}_in", "params"}),
            callees=callees,
            feature_vector={},
            offloadable=True,
            alternatives=(ref, off),
            meta={"plan_field": field},
        ))
    return RegionGraph(regions, "module", cfg.arch_id)


def plan_from_bits(graph: RegionGraph, bits, base: Optional[ExecPlan] = None,
                   exclude: tuple = ()) -> ExecPlan:
    """Decode a chromosome into an ExecPlan (respecting block-pass claims)."""
    plan = base or ExecPlan()
    sites = [r for r in graph.offloadable() if r.name not in exclude]
    assert len(bits) == len(sites), (len(bits), len(sites))
    kw = {}
    for r, b in zip(sites, bits):
        field = r.meta["plan_field"]
        ref, off = _REF_OFFLOAD[field]
        kw[field] = off if b else ref
    return plan.replace(**kw)
