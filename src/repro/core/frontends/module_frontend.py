"""Module-graph frontend: repro model configs -> Region IR.

The third "source language" (the declarative one, playing Java's role in
the paper's trio): a model described by an :class:`ArchConfig` lowers to
regions named after its offloadable sites — the ExecPlan knobs applicable to
that architecture family.  Gene bit k toggles site k between its reference
and offloaded implementation, exactly as the paper toggles loop statements;
sites with more than two shipped implementations (``ExecPlan.SITE_VARIANTS``,
e.g. the rg-LRU step/assoc/chunked scans) expose the full menu, so a gene
over the variant alphabet selects *which* implementation runs.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ArchConfig
from repro.core.ir import Region, RegionGraph
from repro.models.plan import ExecPlan

# site -> (applicability predicate, callees exposed for DB name-matching)
_SITE_DEFS = [
    ("attn_impl", lambda c: c.attn_kind != "none",
     ("attention", "softmax", "sdpa")),
    ("norm_impl", lambda c: True, ("rmsnorm", "layer_norm")),
    ("mlp_impl", lambda c: True, ("mlp", "ffn", "geglu", "swiglu")),
    ("qkv_fused", lambda c: c.attn_kind != "none", ("qkv_proj", "matmul")),
    ("rglru_impl", lambda c: bool(c.block_pattern), ("rglru", "linear_recurrence")),
    ("wkv_impl", lambda c: c.family == "ssm", ("wkv", "rwkv", "time_mix")),
    ("moe_impl", lambda c: c.moe is not None, ("moe", "top_k", "dispatch")),
    ("loss_impl", lambda c: True, ("cross_entropy", "softmax", "logsumexp")),
    ("remat", lambda c: True, ("checkpoint", "remat")),
    ("gather_mode", lambda c: True, ("all_gather", "fsdp")),
]

_REF_OFFLOAD = {f: (r, o) for f, r, o in ExecPlan.OFFLOAD_SITES}


def build_graph(cfg: ArchConfig) -> RegionGraph:
    regions: list[Region] = []
    for field, applicable, callees in _SITE_DEFS:
        if not applicable(cfg):
            continue
        # full implementation menu where the executors ship one (ExecPlan.
        # SITE_VARIANTS, e.g. rglru step/assoc/chunked): genes then select
        # WHICH implementation runs; binary sites clamp at their pair
        alternatives = ExecPlan.SITE_VARIANTS.get(field) \
            or _REF_OFFLOAD[field]
        meta = {"plan_field": field}
        if field in ("remat", "gather_mode"):
            # schedule knobs move recomputation/gather placement, not data
            # onto a device: the transfer planner must not read their
            # non-reference menu positions as accelerator placements
            meta["schedule_knob"] = True
        regions.append(Region(
            name=field,
            kind="loop" if field in ("attn_impl", "rglru_impl", "wkv_impl",
                                     "loss_impl") else "block",
            defs=frozenset({f"{field}_out"}),
            uses=frozenset({f"{field}_in", "params"}),
            callees=callees,
            feature_vector={},
            offloadable=True,
            alternatives=tuple(alternatives),
            meta=meta,
        ))
    return RegionGraph(regions, "module", cfg.arch_id)


def plan_from_bits(graph: RegionGraph, bits, base: Optional[ExecPlan] = None,
                   exclude: tuple = ()) -> ExecPlan:
    """Decode a chromosome into an ExecPlan (respecting block-pass claims).

    Multi-destination genes are welcome: value 1 is the primary accelerator
    (the offloaded plan value); any other value — 0 (CPU) or a cost-only
    stub destination — keeps the reference value, since only executable
    destinations change what actually compiles.
    """
    plan = base or ExecPlan()
    sites = [r for r in graph.offloadable() if r.name not in exclude]
    assert len(bits) == len(sites), (len(bits), len(sites))
    kw = {}
    for r, b in zip(sites, bits):
        field = r.meta["plan_field"]
        ref, off = _REF_OFFLOAD[field]
        kw[field] = off if int(b) == 1 else ref
    return plan.replace(**kw)


def plan_from_coding(graph: RegionGraph, coding, values,
                     base: Optional[ExecPlan] = None) -> ExecPlan:
    """Destination-aware decode: the coding's alphabet picks each site's
    implementation (cost-only destinations resolve to the reference value)."""
    impl = coding.decode(values)
    plan = base or ExecPlan()
    kw = {graph.by_name(region).meta["plan_field"]: value
          for region, value in impl.items()}
    return plan.replace(**kw)


# ---------------------------------------------------------------------------
# the Frontend adapter (repro.core.frontends.registry protocol)
# ---------------------------------------------------------------------------


class ModuleFrontend:
    """Model-config frontend for the unified pipeline: sites are ExecPlan
    knobs; fitness is the AOT cost model when the caller provides a
    ``lower_fn`` (options: lower_fn, n_devices, model_flops, hbm_budget,
    base_plan), else the static-cost stub.

    The static fallback carries only structural signal for module graphs:
    accelerated ExecPlan *compute* values count as device placements in the
    IR transfer planner (their position >= 1 in the region's own
    ``alternatives`` menu), so the static cost charges each offloaded
    compute site its parameter/input uploads and those genes stay
    conservative.  Schedule knobs (remat / gather_mode) are deliberately
    transfer-free there (``meta["schedule_knob"]``), so they decay to the
    surrogate's more-offload tiebreak and converge to their non-reference
    values.  Either way this
    makes the fallback a fast
    structural smoke path (graph/coding/pipeline round-trips without a
    mesh); for decisions that matter, pass ``lower_fn`` so chromosomes are
    scored by compiled artifacts."""

    name = "module"

    def build_graph(self, cfg: ArchConfig, inputs, config) -> RegionGraph:
        return build_graph(cfg)

    def make_fitness(self, graph: RegionGraph, cfg: ArchConfig, inputs,
                     config):
        from repro.core.block_offload import block_offload_pass
        from repro.core.frontends.registry import (FitnessBundle,
                                                   static_cost_fitness_factory)
        from repro.core.pattern_db import default_db

        opts = config.options
        db = config.db or default_db()
        block = block_offload_pass(graph, db, confirm=config.confirm)
        base = (opts.get("base_plan") or ExecPlan()).replace(
            **block.plan_updates)
        exclude = block.claimed_regions
        lower_fn = opts.get("lower_fn")
        context = {"base_plan": base}

        from repro.core.genes import VARIANT_ALPHABET

        if lower_fn is None:
            return FitnessBundle(
                fitness_factory=static_cost_fitness_factory(graph),
                block=block, claimed=exclude,
                cache_extra=f"arch={cfg.arch_id}|staticcost",
                measured=False, destinations=VARIANT_ALPHABET,
                context=context)

        n_devices = int(opts.get("n_devices", 1))
        model_flops = float(opts.get("model_flops", 0.0))
        hbm_budget = float(opts.get("hbm_budget", 16e9))

        def fitness_factory(coding):
            from repro.core.fitness import CostModelFitness
            return CostModelFitness(
                lower=lambda values: lower_fn(
                    plan_from_coding(graph, coding, values, base)),
                n_devices=n_devices, model_flops=model_flops,
                hbm_budget=hbm_budget)

        # compiled step-time estimates are machine-portable — key the
        # persistent cache by architecture + mesh + scale
        cache_extra = (f"arch={cfg.arch_id}|dev={n_devices}"
                       f"|flops={model_flops:.3g}|hbm={hbm_budget:.3g}"
                       f"|base={base}|costmodel")
        return FitnessBundle(
            fitness_factory=fitness_factory, block=block, claimed=exclude,
            cache_extra=cache_extra, measured=True,
            # variant knobs (SITE_VARIANTS) make the gene an implementation
            # choice: propose the 3-letter variant alphabet so chromosomes
            # reach the extra implementations (binary sites clamp)
            destinations=VARIANT_ALPHABET, context=context)

    def apply_plan(self, graph: RegionGraph, coding, values, bundle
                   ) -> ExecPlan:
        return plan_from_coding(graph, coding, values,
                                bundle.context["base_plan"])
