"""jaxpr frontend: traced-JAX callables -> Region IR.

The "compiled language" path (the paper's C/Clang analogue): a JAX program
is traced to a ClosedJaxpr; control-flow equations (scan / while / cond /
pjit closed calls) become *loop/block* regions with their own characteristic
vectors, contiguous simple equations become *stmt* regions.  Variable
def/use sets come from the equation in/out vars, callees from primitive
names plus closed-call names — which is what the pattern DB's name matching
keys on (e.g. a user function named ``flash_attention`` or a scan named
``rglru`` matches directly, the paper's library-name match).
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.core import similarity as sim
from repro.core.ir import Region, RegionGraph

_LOOP_PRIMS = {"scan", "while", "fori_loop", "cond", "pjit", "custom_vjp_call",
               "custom_jvp_call", "remat", "checkpoint", "closed_call", "core_call"}


def _eqn_callees(eqn) -> tuple:
    names = [eqn.primitive.name]
    for k in ("name", "fun_name"):
        v = eqn.params.get(k)
        if isinstance(v, str):
            names.append(v)
    j = eqn.params.get("jaxpr")
    if j is not None and hasattr(j, "jaxpr"):
        for sub in j.jaxpr.eqns:
            names.append(sub.primitive.name)
    return tuple(names)


def build_graph(fn: Callable, *example_args, name: str = "") -> RegionGraph:
    closed = jax.make_jaxpr(fn)(*example_args)
    jaxpr = closed.jaxpr
    regions: list[Region] = []
    pending: list = []
    counter = 0

    def flush():
        nonlocal pending, counter
        if not pending:
            return
        defs = frozenset(str(v) for e in pending for v in e.outvars)
        uses = frozenset(str(v) for e in pending for v in e.invars
                         if hasattr(v, "count"))
        vec: dict = {}
        for e in pending:
            vec[e.primitive.name] = vec.get(e.primitive.name, 0) + 1
        # >= 5 equations = a "functional structure" worth pattern-matching
        # (paper Step1: 機能処理を分析); smaller runs are glue statements.
        is_block = len(pending) >= 5
        regions.append(Region(
            name=f"{'block' if is_block else 'stmt'}_{counter}",
            kind="block" if is_block else "stmt",
            defs=defs, uses=uses,
            callees=tuple(e.primitive.name for e in pending),
            feature_vector=vec, offloadable=is_block,
            alternatives=("ref", "kernel") if is_block else ()))
        counter += 1
        pending = []

    for eqn in jaxpr.eqns:
        pname = eqn.primitive.name
        if pname in _LOOP_PRIMS or "call" in pname:
            flush()
            sub = eqn.params.get("jaxpr")
            vec = sim.jaxpr_vector(sub) if sub is not None else {pname: 1}
            trip = eqn.params.get("length")
            regions.append(Region(
                name=f"{'loop' if pname in ('scan', 'while') else 'block'}_{counter}",
                kind="loop" if pname in ("scan", "while") else "block",
                defs=frozenset(str(v) for v in eqn.outvars),
                uses=frozenset(str(v) for v in eqn.invars if hasattr(v, "count")),
                callees=_eqn_callees(eqn),
                feature_vector=vec,
                offloadable=True,
                alternatives=("ref", "kernel"),
                trip_count=trip if isinstance(trip, int) else None,
                meta={"primitive": pname},
            ))
            counter += 1
        else:
            pending.append(eqn)
    flush()
    g = RegionGraph(regions, "jaxpr", name or getattr(fn, "__name__", "traced"))
    g.meta["whole_program_vector"] = sim.jaxpr_vector(closed)
    return g


# ---------------------------------------------------------------------------
# the Frontend adapter (repro.core.frontends.registry protocol)
# ---------------------------------------------------------------------------


class JaxprFrontend:
    """Traced-JAX frontend for the unified pipeline.

    ``options["example_args"]`` supplies the tracing arguments.  Kernel
    substitution for matched regions is not implemented yet, so the fitness
    is the shared static-cost stub (transfer volume over the region graph)
    — deterministic, which is exactly what the conformance contract needs;
    results carry ``static_cost`` so they are never mistaken for
    measurements.  ``apply_plan`` reports the region -> implementation map.
    """

    name = "jaxpr"

    def build_graph(self, fn: Callable, inputs, config) -> RegionGraph:
        example_args = config.options.get("example_args", ())
        return build_graph(fn, *example_args,
                           name=config.options.get("name", ""))

    def make_fitness(self, graph: RegionGraph, fn: Callable, inputs, config):
        from repro.core.block_offload import block_offload_pass
        from repro.core.frontends.registry import (FitnessBundle,
                                                   static_cost_fitness_factory)
        from repro.core.pattern_db import default_db

        block = block_offload_pass(graph, config.db or default_db(),
                                   confirm=config.confirm)
        return FitnessBundle(
            fitness_factory=static_cost_fitness_factory(graph),
            block=block, claimed=block.claimed_regions,
            base_impl={r: "kernel" for r in block.claimed_regions},
            cache_extra=f"jaxpr={graph.source_name}|staticcost",
            measured=False)

    def apply_plan(self, graph: RegionGraph, coding, values, bundle) -> dict:
        from repro.core.frontends.registry import decoded_pattern
        return decoded_pattern(coding, values, bundle.base_impl)
