"""jaxpr frontend: traced-JAX callables -> Region IR -> substituted programs.

The "compiled language" path (the paper's C/Clang analogue): a JAX program
is traced to a ClosedJaxpr; control-flow equations (scan / while / cond /
user pjit closed calls) become *loop/block* regions with their own
characteristic vectors, contiguous simple equations become *stmt* regions.
Small glue calls (a pjit'd ``tril`` or ``where`` with a handful of inner
equations) are folded into the surrounding run so a hand-written attention
stays one matchable block instead of fragmenting at every jnp helper.
Variable def/use sets come from the equation in/out vars, callees from
primitive names plus closed-call names — which is what the pattern DB's
name matching keys on (e.g. a user function named ``flash_attention``
matches directly, the paper's library-name match).

Every region records its equation span (``meta["eqn_span"]``), and matched
regions are annotated with their pattern and the kernel registry's variant
alphabet (:func:`annotate_variants`) — which is what lets the substitution
engine (:mod:`repro.core.substitution`) turn a plan into a *runnable*
program and :meth:`JaxprFrontend.make_fitness` measure real wall-clock
instead of the static transfer-cost stub.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import similarity as sim
from repro.core.ir import Region, RegionGraph

_LOOP_PRIMS = {"scan", "while", "fori_loop", "cond", "pjit", "custom_vjp_call",
               "custom_jvp_call", "remat", "checkpoint", "closed_call", "core_call"}

#: control-flow primitives are never glue, whatever their body size
_CONTROL_PRIMS = {"scan", "while", "fori_loop", "cond"}

#: a closed call with fewer inner equations than this is jnp-internal glue
#: (tril, where, ...) and folds into the surrounding statement run — the
#: same ">= 5 equations is a functional structure" rule the flush uses.
_GLUE_MAX_EQNS = 4


def _eqn_callees(eqn) -> tuple:
    names = [eqn.primitive.name]
    for k in ("name", "fun_name"):
        v = eqn.params.get(k)
        if isinstance(v, str):
            names.append(v)
    j = eqn.params.get("jaxpr")
    if j is not None and hasattr(j, "jaxpr"):
        for sub in j.jaxpr.eqns:
            names.append(sub.primitive.name)
    return tuple(names)


def _inner_eqn_count(eqn) -> int:
    """Equations inside a closed call, recursing through nested calls — a
    thin jit wrapper delegating to one big jitted helper is not glue."""
    def count(eqns) -> int:
        total = 0
        for e in eqns:
            total += 1
            for v in e.params.values():
                for sub in sim._sub_jaxpr(v):
                    total += count(sub.eqns)
        return total

    return sum(count(sub.eqns)
               for v in eqn.params.values() for sub in sim._sub_jaxpr(v))


def _is_glue(eqn, derived: set) -> bool:
    """Small closed calls, and calls none of whose inputs derive from the
    program's inputs (mask builders like a pjit'd ``tril`` over constants
    compute the same value every run), are glue: they fold into the
    surrounding run instead of splitting a matchable block.  Any
    input-derived operand — float activations or integer indices into a
    closed-over table — keeps the call a region of its own."""
    if eqn.primitive.name in _CONTROL_PRIMS:
        return False
    if _inner_eqn_count(eqn) <= _GLUE_MAX_EQNS:
        return True
    return not any(v in derived for v in eqn.invars if hasattr(v, "count"))


def build_graph(fn: Callable, *example_args, name: str = "") -> RegionGraph:
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*example_args)
    jaxpr = closed.jaxpr
    regions: list[Region] = []
    pending: list = []          # (eqn index, eqn)
    counter = 0

    # stable var naming by first appearance: str(Var) embeds the object id,
    # which would make def/use sets — and the graph fingerprint keying the
    # persistent measurement cache — differ between processes and traces
    _names: dict = {}

    def vname(v) -> str:
        return _names.setdefault(v, f"v{len(_names)}")

    for v in list(jaxpr.constvars) + list(jaxpr.invars):
        vname(v)
    for e in jaxpr.eqns:
        for v in list(e.invars) + list(e.outvars):
            if hasattr(v, "count"):
                vname(v)

    def flush():
        nonlocal pending, counter
        if not pending:
            return
        eqns = [e for _, e in pending]
        defs = frozenset(vname(v) for e in eqns for v in e.outvars)
        uses = frozenset(vname(v) for e in eqns for v in e.invars
                         if hasattr(v, "count"))
        vec = sim.eqns_vector(eqns)
        # >= 5 equations = a "functional structure" worth pattern-matching
        # (paper Step1: 機能処理を分析); smaller runs are glue statements.
        is_block = len(eqns) >= 5
        regions.append(Region(
            name=f"{'block' if is_block else 'stmt'}_{counter}",
            kind="block" if is_block else "stmt",
            defs=defs, uses=uses,
            callees=tuple(e.primitive.name for e in eqns),
            feature_vector=vec, offloadable=is_block,
            alternatives=("ref", "kernel") if is_block else (),
            meta={"eqn_span": (pending[0][0], pending[-1][0] + 1)}))
        counter += 1
        pending = []

    # vars carrying data derived from the program inputs (vs masks/consts)
    derived: set = set(jaxpr.invars)
    for idx, eqn in enumerate(jaxpr.eqns):
        pname = eqn.primitive.name
        if any(v in derived for v in eqn.invars if hasattr(v, "count")):
            derived.update(eqn.outvars)
        if (pname in _LOOP_PRIMS or "call" in pname) \
                and not _is_glue(eqn, derived):
            flush()
            sub = eqn.params.get("jaxpr")
            vec = sim.jaxpr_vector(sub) if sub is not None else {}
            vec[pname] = vec.get(pname, 0) + 1
            trip = eqn.params.get("length")
            meta: dict = {"primitive": pname, "eqn_span": (idx, idx + 1)}
            if pname == "scan":
                meta["scan"] = {k: eqn.params.get(k)
                                for k in ("num_consts", "num_carry",
                                          "length", "reverse")}
            regions.append(Region(
                name=f"{'loop' if pname in ('scan', 'while') else 'block'}_{counter}",
                kind="loop" if pname in ("scan", "while") else "block",
                defs=frozenset(vname(v) for v in eqn.outvars),
                uses=frozenset(vname(v) for v in eqn.invars
                               if hasattr(v, "count")),
                callees=_eqn_callees(eqn),
                feature_vector=vec,
                offloadable=True,
                alternatives=("ref", "kernel"),
                trip_count=trip if isinstance(trip, int) else None,
                meta=meta,
            ))
            counter += 1
        else:
            pending.append((idx, eqn))
    flush()
    g = RegionGraph(regions, "jaxpr", name or getattr(fn, "__name__", "traced"))
    g.meta["whole_program_vector"] = sim.jaxpr_vector(closed)
    # the trace the eqn spans index, for the substitution engine: reusing it
    # avoids re-tracing and guarantees span alignment (in-memory only; the
    # fingerprint never hashes meta)
    g.meta["closed_jaxpr"] = closed
    g.meta["out_tree"] = jax.tree_util.tree_structure(out_shape)
    return g


def annotate_variants(graph: RegionGraph, db, registry=None) -> RegionGraph:
    """Match offloadable regions against the pattern DB and widen their
    implementation alternatives to the registry's executable variants.

    A matched region gets ``meta["pattern"]`` (the pattern-DB record name,
    what the substitution engine keys variants on) and
    ``alternatives = ("ref",) + variant names`` — so a gene over the variant
    alphabet (:data:`repro.core.genes.VARIANT_ALPHABET`) selects *which
    implementation runs*, not just placement.  Unmatched regions keep the
    legacy ``("ref", "kernel")`` pair.
    """
    from repro.kernels.registry import default_registry

    registry = registry or default_registry()
    for region in graph.offloadable():
        matches = db.match_region(region, graph.frontend)
        if not matches:
            continue
        m = matches[0]
        names = registry.variant_names(m.record.name)
        if not names:
            continue
        region.meta["pattern"] = m.record.name
        region.meta["pattern_match"] = {"how": m.how,
                                        "score": round(m.score, 4)}
        region.alternatives = ("ref",) + names
    return graph


def annotate_block_sites(graph: RegionGraph, db, registry=None) -> RegionGraph:
    """Detect *function-block* offload sites: maximal windows of adjacent
    offloadable regions whose merged shape matches a ``block`` pattern-DB
    record (arXiv 2004.09883's function-block genes alongside loop genes).

    Each accepted window becomes a synthetic ``fnblock_*`` region appended
    to the graph: one extra gene whose accelerated alternatives are the
    registry's *block-level* variants.  While that gene is active it claims
    its ``meta["block_members"]`` (see :class:`repro.core.genes.Site`), so
    the member regions' own genes go inert and the whole span runs through
    the block adapter.  The region carries empty def/use sets — the block
    substitutes *in place of* its members, so the transfer planner must not
    charge it extra traffic.

    Windows are tried widest-first and accepted greedily non-overlapping; a
    window is kept only if at least one registry variant actually binds the
    merged span's concrete avals (no dead genes in the chromosome).
    """
    from jax import core as jcore

    from repro.core.substitution import _span_io
    from repro.core.variants import resolve_variant
    from repro.kernels.registry import CallSite, default_registry

    registry = registry or default_registry()
    closed = graph.meta.get("closed_jaxpr")
    if closed is None:
        return graph
    eqns = closed.jaxpr.eqns
    last_use: dict = {}
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if not isinstance(v, jcore.Literal):
                last_use[v] = i
    program_outs = {v for v in closed.jaxpr.outvars
                    if not isinstance(v, jcore.Literal)}
    backend = jax.default_backend()

    # maximal runs of span-adjacent offloadable candidates, program order
    cands = [r for r in graph.regions
             if r.offloadable and r.meta.get("eqn_span") is not None
             and not r.meta.get("block_members")]
    runs: list[list[Region]] = []
    for r in cands:
        if runs and runs[-1][-1].meta["eqn_span"][1] == r.meta["eqn_span"][0]:
            runs[-1].append(r)
        else:
            runs.append([r])

    accepted: list[tuple[int, int]] = []
    blocks: list[Region] = []
    for run in runs:
        for width in range(len(run), 1, -1):
            for lo in range(len(run) - width + 1):
                members = run[lo:lo + width]
                s = members[0].meta["eqn_span"][0]
                e = members[-1].meta["eqn_span"][1]
                if any(s < e0 and s0 < e for s0, e0 in accepted):
                    continue
                m = db.match_block(members, graph.frontend)
                if m is None:
                    continue
                names = registry.variant_names(m.record.name)
                if not names:
                    continue

                def used_later(v, _e=e):
                    return v in program_outs or last_use.get(v, -1) >= _e

                ins, outs = _span_io(eqns[s:e], used_later)
                site = CallSite(
                    pattern=m.record.name, kind="block",
                    in_avals=tuple(v.aval for v in ins),
                    out_avals=tuple(v.aval for v in outs),
                    out_used=(True,) * len(outs), params={},
                    backend=backend, eqns=tuple(eqns[s:e]),
                    in_vars=tuple(ins))
                if not any(resolve_variant(site, n, registry=registry,
                                           backend=backend)[0] is not None
                           for n in names):
                    continue
                vec: dict = {}
                for r in members:
                    for k, c in r.feature_vector.items():
                        vec[k] = vec.get(k, 0) + c
                blocks.append(Region(
                    name=f"fnblock_{len(blocks)}",
                    kind="block",
                    defs=frozenset(), uses=frozenset(),
                    callees=tuple(dict.fromkeys(
                        c for r in members for c in r.callees)),
                    feature_vector=vec,
                    offloadable=True,
                    alternatives=("ref",) + names,
                    meta={"pattern": m.record.name,
                          "pattern_match": {"how": m.how,
                                            "score": round(m.score, 4)},
                          "eqn_span": (s, e),
                          "block_members": tuple(r.name for r in members)}))
                accepted.append((s, e))
    graph.regions.extend(blocks)
    return graph


# ---------------------------------------------------------------------------
# the Frontend adapter (repro.core.frontends.registry protocol)
# ---------------------------------------------------------------------------


class JaxprFrontend:
    """Traced-JAX frontend for the unified pipeline.

    ``options["example_args"]`` supplies the tracing arguments.  The default
    fitness is *measured*: every chromosome decodes to a substituted program
    (kernel registry variants spliced in by the substitution engine), which
    is jitted, verified against the unsubstituted reference
    (:mod:`repro.core.verifier` numeric equivalence) and wall-clock timed —
    the paper's verification-environment loop on real artifacts.  Pass
    ``options={"static_cost": True}`` to keep the deterministic transfer
    cost stub instead (the conformance-friendly no-execution path; results
    carry ``static_cost`` so they are never mistaken for measurements).
    """

    name = "jaxpr"

    def build_graph(self, fn: Callable, inputs, config) -> RegionGraph:
        from repro.core.pattern_db import default_db

        example_args = config.options.get("example_args", ())
        graph = build_graph(fn, *example_args,
                            name=config.options.get("name", ""))
        db = config.db or default_db()
        graph = annotate_variants(graph, db,
                                  registry=config.options.get("registry"))
        # function-block genes (whole-window substitution) ride alongside
        # the loop/span genes unless explicitly disabled — benchmarks use
        # options={"block_sites": False} for the loop-only comparison arm
        if config.options.get("block_sites", True):
            graph = annotate_block_sites(
                graph, db, registry=config.options.get("registry"))
        return graph

    def make_fitness(self, graph: RegionGraph, fn: Callable, inputs, config):
        from repro.core.block_offload import block_offload_pass
        from repro.core.frontends.registry import (FitnessBundle,
                                                   static_cost_fitness_factory)
        from repro.core.pattern_db import default_db

        block = block_offload_pass(graph, config.db or default_db(),
                                   confirm=config.confirm)
        if config.options.get("static_cost"):
            return FitnessBundle(
                fitness_factory=static_cost_fitness_factory(graph),
                block=block, claimed=block.claimed_regions,
                base_impl={r: "kernel" for r in block.claimed_regions},
                cache_extra=f"jaxpr={graph.source_name}|staticcost",
                measured=False)

        import threading

        from repro.core.fitness import WallClockFitness
        from repro.core.frontends.registry import decoded_pattern
        from repro.core.genes import VARIANT_ALPHABET, with_mesh_destinations
        from repro.core.pattern_db import record_pattern_outcome
        from repro.core.substitution import SubstitutionEngine

        example_args = tuple(config.options.get("example_args", ()))
        engine = SubstitutionEngine(fn, example_args, graph,
                                    registry=config.options.get("registry"))
        reference_output = jax.tree_util.tree_map(
            lambda x: np.asarray(x) if hasattr(x, "dtype") else x,
            engine.reference())
        args_sig = ",".join(
            f"{tuple(np.shape(a))}:{getattr(a, 'dtype', np.dtype(type(a)))}"
            for a in jax.tree_util.tree_leaves(example_args))
        repeats = config.repeats
        precision_dir = config.ga.cache_dir

        def factory(coding):
            # bits -> SubstitutionReport of the program just built, so the
            # verifier outcome in prepare() can be attributed per (pattern,
            # variant).  Guarded: prepare may run on compile-pool threads.
            reports: dict = {}
            rlock = threading.Lock()

            def build(values):
                values = tuple(values)
                impl = decoded_pattern(coding, values, {})
                sub = engine.substitute(
                    impl, destinations=coding.destinations_of(values))
                with rlock:
                    reports[tuple(values)] = sub.report
                jitted = jax.jit(sub.fn)
                return lambda: jitted(*example_args)

            class _RecordingFitness(WallClockFitness):
                """Classify each chromosome's verifier outcome and journal
                it per substituted (pattern, variant) — the ROADMAP's
                per-pattern match-precision record."""

                def prepare(self, bits):
                    prep = super().prepare(tuple(bits))
                    with rlock:
                        report = reports.pop(tuple(bits), None)
                    if report is None:     # build itself failed: no program
                        return prep
                    if prep.failure is None:
                        outcome = "ok"
                    elif "verify" in prep.failure.detail:
                        outcome = "verify_fail"
                    else:
                        outcome = "error"
                    for c in report.choices:
                        if c.chosen != "ref":
                            record_pattern_outcome(
                                precision_dir, c.pattern, c.chosen,
                                outcome, region=c.region)
                        elif c.requested not in ("ref", "interp",
                                                 "host", "cpu"):
                            record_pattern_outcome(
                                precision_dir, c.pattern, c.requested,
                                "bind_fail", region=c.region)
                    return prep

            return _RecordingFitness(build, reference_output=reference_output,
                                     repeats=repeats)

        # note: block-pass matches are *not* claimed here — on the measured
        # path the genes range over each matched region's variant set (the
        # paper measures replacement blocks on/off too), so the GA decides
        # which implementation runs; the block result remains for reporting
        # and pattern-DB population seeding.
        return FitnessBundle(
            fitness_factory=factory,
            block=block, claimed=(), base_impl={},
            # device count joins the cache key: a mesh gene measured on an
            # 8-device host and the same bits cost-modeled on a laptop are
            # different experiments
            cache_extra=(f"jaxpr={graph.source_name}|measured"
                         f"|args={args_sig}|backend={engine.backend}"
                         f"|ndev={jax.device_count()}"),
            serial_only=True, measured=True, overlap_compiles=True,
            # variant alphabet plus whatever meshes this host can really
            # build (empty extension on single-device CI)
            destinations=with_mesh_destinations(VARIANT_ALPHABET),
            # this measured path genuinely decodes mesh genes to shard_map
            # execution, so available meshes are measured, not modeled
            mesh_executed=True,
            # bind results join the phenotype key: two chromosomes whose
            # variants fall back to ref at a site are one program and
            # share one measurement (eager resolution is static per
            # (region, impl) — the avals never change)
            impl_resolver=engine.resolved_impl,
            context={"engine": engine, "example_args": example_args})

    def apply_plan(self, graph: RegionGraph, coding, values, bundle):
        from repro.core.frontends.registry import decoded_pattern

        values = tuple(values)
        impl = decoded_pattern(coding, values, bundle.base_impl)
        engine = bundle.context.get("engine")
        if engine is None:               # static-cost path: impl map only
            return impl
        return engine.substitute(impl,
                                 destinations=coding.destinations_of(values))
