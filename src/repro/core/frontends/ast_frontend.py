"""Python-`ast` frontend (paper §3.3.2 / §4.3.2).

Parses a *numeric* Python function with ``ast`` (exactly the tool the paper
names), extracts its loop statements and per-statement variable def/use sets,
and builds:

  * a :class:`RegionGraph` for the common core (genes, pattern DB, transfer
    planner), and
  * an *executor* that runs the function with any offload pattern: bit 0
    keeps a loop in the CPython interpreter (the paper's CPU path), bit 1
    compiles it with ``jax.jit`` after an np→jnp / in-place→functional
    rewrite (the paper's PyCUDA path, retargeted at XLA).

Loops that fail to compile under the offload rewrite are excluded from the
gene (paper: エラーが出る for 文は GA の対象外とする).  The executor counts
host↔device transfers and consults the transfer planner to hoist
loop-invariant transfers out of interpreted loops (paper's 一括転送).

Matched loop nests whose pattern has kernel-registry variants additionally
keep their gene over the *variant alphabet*: role inference concretizes the
loop to the same :class:`~repro.kernels.registry.CallSite` the jaxpr engine
binds against, the shared resolver (:mod:`repro.core.variants`) applies
each variant's availability predicate, and the bound adapters become the
region's lib-call menu — gene value k runs implementation k, exactly as on
the jaxpr path.
"""
from __future__ import annotations

import ast
import copy
import inspect
import textwrap
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import similarity as sim
from repro.core.ir import Region, RegionGraph

# ---------------------------------------------------------------------------
# AST analysis helpers
# ---------------------------------------------------------------------------


def _defs_uses(node: ast.AST) -> tuple[set, set]:
    defs: set = set()
    uses: set = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            if isinstance(n.ctx, ast.Store):
                defs.add(n.id)
            else:
                uses.add(n.id)
        elif isinstance(n, ast.Subscript):
            base = n.value
            while isinstance(base, ast.Subscript):
                base = base.value
            if isinstance(base, ast.Name):
                if isinstance(n.ctx, ast.Store):
                    defs.add(base.id)
                    uses.add(base.id)  # partial write reads the rest
        elif isinstance(n, ast.AugAssign):
            t = n.target
            if isinstance(t, ast.Name):
                uses.add(t.id)
    return defs, uses


def _callees(node: ast.AST) -> tuple:
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            name = sim._call_name(n)
            if name:
                out.append(name)
    return tuple(out)


def _static_trip_count(loop: ast.For, consts: dict) -> Optional[int]:
    it = loop.iter
    if isinstance(it, ast.Call) and isinstance(it.func, ast.Name) and it.func.id == "range":
        vals = []
        for a in it.args:
            if isinstance(a, ast.Constant) and isinstance(a.value, int):
                vals.append(a.value)
            elif isinstance(a, ast.Name) and isinstance(consts.get(a.id), int):
                vals.append(consts[a.id])
            else:
                return None
        if len(vals) == 1:
            return vals[0]
        if len(vals) >= 2:
            step = vals[2] if len(vals) == 3 else 1
            return max(0, (vals[1] - vals[0] + step - 1) // step)
    return None


# ---------------------------------------------------------------------------
# np -> jnp rewriting (the "language-dependent code generation")
# ---------------------------------------------------------------------------


class _JaxRewriter(ast.NodeTransformer):
    """np.X -> jnp.X, math.X -> jnp.X, a[i] = v -> a = a.at[i].set(v)."""

    def visit_Name(self, node: ast.Name):
        if node.id in ("np", "numpy", "math"):
            return ast.copy_location(ast.Name(id="jnp", ctx=node.ctx), node)
        return node

    def visit_Assign(self, node: ast.Assign):
        self.generic_visit(node)
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Subscript):
            tgt = node.targets[0]
            base = copy.deepcopy(tgt.value)
            _set_ctx_load(base)
            sl = copy.deepcopy(tgt.slice)
            _set_ctx_load(sl)
            at = ast.Attribute(value=base, attr="at", ctx=ast.Load())
            idx = ast.Subscript(value=at, slice=sl, ctx=ast.Load())
            call = ast.Call(
                func=ast.Attribute(value=idx, attr="set", ctx=ast.Load()),
                args=[node.value], keywords=[])
            new_target = copy.deepcopy(tgt.value)
            if not isinstance(new_target, ast.Name):
                raise _RewriteError("can only functionalize writes to simple names")
            new_target.ctx = ast.Store()
            return ast.copy_location(
                ast.Assign(targets=[new_target], value=call), node)
        return node

    def visit_AugAssign(self, node: ast.AugAssign):
        self.generic_visit(node)
        if isinstance(node.target, ast.Subscript):
            tgt = node.target
            base = copy.deepcopy(tgt.value)
            _set_ctx_load(base)
            sl = copy.deepcopy(tgt.slice)
            _set_ctx_load(sl)
            at = ast.Attribute(value=base, attr="at", ctx=ast.Load())
            idx = ast.Subscript(value=at, slice=sl, ctx=ast.Load())
            method = {"Add": "add", "Mult": "multiply"}.get(type(node.op).__name__)
            if method is None:
                raise _RewriteError(f"unsupported augmented op {type(node.op).__name__}")
            call = ast.Call(
                func=ast.Attribute(value=idx, attr=method, ctx=ast.Load()),
                args=[node.value], keywords=[])
            new_target = copy.deepcopy(tgt.value)
            if not isinstance(new_target, ast.Name):
                raise _RewriteError("can only functionalize writes to simple names")
            new_target.ctx = ast.Store()
            return ast.copy_location(
                ast.Assign(targets=[new_target], value=call), node)
        return node


class _RewriteError(Exception):
    pass


def _set_ctx_load(node: ast.AST) -> None:
    for n in ast.walk(node):
        if hasattr(n, "ctx"):
            n.ctx = ast.Load()


# ---------------------------------------------------------------------------
# program model
# ---------------------------------------------------------------------------


@dataclass
class _Node:
    """Executor tree node: plain statements or a (potentially offloadable) loop."""
    kind: str                       # "stmt" | "loop"
    region: Optional[str]           # region name for loops
    stmts: list = field(default_factory=list)   # ast stmts ("stmt" nodes)
    loop: Optional[ast.For] = None
    body: list = field(default_factory=list)    # child _Nodes ("loop" nodes)


class PyProgram:
    """A parsed numeric Python function, ready for offload search."""

    def __init__(self, fn: Callable | str, name: str = "",
                 consts: Optional[dict] = None):
        src = fn if isinstance(fn, str) else textwrap.dedent(inspect.getsource(fn))
        self.source = src
        tree = ast.parse(src)
        fdefs = [n for n in tree.body if isinstance(n, ast.FunctionDef)]
        assert fdefs, "source must contain a function definition"
        self.fdef: ast.FunctionDef = fdefs[0]
        self.name = name or self.fdef.name
        self.arg_names = [a.arg for a in self.fdef.args.args]
        self.consts = dict(consts or {})
        self.output_names: list[str] = []
        body = self._strip_returns(self.fdef.body)
        self._regions: list[Region] = []
        self._counter = 0
        self.tree_nodes = self._build_nodes(body, depth=0, parent=None)
        self._graph = RegionGraph(self._regions, "python_ast", self.name)
        self._compiled_cache: dict[str, Callable] = {}
        # code-object caches shared by all Executors of this program: the GA
        # re-runs the interpreter once per measurement, and re-`compile()`ing
        # every stmt node / loop-iter expression dominated interp time
        self._stmt_code: dict[str, Any] = {}
        self._iter_code: dict[str, Any] = {}

    def stmt_code(self, node: "_Node"):
        code = self._stmt_code.get(node.region)
        if code is None:
            code = compile(ast.Module(body=node.stmts, type_ignores=[]),
                           f"<interp:{node.region}>", "exec")
            self._stmt_code[node.region] = code
        return code

    def iter_code(self, node: "_Node"):
        code = self._iter_code.get(node.region)
        if code is None:
            code = compile(ast.Expression(node.loop.iter),
                           f"<it:{node.region}>", "eval")
            self._iter_code[node.region] = code
        return code

    def _strip_returns(self, stmts: list) -> list:
        out = []
        for s in stmts:
            if isinstance(s, ast.Return):
                v = s.value
                if isinstance(v, ast.Tuple):
                    self.output_names = [e.id for e in v.elts if isinstance(e, ast.Name)]
                elif isinstance(v, ast.Name):
                    self.output_names = [v.id]
                continue
            out.append(s)
        return out

    # --- region extraction ---------------------------------------------------
    def _build_nodes(self, stmts: list, depth: int, parent: Optional[str]) -> list:
        nodes: list[_Node] = []
        pending: list = []

        def flush():
            nonlocal pending
            if pending:
                name = f"stmt_{self._counter}"
                self._counter += 1
                d, u = set(), set()
                for s in pending:
                    dd, uu = _defs_uses(s)
                    d |= dd
                    u |= uu
                self._regions.append(Region(
                    name=name, kind="stmt", depth=depth, parent=parent,
                    defs=frozenset(d), uses=frozenset(u),
                    callees=tuple(c for s in pending for c in _callees(s)),
                    feature_vector={}, offloadable=False))
                nodes.append(_Node("stmt", name, stmts=list(pending)))
                pending = []

        for s in stmts:
            if isinstance(s, ast.For):
                flush()
                rname = f"loop_{self._counter}"
                self._counter += 1
                d, u = _defs_uses(s)
                region = Region(
                    name=rname, kind="loop", depth=depth, parent=parent,
                    defs=frozenset(d), uses=frozenset(u),
                    callees=_callees(s),
                    feature_vector=sim.ast_vector(s),
                    offloadable=False,  # set by check_offloadable()
                    alternatives=("interp", "jit"),
                    trip_count=_static_trip_count(s, self.consts))
                self._regions.append(region)
                node = _Node("loop", rname, loop=s)
                node.body = self._build_nodes(s.body, depth + 1, rname)
                nodes.append(node)
            else:
                pending.append(s)
        flush()
        return nodes

    @property
    def graph(self) -> RegionGraph:
        return self._graph

    # --- offload feasibility (paper: failing loops leave the gene) -----------
    def check_offloadable(self, example_inputs: dict) -> list[str]:
        """Interpret the program once to snapshot the live environment at each
        loop entry, then try to compile each loop against its snapshot; loops
        that error are excluded from the gene (paper §4.2.2)."""
        snaps: dict[str, dict] = {}
        ex = Executor(self, {}, hoist_transfers=False)
        ex.pre_loop_hook = lambda name, env: snaps.setdefault(name, dict(env))
        ex.run(**example_inputs)
        ok = []
        for r in self._graph.loops():
            env = snaps.get(r.name)
            if env is None:
                r.offloadable = False
                r.meta["offload_error"] = "loop never entered during calibration"
                continue
            try:
                node = self._find_loop(r.name)
                fn, live_in, _ = self._compile_loop(node, env)
                args = [jnp.asarray(env[v]) for v in live_in]
                jax.eval_shape(fn, *args)
                r.offloadable = True
                ok.append(r.name)
            except Exception as e:  # noqa: BLE001 — any failure disqualifies
                r.offloadable = False
                r.meta["offload_error"] = f"{type(e).__name__}: {e}"[:200]
        return ok

    def _find_loop(self, name: str, nodes: Optional[list] = None) -> _Node:
        for n in (nodes if nodes is not None else self.tree_nodes):
            if n.kind == "loop":
                if n.region == name:
                    return n
                try:
                    return self._find_loop(name, n.body)
                except KeyError:
                    pass
        raise KeyError(name)

    # --- loop compilation ------------------------------------------------------
    @staticmethod
    def _range_names(loop: ast.For) -> set:
        """Names used inside range(...) calls anywhere in the loop subtree —
        these must stay static (Python ints) so trip counts are concrete."""
        names: set = set()
        for n in ast.walk(loop):
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) \
                    and n.func.id in ("range", "len"):
                for a in n.args:
                    for nn in ast.walk(a):
                        if isinstance(nn, ast.Name):
                            names.add(nn.id)
        return names

    @staticmethod
    def _loop_targets(loop: ast.For) -> set:
        tgts: set = set()
        for n in ast.walk(loop):
            if isinstance(n, ast.For) and isinstance(n.target, ast.Name):
                tgts.add(n.target.id)
        return tgts

    def _compile_loop(self, node: _Node, env: dict) -> tuple[Callable, list, list]:
        """Build + jit a function for one loop.  Returns (fn, live_in, live_out).

        Arrays and non-range scalars become traced args; range/len bounds
        become closure constants (static trip counts — the OpenACC "kernels"
        region analogue).  Variables created inside the loop and assigned by
        it are returned alongside the rewritten in-place updates.
        """
        region = self._graph.by_name(node.region)
        key = node.region
        loop_src = ast.unparse(node.loop)
        static_names = self._range_names(node.loop)
        targets = self._loop_targets(node.loop)

        static: dict = {}
        live_in: list[str] = []
        for v in sorted((region.uses | region.defs) - targets):
            if v in static_names:
                val = env.get(v, self.consts.get(v))
                if not isinstance(val, (int, np.integer)):
                    raise _RewriteError(f"range bound '{v}' is not a static int")
                static[v] = int(val)
            elif v in env and isinstance(
                    env[v], (np.ndarray, jax.Array, int, float, bool, np.number)):
                live_in.append(v)
        live_out = sorted((region.defs - targets) - set(static))
        cache_key = (f"{key}:{hash(loop_src)}:{tuple(sorted(static.items()))}"
                     f":{tuple(live_in)}:{tuple(live_out)}")
        if cache_key in self._compiled_cache:
            return self._compiled_cache[cache_key], live_in, live_out

        rewritten = _JaxRewriter().visit(ast.parse(loop_src))
        ast.fix_missing_locations(rewritten)
        body_src = textwrap.indent(ast.unparse(rewritten), "    ")
        fn_src = (f"def _offload({', '.join(live_in)}):\n"
                  f"{body_src}\n"
                  f"    return ({', '.join(live_out)}{',' if len(live_out) == 1 else ''})\n")
        glb: dict = {"jnp": jnp, "jax": jax, "range": range, "len": len,
                     "min": min, "max": max, "abs": abs, "float": float,
                     "int": int, "enumerate": enumerate, "zip": zip}
        glb.update(static)
        glb.update({k: v for k, v in self.consts.items()
                    if k not in live_in and k not in glb})
        loc: dict = {}
        exec(compile(ast.parse(fn_src), f"<offload:{key}>", "exec"), glb, loc)  # noqa: S102
        fn = jax.jit(loc["_offload"])
        self._compiled_cache[cache_key] = fn
        return fn, live_in, live_out


# ---------------------------------------------------------------------------
# executor with transfer accounting
# ---------------------------------------------------------------------------


@dataclass
class ExecStats:
    h2d: int = 0
    d2h: int = 0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    jit_calls: int = 0
    interp_loops: int = 0


class Executor:
    """Runs a PyProgram under an offload pattern with transfer accounting.

    ``hoist_transfers=True`` enables the paper's batched-transfer
    optimization: device copies of host arrays are cached and only
    re-uploaded when the host version changes (so a loop-invariant array
    transfers once instead of once per iteration).
    """

    def __init__(self, program: PyProgram, impl: dict[str, str],
                 hoist_transfers: bool = True,
                 globals_env: Optional[dict] = None,
                 lib_calls: Optional[dict] = None,
                 block_sites: Optional[dict] = None):
        self.p = program
        self.impl = impl
        # region -> {impl id: (callable, in_names, out_names)}: the variant
        # menu of library implementations for a matched block.  The legacy
        # single-implementation form (region -> triple) normalizes to a
        # one-entry menu under the historical "lib" id.
        self.lib_calls: dict[str, dict] = {}
        for region, entry in (lib_calls or {}).items():
            self.lib_calls[region] = dict(entry) if isinstance(entry, dict) \
                else {"lib": entry}
        # function-block sites: synthetic region -> its member node regions
        # (adjacent sibling loops).  When the block's gene selects a bound
        # menu variant, the lib call runs once at the first member and the
        # remaining member nodes are skipped — the whole run is replaced.
        self.block_sites: dict[str, tuple] = dict(block_sites or {})
        self._block_first: dict[str, str] = {
            members[0]: bname for bname, members in self.block_sites.items()
            if members}
        self.hoist = hoist_transfers
        self.stats = ExecStats()
        self.globals = {"np": np, "math": __import__("math"),
                        "range": range, "len": len, "min": min, "max": max,
                        "abs": abs, "float": float, "int": int,
                        "enumerate": enumerate, "zip": zip}
        if globals_env:
            self.globals.update(globals_env)
        self._dev_cache: dict[str, tuple[int, Any]] = {}
        self._ver: dict[str, int] = {}
        self.pre_loop_hook: Optional[Callable[[str, dict], None]] = None

    # --- transfers -------------------------------------------------------------
    def _to_device(self, name: str, env: dict):
        v = env[name]
        if isinstance(v, jax.Array):
            return v
        ver = self._ver.get(name, 0)
        if self.hoist and name in self._dev_cache:
            cver, cval = self._dev_cache[name]
            if cver == ver:
                return cval
        dv = jnp.asarray(v)
        self.stats.h2d += 1
        self.stats.h2d_bytes += getattr(v, "nbytes", 8)
        self._dev_cache[name] = (ver, dv)
        return dv

    def _to_host(self, name: str, env: dict):
        v = env[name]
        if isinstance(v, jax.Array):
            hv = np.asarray(v)
            self.stats.d2h += 1
            self.stats.d2h_bytes += hv.nbytes
            env[name] = hv
            self._ver[name] = self._ver.get(name, 0)  # same logical version
            self._dev_cache[name] = (self._ver.get(name, 0), v)
            return hv
        return v

    def _bump(self, names) -> None:
        for n in names:
            self._ver[n] = self._ver.get(n, 0) + 1
            self._dev_cache.pop(n, None) if not self.hoist else None

    # --- execution ------------------------------------------------------------
    def run(self, **inputs) -> dict:
        env = dict(self.p.consts)
        # interpreted statements write arrays IN PLACE (a[i] = v); copy array
        # inputs so repeated measurement runs (and the calibration run before
        # them) start from identical state instead of each other's leftovers
        env.update({k: v.copy() if isinstance(v, np.ndarray) else v
                    for k, v in inputs.items()})
        for name in list(env):
            self._ver[name] = 0
        self._exec_nodes(self.p.tree_nodes, env)
        return env

    def _exec_nodes(self, nodes: list, env: dict) -> None:
        skip: set = set()
        for node in nodes:
            if node.region in skip:
                continue
            blk = self._block_first.get(node.region)
            if blk is not None:
                menu = self.lib_calls.get(blk)
                chosen = self.impl.get(blk)
                if menu and chosen in menu:
                    # active function-block gene: the library implementation
                    # computes the whole run; member nodes are claimed
                    self._exec_lib(node, env, menu[chosen])
                    skip.update(self.block_sites[blk])
                    continue
            if node.kind == "stmt":
                self._exec_stmts(node, env)
            else:
                if self.pre_loop_hook is not None:
                    self.pre_loop_hook(node.region, env)
                menu = self.lib_calls.get(node.region)
                chosen = self.impl.get(node.region)
                if menu and chosen in menu:
                    self._exec_lib(node, env, menu[chosen])
                    continue
                region = self.p.graph.by_name(node.region)
                offload = region.offloadable and self.impl.get(node.region) == "jit"
                if offload:
                    self._exec_offloaded(node, env)
                else:
                    # includes the fallback for a variant that did not bind:
                    # the reference interpreter is the ast "ref" path
                    self._exec_interp_loop(node, env)

    def _exec_stmts(self, node: _Node, env: dict) -> None:
        region = self.p.graph.by_name(node.region)
        for v in region.uses:
            if v in env:
                self._to_host(v, env)
        # fresh namespace per exec: a shared one would leak bindings across
        # regions (stale names resolving instead of NameError) and change
        # the reference interpreter's semantics
        g = dict(self.globals)
        g.update(env)
        exec(self.p.stmt_code(node), g)  # noqa: S102
        for v in region.defs | region.uses:
            if v in g:
                env[v] = g[v]
        self._bump(region.defs)

    def _exec_offloaded(self, node: _Node, env: dict) -> None:
        fn, live_in, live_out = self.p._compile_loop(node, env)
        args = [self._to_device(v, env) for v in live_in]
        outs = fn(*args)
        self.stats.jit_calls += 1
        for v, o in zip(live_out, outs):
            env[v] = o
            self._ver[v] = self._ver.get(v, 0) + 1
            self._dev_cache[v] = (self._ver[v], o)

    def _exec_lib(self, node: _Node, env: dict, entry: tuple) -> None:
        """Function-block offload: run a device-tuned library implementation
        in place of the matched block (paper §4.2.1)."""
        fn, in_names, out_names = entry
        args = [self._to_device(v, env) for v in in_names]
        outs = fn(*args)
        self.stats.jit_calls += 1
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for v, o in zip(out_names, outs):
            env[v] = o
            self._ver[v] = self._ver.get(v, 0) + 1
            self._dev_cache[v] = (self._ver[v], o)

    def _exec_interp_loop(self, node: _Node, env: dict) -> None:
        self.stats.interp_loops += 1
        region = self.p.graph.by_name(node.region)
        loop = node.loop
        for v in region.uses:
            if v in env and not any(
                    ch.kind == "loop" and self.impl.get(ch.region) == "jit"
                    for ch in node.body):
                self._to_host(v, env)
        g = dict(self.globals)
        g.update(env)
        iter_vals = eval(self.p.iter_code(node), g)  # noqa: S307
        tname = loop.target.id if isinstance(loop.target, ast.Name) else None
        for val in iter_vals:
            if tname:
                env[tname] = val
            self._exec_nodes(node.body, env)

    def outputs(self, env: dict, names: list) -> dict:
        out = {}
        for n in names:
            v = env[n]
            out[n] = np.asarray(v)
        return out


# ---------------------------------------------------------------------------
# library-call adapters ("CUDA library" substitution, paper §4.2.1)
# ---------------------------------------------------------------------------


def _order_by_appearance(names, source: str) -> list:
    return sorted(names, key=lambda v: source.find(v) if v in source else 1 << 30)


def _adapt_matmul(region, env, source):
    arrays_in = [v for v in region.uses - region.defs
                 if isinstance(env.get(v), np.ndarray) and env[v].ndim == 2]
    outs = [v for v in region.defs
            if isinstance(env.get(v), np.ndarray) and env[v].ndim == 2]
    arrays_in = _order_by_appearance(arrays_in, source)
    if len(arrays_in) != 2 or len(outs) != 1:
        raise ValueError("matmul adapter needs exactly (a, b) -> c")
    return (lambda a, b: jnp.matmul(a, b)), arrays_in, outs


def _adapt_fft(region, env, source):
    ins = _order_by_appearance(
        [v for v in region.uses - region.defs
         if isinstance(env.get(v), np.ndarray)], source)
    outs = _order_by_appearance(
        [v for v in region.defs if isinstance(env.get(v), np.ndarray)], source)
    if len(ins) == 2 and len(outs) == 2:    # (re, im) -> (re, im): adapt complex
        def fft2ri(re, im):
            z = jnp.fft.fft(re + 1j * im)
            return jnp.real(z), jnp.imag(z)
        return fft2ri, ins, outs
    if len(ins) == 1 and len(outs) == 1:
        return (lambda x: jnp.abs(jnp.fft.fft(x))), ins, outs
    raise ValueError("fft adapter: unsupported interface")


_AST_ADAPTERS: dict[str, Callable] = {
    "matmul": _adapt_matmul,
    "fft": _adapt_fft,
}


# ---------------------------------------------------------------------------
# registry-variant lib-call sites (kernel substitution for the ast path)
# ---------------------------------------------------------------------------
#
# A matched loop nest concretizes to the same CallSite the jaxpr engine
# binds variants against: role inference maps the region's live arrays onto
# the pattern's signature — structurally where the loop AST proves the role
# (q is the array rows-indexed by the outer loop variable, log_a the scan
# input inside exp(...)), by in-loop appearance order otherwise — the
# interface-matching step the paper's library substitution performs.  The
# environment snapshot supplies the abstract values, and the shared
# resolution rule (repro.core.variants.resolve_variant) applies every
# variant's availability predicate.  A bound variant becomes one entry of
# the region's lib-call menu; anything role inference or the avals cannot
# prove (a mis-assigned operand, a non-causal attention loop against the
# causal kernels) is caught by the per-measurement verifier, the paper's
# PCAST flow — the chromosome measures invalid and the site stays on its
# reference path.


def _walk_program_order(node: ast.AST):
    """DFS pre-order (ast.walk is BFS, which scrambles appearance order)."""
    yield node
    for child in ast.iter_child_nodes(node):
        yield from _walk_program_order(child)


def _loop_order(loop: ast.AST, names) -> list:
    """`names` by first occurrence as a Name node inside the loop subtree —
    token-exact, unlike substring search over the source."""
    pos: dict[str, int] = {}
    for i, n in enumerate(_walk_program_order(loop)):
        if isinstance(n, ast.Name) and n.id in names and n.id not in pos:
            pos[n.id] = i
    return sorted(names, key=lambda v: pos.get(v, 1 << 30))


def _sub_base(node: ast.AST) -> Optional[str]:
    while isinstance(node, ast.Subscript):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _first_index_names(loop: ast.AST, arr: str) -> set:
    """Name ids used as `arr`'s leading subscript index inside the loop."""
    out: set = set()
    for n in ast.walk(loop):
        if isinstance(n, ast.Subscript) and isinstance(n.value, ast.Name) \
                and n.value.id == arr and isinstance(n.slice, ast.Name):
            out.add(n.slice.id)
    return out


def _mult_partners(loop: ast.AST, arr: str) -> set:
    """Arrays that share a product (BinOp Mult subtree) with `arr`."""
    partners: set = set()
    for n in ast.walk(loop):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mult):
            arrs = {_sub_base(m) for m in ast.walk(n)
                    if isinstance(m, ast.Subscript)}
            if arr in arrs:
                partners |= arrs - {arr, None}
    return partners


def _used_inside_exp(loop: ast.AST, arr: str) -> bool:
    for n in ast.walk(loop):
        if isinstance(n, ast.Call):
            fname = n.func.attr if isinstance(n.func, ast.Attribute) \
                else getattr(n.func, "id", "")
            if fname == "exp" and any(
                    _sub_base(m) == arr for a in n.args
                    for m in ast.walk(a) if isinstance(m, ast.Subscript)):
                return True
    return False


def _snapshot_arrays(region, node: "_Node", env: dict, *, read_only: bool,
                     ndim: int) -> list:
    pool = (region.uses - region.defs) if read_only else region.defs
    names = [v for v in pool
             if isinstance(env.get(v), (np.ndarray, jax.Array))
             and env[v].ndim == ndim]
    return _loop_order(node.loop, names)


def _aval_of(value) -> jax.ShapeDtypeStruct:
    # canonicalize: interpreted numpy defaults to float64, which jax (x64
    # disabled) would silently demote mid-trace and fail the output check
    return jax.ShapeDtypeStruct(
        np.shape(value), jax.dtypes.canonicalize_dtype(value.dtype))


def _site_attention(region, node, env):
    from repro.kernels.registry import VariantUnavailable
    ins = _snapshot_arrays(region, node, env, read_only=True, ndim=2)
    outs = _snapshot_arrays(region, node, env, read_only=False, ndim=2)
    if len(ins) != 3 or len(outs) != 1:
        raise VariantUnavailable(
            f"attention site needs (q, k, v) -> out arrays, found "
            f"{len(ins)} in / {len(outs)} out")
    # structural roles: q is rows-indexed by the outer loop variable only;
    # k shares the score product with q; v is the remaining operand.  An
    # attention loop the structure cannot prove keeps appearance order
    # (the verifier rejects a wrong assignment at measurement time).
    loop = node.loop
    outer = loop.target.id if isinstance(loop.target, ast.Name) else None
    if outer is not None:
        qs = [a for a in ins if _first_index_names(loop, a) == {outer}]
        rest = [a for a in ins if a not in qs]
        if len(qs) == 1 and len(rest) == 2:
            ks = [a for a in rest if qs[0] in _mult_partners(loop, a)]
            if len(ks) == 1:
                ins = [qs[0], ks[0],
                       rest[0] if rest[1] == ks[0] else rest[1]]
    return ins, outs, "call", {}


def _site_rmsnorm(region, node, env):
    from repro.kernels.registry import VariantUnavailable
    xs = _snapshot_arrays(region, node, env, read_only=True, ndim=2)
    scales = _snapshot_arrays(region, node, env, read_only=True, ndim=1)
    outs = _snapshot_arrays(region, node, env, read_only=False, ndim=2)
    if len(xs) != 1 or len(scales) != 1 or len(outs) != 1:
        raise VariantUnavailable(
            f"rmsnorm site needs (x, scale) -> out arrays, found "
            f"{len(xs)}/{len(scales)} in / {len(outs)} out")
    return [xs[0], scales[0]], outs, "call", {}


def _site_recurrence(region, node, env):
    from repro.kernels.registry import VariantUnavailable
    xs = _snapshot_arrays(region, node, env, read_only=True, ndim=2)
    carries = [v for v in region.uses & region.defs
               if isinstance(env.get(v), (np.ndarray, jax.Array))
               and env[v].ndim == 1]
    ys = [v for v in _snapshot_arrays(region, node, env, read_only=False,
                                      ndim=2) if v not in xs]
    if len(xs) != 2 or len(carries) != 1 or len(ys) != 1:
        raise VariantUnavailable(
            f"recurrence site needs carry + (log_a, b) -> ys, found "
            f"xs={len(xs)} carry={len(carries)} ys={len(ys)}")
    # structural roles: log_a is the xs operand inside exp(...) — the decay
    # coefficient of h = exp(log_a) * h + b; appearance order otherwise
    in_exp = [a for a in xs if _used_inside_exp(node.loop, a)]
    if len(in_exp) == 1:
        xs = [in_exp[0], xs[0] if xs[1] == in_exp[0] else xs[1]]
    h = carries[0]
    # scan-site signature: inputs (carry, xs...), outputs (carry, ys);
    # the adapters serve the final carry from ys[-1]
    params = {"num_consts": 0, "num_carry": 1,
              "length": int(env[xs[0]].shape[0]), "reverse": False}
    return [h] + xs, [h, ys[0]], "scan", params


#: pattern -> (region, env, source) -> (in_names, out_names, kind, params)
_VARIANT_SITE_BUILDERS: dict[str, Callable] = {
    "softmax_attention": _site_attention,
    "rmsnorm": _site_rmsnorm,
    "linear_recurrence": _site_recurrence,
}


def resolve_lib_variants(region, pattern: str, env: dict,
                         program: "PyProgram",
                         registry=None, backend: Optional[str] = None
                         ) -> tuple[dict, dict]:
    """Bind every registry variant of ``pattern`` against the region.

    Returns ``(menu, fallbacks)``: ``menu`` maps bound variant names to
    executor lib-call entries ``(callable, in_names, out_names)``,
    ``fallbacks`` maps refused names to the predicate's reason — exactly
    the record the jaxpr engine keeps, so both frontends report
    substitution the same way.
    """
    from repro.core.variants import resolve_variant
    from repro.kernels.registry import (CallSite, VariantUnavailable,
                                        default_registry)

    registry = registry or default_registry()
    backend = backend or jax.default_backend()
    builder = _VARIANT_SITE_BUILDERS.get(pattern)
    if builder is None:
        return {}, {"site": f"no ast site builder for pattern {pattern!r}"}
    try:
        node = program._find_loop(region.name)
        in_names, out_names, kind, params = builder(region, node, env)
    except (VariantUnavailable, KeyError) as e:
        return {}, {"site": str(e)}
    site = CallSite(
        pattern=pattern, kind=kind,
        in_avals=tuple(_aval_of(env[v]) for v in in_names),
        out_avals=tuple(_aval_of(env[v]) for v in out_names),
        out_used=(True,) * len(out_names),
        params=params, backend=backend)
    menu: dict[str, tuple] = {}
    fallbacks: dict[str, str] = {}
    for name in registry.variant_names(pattern):
        adapter, chosen, why = resolve_variant(site, name, registry=registry,
                                               backend=backend)
        if adapter is not None:
            menu[chosen] = (adapter, list(in_names), list(out_names))
        else:
            fallbacks[name] = why
    return menu, fallbacks


# ---------------------------------------------------------------------------
# function-block sites (whole-run substitution, arXiv 2004.09883)
# ---------------------------------------------------------------------------
#
# The ast twin of the jaxpr frontend's ``annotate_block_sites``: maximal
# runs of *adjacent sibling loops* are matched — merged feature vectors,
# merged callees — against the pattern DB's ``block`` records.  An accepted
# run appends one synthetic ``fnblock_*`` region to the graph: an extra
# gene whose accelerated alternatives are the registry's block-level
# variants, and whose activation claims the member loops (decode-level,
# :class:`repro.core.genes.Site`), shrinking the loop-level search to the
# unclaimed remainder.  Role inference here orders the operands
# positionally for the shared binders (a block CallSite without equations).


def _block_site_attention_stack(members, nodes, program, env, live_after):
    """Order a merged run's arrays as (x, scale, wq, wk, wv) -> (out,)."""
    from repro.kernels.registry import VariantUnavailable

    all_defs = set().union(*(set(r.defs) for r in members))
    free = set().union(*(set(r.uses) for r in members)) - all_defs
    module = ast.Module(body=[n.loop for n in nodes], type_ignores=[])

    def arrays(names, ndim):
        picked = [v for v in names
                  if isinstance(env.get(v), (np.ndarray, jax.Array))
                  and env[v].ndim == ndim]
        return _loop_order(module, picked)

    scales = arrays(free, 1)
    mats = arrays(free, 2)
    if len(scales) != 1 or len(mats) != 4:
        raise VariantUnavailable(
            f"attention-stack block needs (x, scale, wq, wk, wv) free "
            f"inputs, found rank1={len(scales)} rank2={len(mats)}")
    xs = [v for v in mats if v in members[0].uses]
    if len(xs) != 1:
        raise VariantUnavailable(
            "cannot identify the residual-stream input of the block")
    outs = arrays(all_defs & live_after, 2)
    if len(outs) != 1:
        raise VariantUnavailable(
            f"attention-stack block must produce one surviving array, "
            f"found {outs}")
    return [xs[0], scales[0]] + [v for v in mats if v != xs[0]], outs


#: block pattern -> (members, nodes, program, env, live_after)
#:              -> (ordered in_names, out_names)
_BLOCK_SITE_BUILDERS: dict[str, Callable] = {
    "attention_stack": _block_site_attention_stack,
}


def _live_after(program: "PyProgram", members) -> set:
    """Names that survive the block: program outputs plus anything read by
    a region outside the run (regions before it cannot read its writes, so
    the over-approximation is harmless)."""
    g = program.graph
    mem = {r.name for r in members}

    def inside(r) -> bool:
        if r.name in mem:
            return True
        p = r.parent
        while p is not None:
            if p in mem:
                return True
            p = g.by_name(p).parent
        return False

    live = set(program.output_names)
    for r in g.regions:
        if not inside(r):
            live |= r.uses
    return live


def resolve_block_sites(program: "PyProgram", db, snaps: dict,
                        registry=None, backend: Optional[str] = None,
                        log: Optional[Callable] = None) -> dict:
    """Detect function-block sites and wire them for the executor.

    Returns ``{fnblock name: {"menu": variant menu, "fallbacks": reasons,
    "members": top-level member regions, "claims": members + descendants}}``
    and appends the synthetic regions to ``program.graph``.  Windows are
    tried widest-first, accepted greedily non-overlapping, and kept only
    when at least one registry variant binds the concrete avals.
    """
    from repro.core.variants import resolve_variant
    from repro.kernels.registry import (CallSite, VariantUnavailable,
                                        default_registry)

    registry = registry or default_registry()
    backend = backend or jax.default_backend()
    log = log or (lambda s: None)
    graph = program.graph

    runs: list[list] = []
    cur: list = []
    for node in program.tree_nodes:
        if node.kind == "loop":
            cur.append(node)
        else:
            if len(cur) >= 2:
                runs.append(cur)
            cur = []
    if len(cur) >= 2:
        runs.append(cur)

    sites: dict[str, dict] = {}
    taken: set = set()
    for run in runs:
        for width in range(len(run), 1, -1):
            for lo in range(len(run) - width + 1):
                nodes = run[lo:lo + width]
                if any(id(n) in taken for n in nodes):
                    continue
                members = [graph.by_name(n.region) for n in nodes]
                m = db.match_block(members, graph.frontend)
                if m is None:
                    continue
                names = registry.variant_names(m.record.name)
                builder = _BLOCK_SITE_BUILDERS.get(m.record.name)
                if not names or builder is None:
                    continue
                env = snaps.get(nodes[0].region)
                if env is None:
                    continue
                try:
                    in_names, out_names = builder(
                        members, nodes, program, env,
                        _live_after(program, members))
                except VariantUnavailable as e:
                    log(f"block run {[r.name for r in members]}: {e}")
                    continue
                site = CallSite(
                    pattern=m.record.name, kind="block",
                    in_avals=tuple(_aval_of(env[v]) for v in in_names),
                    out_avals=tuple(_aval_of(env[v]) for v in out_names),
                    out_used=(True,) * len(out_names), params={},
                    backend=backend)
                menu: dict[str, tuple] = {}
                fails: dict[str, str] = {}
                for nm in names:
                    adapter, chosen, why = resolve_variant(
                        site, nm, registry=registry, backend=backend)
                    if adapter is not None:
                        menu[chosen] = (adapter, list(in_names),
                                        list(out_names))
                    else:
                        fails[nm] = why
                if not menu:
                    log(f"block run {[r.name for r in members]} "
                        f"({m.record.name}): no variant bound: {fails}")
                    continue
                claims = tuple(r.name for r in graph.regions
                               if r in members
                               or _parent_chain_hits(graph, r,
                                                     {x.name for x
                                                      in members}))
                vec: dict = {}
                for r in members:
                    for k, c in r.feature_vector.items():
                        vec[k] = vec.get(k, 0) + c
                bname = f"fnblock_{len(sites)}"
                graph.regions.append(Region(
                    name=bname, kind="block", depth=0, parent=None,
                    defs=frozenset(), uses=frozenset(),
                    callees=tuple(dict.fromkeys(
                        c for r in members for c in r.callees)),
                    feature_vector=vec, offloadable=True,
                    alternatives=("interp",) + tuple(
                        n for n in names if n in menu),
                    meta={"pattern": m.record.name,
                          "pattern_match": {"how": m.how,
                                            "score": round(m.score, 4)},
                          "block_members": claims}))
                sites[bname] = {"menu": menu, "fallbacks": fails,
                                "members": tuple(r.name for r in members),
                                "claims": claims}
                taken.update(id(n) for n in nodes)
    return sites


def _parent_chain_hits(graph, region, names: set) -> bool:
    p = region.parent
    while p is not None:
        if p in names:
            return True
        p = graph.by_name(p).parent
    return False


# ---------------------------------------------------------------------------
# the Frontend adapter (repro.core.frontends.registry protocol)
# ---------------------------------------------------------------------------


@dataclass
class PyOffloadArtifact:
    """The python frontend's deliverable: a program bound to its plan."""

    program: PyProgram
    impl: dict                       # region -> implementation id
    lib_calls: dict                  # region -> variant menu (or the legacy
                                     # (callable, in_names, out_names) triple)
    hoist_transfers: bool = True
    report: Optional[Any] = None     # SubstitutionReport: what runs where
                                     # and why the rest fell back
    block_sites: dict = field(default_factory=dict)  # fnblock -> member nodes

    def executor(self) -> Executor:
        return Executor(self.program, self.impl,
                        hoist_transfers=self.hoist_transfers,
                        lib_calls=self.lib_calls,
                        block_sites=self.block_sites)

    def run(self, **inputs) -> dict:
        """Execute under the planned pattern; returns the output arrays."""
        env = self.executor().run(**inputs)
        names = self.program.output_names or sorted(
            v for v in env if isinstance(env[v], np.ndarray))
        return {n: np.asarray(env[n]) for n in names}


class AstFrontend:
    """Python-source frontend for the unified pipeline: parse with ``ast``,
    measure with the interpreting Executor (wall clock, PCAST-style
    verification), substitute device libraries for matched blocks.

    Matched blocks with kernel-registry variants stay in the gene and the
    GA selects the implementation (``VARIANT_ALPHABET`` proposed via
    ``FitnessBundle.destinations``); blocks with a single library adapter
    (matmul, fft) keep the legacy measured-combination claim."""

    name = "python_ast"

    def normalize_target(self, target, inputs, config) -> PyProgram:
        if isinstance(target, PyProgram):
            return target
        return PyProgram(target, consts=config.options.get("consts"))

    def build_graph(self, target: PyProgram, inputs, config):
        if inputs:
            # interpret once against real inputs; loops that fail to compile
            # under the offload rewrite leave the gene (paper §4.2.2)
            target.check_offloadable(inputs)
        return target.graph

    def make_fitness(self, graph, program: PyProgram, inputs, config):
        import hashlib
        import os
        import platform

        from repro.core.block_offload import block_offload_pass
        from repro.core.fitness import WallClockFitness
        from repro.core.frontends.registry import FitnessBundle
        from repro.core.pattern_db import default_db

        db = config.db or default_db()
        log = config.log or (lambda s: None)
        inputs = inputs or {}

        # --- calibration: interpret once; snapshots + reference outputs ----
        snaps: dict[str, dict] = {}
        ex0 = Executor(program, {}, hoist_transfers=False)
        ex0.pre_loop_hook = lambda name, env: snaps.setdefault(name, dict(env))
        env0 = ex0.run(**inputs)
        out_names = program.output_names or sorted(
            v for v in env0 if isinstance(env0[v], (np.ndarray,)))
        reference = {n: np.asarray(env0[n]) for n in out_names}

        # fnblock -> member node regions, filled by block detection below
        # (runner closes over it; the dict is updated in place)
        block_members: dict[str, tuple] = {}

        def runner(impl: dict, lib_calls: dict) -> Callable[[], dict]:
            def run():
                ex = Executor(program, impl,
                              hoist_transfers=config.hoist_transfers,
                              lib_calls=lib_calls,
                              block_sites=block_members)
                env = ex.run(**inputs)
                return {n: np.asarray(env[n]) for n in out_names}
            return run

        # one fitness instance for the whole planning run; `build` reads the
        # measurement spec staged by `timed` / the GA fitness below
        _spec: dict = {"impl": {}, "lib": {}}
        wall_fit = WallClockFitness(
            build=lambda bits: runner(_spec["impl"], _spec["lib"]),
            reference_output=reference, repeats=config.repeats)

        def timed(impl: dict, lib_calls: dict):
            _spec["impl"], _spec["lib"] = impl, lib_calls
            return wall_fit(())

        baseline = timed({}, {})
        log(f"baseline (all-interpreted): {baseline.time_s:.4f}s")

        # --- function-block offload first (paper §4.2) ---------------------
        block = block_offload_pass(graph=program.graph, db=db,
                                   confirm=config.confirm)

        # registry-variant sites: a matched block whose pattern has
        # executable kernel-registry variants stays IN the gene (exactly
        # like the measured jaxpr path) with the variant menu as its extra
        # implementations — the GA picks which code runs, and the paper's
        # measure-replacements-on/off step becomes part of the search.
        from repro.kernels.registry import default_registry
        registry = config.options.get("registry") or default_registry()
        variant_sites: dict[str, dict] = {}
        variant_fallbacks: dict[str, dict] = {}
        candidates = {}
        for bo in block.offloads:
            envs = snaps.get(bo.region)
            if envs is None:
                continue
            region = program.graph.by_name(bo.region)
            names = registry.variant_names(bo.pattern)
            if names and bo.pattern in _VARIANT_SITE_BUILDERS:
                menu, fails = resolve_lib_variants(
                    region, bo.pattern, envs, program, registry=registry)
                variant_fallbacks[bo.region] = fails
                if menu:
                    variant_sites[bo.region] = menu
                    region.meta["pattern"] = bo.pattern
                    region.meta["pattern_match"] = {"how": bo.how,
                                                    "score": round(bo.score, 4)}
                    # a variant site needs no jit path of its own: the menu
                    # is its accelerated implementation set, the interpreter
                    # its reference — it joins the gene even when the loop
                    # itself failed to compile under the offload rewrite
                    region.offloadable = True
                    region.meta.pop("offload_error", None)
                    # only BOUND variants enter the menu: an unbound name in
                    # the gene would decode to a variant label while running
                    # the interpreter — a second, mislabeled measurement of
                    # the gene-0 phenotype that could win on timing noise
                    region.alternatives = ("interp",) + tuple(
                        n for n in names if n in menu)
                    log(f"block {bo.region} ({bo.pattern}): variants "
                        f"{sorted(menu)} join the gene")
                    continue
                log(f"block {bo.region} ({bo.pattern}): no variant bound: "
                    f"{fails}")
            adapter = _AST_ADAPTERS.get(bo.pattern)
            if adapter is None:
                continue
            try:
                candidates[bo.region] = adapter(region, envs, program.source)
            except ValueError as e:
                log(f"block {bo.region} ({bo.pattern}): adapter failed: {e}")

        # function-block sites: runs of adjacent sibling loops whose merged
        # shape matches a block record join the gene as one synthetic
        # region each; an active block gene claims its members at decode
        # time (repro.core.genes) and at execution time (the Executor runs
        # the lib call once and skips the member nodes)
        block_sites: dict[str, dict] = {}
        if config.options.get("block_sites", True):
            block_sites = resolve_block_sites(
                program, db, snaps, registry=registry, log=log)
            for bname, entry in block_sites.items():
                block_members[bname] = entry["members"]
                log(f"function block {bname} "
                    f"({program.graph.by_name(bname).meta['pattern']}): "
                    f"members={list(entry['members'])} variants "
                    f"{sorted(entry['menu'])} join the gene")

        # measure each block and combinations (paper §4.2.1)
        import itertools
        best_lib: dict = {}
        best_time = baseline.time_s
        keys = list(candidates)
        combos = itertools.chain.from_iterable(
            itertools.combinations(keys, r) for r in range(1, len(keys) + 1)) \
            if len(keys) <= 3 else [tuple(keys)] + [(k,) for k in keys]
        for combo in combos:
            lib = {k: candidates[k] for k in combo}
            impl = {k: "lib" for k in combo}
            ev = timed(impl, lib)
            log(f"block combo {combo}: {ev.time_s:.4f}s valid={ev.valid}")
            if ev.valid and ev.time_s < best_time:
                best_time, best_lib = ev.time_s, lib
        block_impl = {k: "lib" for k in best_lib}

        # claimed regions (and their descendants) leave the gene; a variant
        # site keeps its own gene — the GA picks its implementation — but
        # claims its descendants (the nested loops it replaces wholesale)
        claimed = set(best_lib)
        roots = set(best_lib) | set(variant_sites)
        for r in program.graph.regions:
            p_ = r.parent
            while p_ is not None:
                if p_ in roots:
                    claimed.add(r.name)
                    break
                p_ = program.graph.by_name(p_).parent
        claimed = tuple(sorted(claimed))

        # persistent-cache key context: wall-clock measurements are only
        # comparable for the same source, constants, input shapes AND the
        # same machine — timings are not portable between hosts
        shapes = {k: getattr(v, "shape", ()) for k, v in sorted(inputs.items())}
        block_patterns = sorted((bo.region, bo.pattern) for bo in block.offloads
                                if bo.region in best_lib)
        variants_sig = sorted((r, tuple(sorted(m)))
                              for r, m in variant_sites.items())
        variants_sig += sorted((b, tuple(sorted(e["menu"])))
                               for b, e in block_sites.items())
        cache_extra = (
            f"src={hashlib.sha256(program.source.encode()).hexdigest()[:12]}"
            f"|consts={sorted(program.consts.items())}"
            f"|shapes={sorted(shapes.items())}"
            f"|block={block_patterns}"
            f"|variants={variants_sig}"
            f"|hoist={config.hoist_transfers}|repeats={config.repeats}"
            f"|host={platform.node()}|ncpu={os.cpu_count()}"
            f"|dev={jax.default_backend()}|wallclock")

        # the full lib-call table: legacy single-implementation claims plus
        # the per-region variant menus the genes select from
        lib_all: dict[str, dict] = {k: {"lib": v} for k, v in best_lib.items()}
        lib_all.update(variant_sites)
        lib_all.update({b: e["menu"] for b, e in block_sites.items()})

        def fitness_factory(coding):
            # a WallClockFitness whose build decodes per call (no shared
            # staging state), so the evaluation engine may overlap different
            # chromosomes' warm-up/verify phases ahead of the serial timing
            # loop (two-phase prepare/measure; Executors are per-run)
            def build(values):
                impl = dict(block_impl)
                impl.update(coding.decode(tuple(values)))
                return runner(impl, lib_all)

            return WallClockFitness(build, reference_output=reference,
                                    repeats=config.repeats)

        # no impl_resolver: ast bind results are already folded in at the
        # *menu* level — region.alternatives holds only BOUND variants, so
        # the gene decode itself clamps every chromosome into implementations
        # that run (phenotype dedup needs no extra resolution step here)
        from repro.core.genes import VARIANT_ALPHABET, with_mesh_destinations
        return FitnessBundle(
            fitness_factory=fitness_factory,
            block=block, claimed=claimed, base_impl=block_impl,
            cache_extra=cache_extra, serial_only=True, measured=True,
            # the executor's warm-up/verify pass releases the GIL inside its
            # jitted segments; the adaptive evaluator backs the overlap off
            # on its own when contention eats the estimated saving
            overlap_compiles=True,
            # variant sites make the gene an implementation choice, so the
            # frontend proposes the variant alphabet — plus this host's
            # mesh destinations (cost-modeled: mesh_executed stays False,
            # the interpreter never decodes a gene to shard_map execution);
            # plain programs keep the paper's binary interp/jit gene
            destinations=(with_mesh_destinations(VARIANT_ALPHABET)
                          if variant_sites or block_sites else None),
            context={"program": program, "lib_calls": lib_all,
                     "variant_sites": variant_sites,
                     "variant_fallbacks": variant_fallbacks,
                     "block_sites": block_sites,
                     "baseline": baseline, "block_time_s": best_time,
                     "out_names": out_names,
                     "hoist": config.hoist_transfers})

    def apply_plan(self, graph, coding, values, bundle) -> PyOffloadArtifact:
        from repro.core.frontends.registry import decoded_pattern
        from repro.core.variants import (_REF_IMPLS, SubstitutionChoice,
                                         SubstitutionReport)

        impl = decoded_pattern(coding, values, bundle.base_impl)
        blocks = bundle.context.get("block_sites", {})
        menus = dict(bundle.context.get("variant_sites", {}))
        menus.update({b: e["menu"] for b, e in blocks.items()})
        fails = dict(bundle.context.get("variant_fallbacks", {}))
        fails.update({b: e["fallbacks"] for b, e in blocks.items()})
        report = SubstitutionReport()
        for s in coding.sites:
            region = s.region
            req = str(impl.get(region, s.ref_impl))
            pattern = graph.by_name(region).meta.get("pattern")
            if req in _REF_IMPLS:
                report.choices.append(SubstitutionChoice(
                    region, pattern, "ref", "ref", "requested"))
            elif region in menus and req in menus[region]:
                report.choices.append(SubstitutionChoice(
                    region, pattern, req, req, ""))
            elif region in menus:
                why = fails.get(region, {}).get(
                    req, f"variant {req!r} did not bind")
                report.choices.append(SubstitutionChoice(
                    region, pattern, req, "ref", why))
            else:                        # the paper's plain jit offload path
                report.choices.append(SubstitutionChoice(
                    region, pattern, req, req, ""))
        for region in sorted(bundle.base_impl):
            report.choices.append(SubstitutionChoice(
                region, graph.by_name(region).meta.get("pattern"),
                "lib", "lib", "block-pass claim"))
        bundle.context["substitution_report"] = report
        return PyOffloadArtifact(
            program=bundle.context["program"], impl=impl,
            lib_calls=bundle.context["lib_calls"],
            hoist_transfers=bundle.context.get("hoist", True),
            report=report,
            block_sites={b: e["members"] for b, e in blocks.items()})
