"""Genetic-algorithm engine for offload-pattern search (paper §3.2.1, §4.2.2).

Faithful to the paper's loop:
  * initial population: random 0/1 chromosomes (the all-off and all-on
    patterns are seeded so the baseline and full-offload are always tried),
  * fitness from *measured* performance (wall clock or compiled cost model),
  * invalid results (PCAST-style verification failure, compile error) get
    processing time infinity -> fitness 0,
  * roulette selection scaled by fitness, single-point crossover, bit-flip
    mutation, elite copy,
  * per-chromosome measurement cache (a pattern is never re-measured),
  * fixed generation count, best chromosome wins.
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np


@dataclass
class GAConfig:
    population: int = 12
    generations: int = 8
    crossover_rate: float = 0.9
    mutation_rate: float = 0.05
    elite: int = 2
    seed: int = 0
    patience: Optional[int] = None    # stop after N generations w/o improvement


@dataclass
class Evaluation:
    bits: tuple
    time_s: float                     # inf if invalid
    valid: bool
    detail: dict = field(default_factory=dict)

    @property
    def fitness(self) -> float:
        return 0.0 if not self.valid or not math.isfinite(self.time_s) \
            else 1.0 / max(self.time_s, 1e-12)


@dataclass
class GAResult:
    best: Evaluation
    history: list[dict]               # per generation: best/mean time
    evaluations: int                  # unique chromosome measurements
    cache_hits: int
    baseline: Optional[Evaluation] = None   # all-off pattern

    @property
    def speedup_vs_baseline(self) -> float:
        if self.baseline is None or not self.baseline.valid:
            return float("nan")
        return self.baseline.time_s / self.best.time_s


FitnessFn = Callable[[tuple], Evaluation]


def run_ga(length: int, fitness_fn: FitnessFn, cfg: GAConfig,
           log: Optional[Callable[[str], None]] = None) -> GAResult:
    """Search binary chromosomes of `length`; returns the fastest valid one."""
    rng = np.random.default_rng(cfg.seed)
    cache: dict[tuple, Evaluation] = {}
    cache_hits = 0

    def evaluate(bits: tuple) -> Evaluation:
        nonlocal cache_hits
        if bits in cache:
            cache_hits += 1
            return cache[bits]
        ev = fitness_fn(bits)
        cache[bits] = ev
        return ev

    if length == 0:
        ev = evaluate(())
        return GAResult(ev, [], 1, 0, baseline=ev)

    # --- population init: random + seeded all-off / all-on -----------------
    pop: list[tuple] = [tuple([0] * length), tuple([1] * length)]
    while len(pop) < cfg.population:
        pop.append(tuple(int(b) for b in rng.integers(0, 2, length)))
    pop = pop[: cfg.population]

    baseline = evaluate(tuple([0] * length))
    history: list[dict] = []
    best: Optional[Evaluation] = None
    stale = 0

    for gen in range(cfg.generations):
        evals = [evaluate(p) for p in pop]
        gen_best = min(evals, key=lambda e: e.time_s)
        if best is None or gen_best.time_s < best.time_s:
            best = gen_best
            stale = 0
        else:
            stale += 1
        finite = [e.time_s for e in evals if math.isfinite(e.time_s)]
        history.append({
            "generation": gen,
            "best_time_s": best.time_s,
            "gen_best_time_s": gen_best.time_s,
            "mean_time_s": float(np.mean(finite)) if finite else float("inf"),
            "n_invalid": sum(1 for e in evals if not e.valid),
        })
        if log:
            log(f"gen {gen}: best={best.time_s:.6g}s "
                f"mean={history[-1]['mean_time_s']:.6g}s "
                f"invalid={history[-1]['n_invalid']}")
        if cfg.patience is not None and stale >= cfg.patience:
            break

        # --- selection: fitness-proportional (roulette) --------------------
        fits = np.array([e.fitness for e in evals])
        if fits.sum() <= 0:
            probs = np.full(len(pop), 1.0 / len(pop))
        else:
            probs = fits / fits.sum()

        ranked = sorted(zip(pop, evals), key=lambda pe: pe[1].time_s)
        next_pop: list[tuple] = [p for p, _ in ranked[: cfg.elite]]  # elite copy
        while len(next_pop) < cfg.population:
            i, j = rng.choice(len(pop), size=2, p=probs)
            a, b = list(pop[i]), list(pop[j])
            if rng.random() < cfg.crossover_rate and length > 1:
                cut = int(rng.integers(1, length))
                a = a[:cut] + b[cut:]
            for t in range(length):                       # bit-flip mutation
                if rng.random() < cfg.mutation_rate:
                    a[t] = 1 - a[t]
            next_pop.append(tuple(a))
        pop = next_pop

    assert best is not None
    return GAResult(best, history, evaluations=len(cache),
                    cache_hits=cache_hits, baseline=baseline)
