"""Genetic-algorithm engine for offload-pattern search (paper §3.2.1, §4.2.2).

Faithful to the paper's loop:
  * initial population: random 0/1 chromosomes (the all-off and all-on
    patterns are seeded so the baseline and full-offload are always tried),
  * fitness from *measured* performance (wall clock or compiled cost model),
  * invalid results (PCAST-style verification failure, compile error) get
    processing time infinity -> fitness 0,
  * roulette selection scaled by fitness, single-point crossover, bit-flip
    mutation, elite copy,
  * per-chromosome measurement cache (a pattern is never re-measured),
  * fixed generation count, best chromosome wins.

Measurement scheduling (dedup, parallel dispatch, the persistent on-disk
cache and the optional surrogate pre-screen) lives in
:mod:`repro.core.evaluator`; `run_ga` drives it one *generation batch* at a
time, and generates **duplicate-avoiding offspring** (arXiv:2002.12115):
children that decode to an already-measured pattern are re-mutated so each
verification measurement buys new information.  With a deterministic fitness
function the search trajectory is byte-identical in serial and parallel
evaluation modes.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclass
class GAConfig:
    population: int = 12
    generations: int = 8
    crossover_rate: float = 0.9
    mutation_rate: float = 0.05
    elite: int = 2
    seed: int = 0
    patience: Optional[int] = None    # stop after N generations w/o improvement
    # --- evaluation-engine knobs (repro.core.evaluator) ---------------------
    workers: int = 0                  # 0/1 serial; N>1 thread pool (compile-
                                      # bound fitness only — keep wall-clock
                                      # fitness serial for timing fidelity)
    compile_workers: Optional[int] = None
                                      # compile-parallel/time-serial phase for
                                      # two-phase fitness (WallClockFitness
                                      # prepare/measure): warm-up compiles of
                                      # different chromosomes overlap on this
                                      # many threads ahead of the strictly
                                      # serial timing loop.  None = the
                                      # frontend decides — Offloader.plan
                                      # auto-enables it where the bundle says
                                      # a chromosome's prepare is one big
                                      # GIL-releasing compile
                                      # (FitnessBundle.overlap_compiles: the
                                      # jaxpr substitution path); bare
                                      # run_ga/ga_search keep warm-ups serial.
                                      # 0/1 = explicitly serial.  Safe with
                                      # serial_only fitness: timing never
                                      # interleaves with compilation
    pool: Optional[str] = None        # registered fitness-factory name: run
                                      # measurements in an evaluator.
                                      # ProcessPool of `workers` spawn
                                      # processes built from that factory
                                      # (XLA serializes LLVM compiles
                                      # in-process, so compile-bound fitness
                                      # only scales across processes).  Takes
                                      # effect via ga_search, whose
                                      # caller owns
                                      # keeping the factory's fitness in sync
                                      # with the searched coding; bare run_ga
                                      # and Offloader.plan (which composes a
                                      # fitness workers can't rebuild) raise
    screen_top_k: Optional[int] = None  # surrogate pre-screen: measure at
                                        # most k new offspring per generation.
                                        # Needs a surrogate ranking fn, so it
                                        # only takes effect via
                                        # ga_search (or a hand-built
                                        # Evaluator); bare run_ga raises
    cache_dir: Optional[str] = None   # persistent measurement cache location.
                                      # Needs a program fingerprint, so it
                                      # only takes effect via
                                      # ga_search (or a hand-built
                                      # Evaluator); bare run_ga raises
    auto_screen: bool = True          # when screen_top_k is unset and a prior
                                      # search of the same fingerprint (in
                                      # cache_dir) recorded a surrogate rank
                                      # correlation >= auto_screen_corr,
                                      # ga_search sets screen_top_k to
                                      # population // 2 by itself
    auto_screen_corr: float = 0.6     # evidence bar for auto-screening
    auto_screen_horizon_s: float = 7 * 24 * 3600.0
                                      # staleness horizon for that evidence:
                                      # rank-corr records older than this are
                                      # ignored (and compacted away), so
                                      # auto-screening never acts on a stale
                                      # fingerprint
    fit_surrogate: bool = True        # fit a regression surrogate against the
                                      # fingerprint's measurement journal
                                      # (repro.core.surrogate) and prefer it
                                      # over the static transfer-cost formula
                                      # when its journal rank correlation is
                                      # strictly better.  Takes effect via
                                      # ga_search with a cache_dir
    surrogate_min_records: int = 10   # journal rows below which the fit
                                      # abstains and the hand formula stays
    dup_retries: int = 3              # re-mutation attempts per duplicate child
    objectives: tuple = ("latency",)  # objective axes for selection.  The
                                      # default single axis keeps the paper's
                                      # fitness-proportional roulette path
                                      # byte-identical; a multi-axis tuple
                                      # (e.g. repro.core.objectives.OBJECTIVES
                                      # = latency/energy/transfer) makes
                                      # ga_search build an objective vector fn
                                      # and run_ga switch to NSGA-style
                                      # non-dominated + crowding selection,
                                      # reporting the Pareto front in
                                      # GAResult.front


@dataclass
class Evaluation:
    bits: tuple
    time_s: float                     # inf if invalid
    valid: bool
    detail: dict = field(default_factory=dict)

    @property
    def fitness(self) -> float:
        return 0.0 if not self.valid or not math.isfinite(self.time_s) \
            else 1.0 / max(self.time_s, 1e-12)


@dataclass
class GAResult:
    best: Evaluation
    history: list[dict]               # per generation: best/mean time
    evaluations: int                  # fitness_fn invocations (new measurements)
    cache_hits: int                   # in-memory + in-flight dedup hits
    baseline: Optional[Evaluation] = None   # all-off pattern
    persistent_hits: int = 0          # measurements served by the disk cache
    screened_out: int = 0             # offspring deferred by the surrogate
    duplicates_avoided: int = 0       # dup children re-mutated to fresh ones
    wall_s: float = 0.0               # total search wall-clock
    eval_wall_s: float = 0.0          # wall-clock inside measurement batches
    surrogate_rank_corr: float = float("nan")  # Spearman corr of the
                                      # surrogate's ranking vs measured
                                      # fitness (nan when no surrogate or
                                      # too few finite measurements) — the
                                      # number that justifies screen_top_k
    surrogate_kind: str = "static"    # which surrogate ranked offspring:
                                      # the hand transfer-cost formula or a
                                      # journal-fitted regression ("fitted",
                                      # repro.core.surrogate) — set by
                                      # ga_search when the fitted model's
                                      # journal rank corr beats the static
    compile_overlap_saved_s: float = 0.0  # wall-clock saved by overlapping
                                      # warm-up compiles ahead of the serial
                                      # timing loop (EvalStats)
    front: list = field(default_factory=list)  # Pareto-optimal Evaluations
                                      # (multi-objective search: every
                                      # non-dominated measured pattern,
                                      # sorted fastest-first; single-
                                      # objective: just [best])

    @property
    def speedup_vs_baseline(self) -> float:
        if self.baseline is None or not self.baseline.valid:
            return float("nan")
        return self.baseline.time_s / self.best.time_s

    @property
    def measurements_saved(self) -> int:
        """Verification measurements avoided by cache + dedup + screening."""
        return self.cache_hits + self.persistent_hits + self.screened_out


FitnessFn = Callable[[tuple], Evaluation]
ObjectiveFn = Callable[[Evaluation], tuple]


# ---------------------------------------------------------------------------
# NSGA-style multi-objective selection primitives (Deb et al. 2002)
# ---------------------------------------------------------------------------


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """Pareto dominance, all axes minimized: ``a`` dominates ``b`` iff it is
    no worse everywhere and strictly better somewhere.  Totality note: for
    any pair exactly one of {a dom b, b dom a, neither} holds — equal
    vectors (and all-inf invalid points) are mutually non-dominating."""
    assert len(a) == len(b), (len(a), len(b))
    better = False
    for x, y in zip(a, b):
        if x > y:
            return False
        if x < y:
            better = True
    return better


def non_dominated_sort(points: Sequence[Sequence[float]]) -> list[list[int]]:
    """Fast-ish O(n²) non-dominated sort: index lists per front, front 0
    first.  Every input index appears in exactly one front."""
    n = len(points)
    dominated_by: list[list[int]] = [[] for _ in range(n)]
    dom_count = [0] * n
    for i in range(n):
        for j in range(i + 1, n):
            if dominates(points[i], points[j]):
                dominated_by[i].append(j)
                dom_count[j] += 1
            elif dominates(points[j], points[i]):
                dominated_by[j].append(i)
                dom_count[i] += 1
    fronts: list[list[int]] = []
    current = [i for i in range(n) if dom_count[i] == 0]
    while current:
        fronts.append(current)
        nxt = []
        for i in current:
            for j in dominated_by[i]:
                dom_count[j] -= 1
                if dom_count[j] == 0:
                    nxt.append(j)
        current = nxt
    return fronts


def crowding_distances(points: Sequence[Sequence[float]]) -> list[float]:
    """Crowding distance within one front: boundary points (per-axis min or
    max) get ``inf`` so selection always preserves the extremes; interior
    points sum normalized neighbor gaps per axis."""
    n = len(points)
    if n == 0:
        return []
    if n <= 2:
        return [float("inf")] * n
    m = len(points[0])
    dist = [0.0] * n
    for ax in range(m):
        order = sorted(range(n), key=lambda i: points[i][ax])
        lo, hi = points[order[0]][ax], points[order[-1]][ax]
        dist[order[0]] = dist[order[-1]] = float("inf")
        span = hi - lo
        if span <= 0 or not math.isfinite(span):
            continue
        for k in range(1, n - 1):
            gap = (points[order[k + 1]][ax] - points[order[k - 1]][ax]) / span
            if math.isfinite(dist[order[k]]):
                dist[order[k]] += gap
    return dist


def pareto_front(points: Sequence[Sequence[float]]) -> list[int]:
    """Indices of the non-dominated points (first front), input order kept."""
    if not points:
        return []
    return sorted(non_dominated_sort(points)[0])


def run_ga(length: int, fitness_fn: FitnessFn, cfg: GAConfig,
           log: Optional[Callable[[str], None]] = None,
           evaluator=None, arity: int = 2,
           seeds: Sequence[Sequence[int]] = (),
           objective_fn: Optional[ObjectiveFn] = None) -> GAResult:
    """Search chromosomes of `length`; returns the fastest valid one.

    Genes range over ``{0 .. arity-1}`` (2 = the paper's binary CPU/GPU
    encoding; larger alphabets come from multi-destination gene codings —
    see :mod:`repro.core.genes`).  ``seeds`` are extra chromosomes injected
    into the initial population after the always-seeded all-off / all-on
    patterns — the pattern-DB and similarity-neighbor warm starts.

    ``evaluator`` is an optional pre-built :class:`repro.core.evaluator.
    Evaluator` (callers that want a persistent cache keyed to a program
    fingerprint, or a surrogate pre-screen, construct it themselves — see
    ``ga_search``).  When omitted, one is built from the GAConfig
    knobs (`workers`, `cache_dir`, `screen_top_k`).  The GAResult measurement
    counters are the evaluator's lifetime totals, so pass a fresh evaluator
    per search if you want per-search numbers.

    ``objective_fn`` switches selection to NSGA-style multi-objective mode:
    it maps each :class:`Evaluation` to a smaller-is-better float tuple
    (see :func:`repro.core.objectives.make_objective_fn`), parents are
    chosen by binary tournament on (non-domination rank, crowding distance)
    and elites are the rank/crowding-best individuals.  ``best``, patience
    and the history stay latency-first (objective 0 is latency by
    convention) so tier-1 semantics are untouched, and
    :attr:`GAResult.front` reports every non-dominated measured pattern.
    When ``objective_fn`` is None (the default) the paper's roulette path
    runs byte-identically to before.
    """
    from repro.core.evaluator import Evaluator  # deferred: avoids import cycle

    assert arity >= 2, arity
    t_start = time.perf_counter()
    rng = np.random.default_rng(cfg.seed)
    owns_evaluator = evaluator is None
    if evaluator is None:
        if cfg.cache_dir is not None:
            # a persistent cache needs a program identity; bare run_ga has
            # none, and an anonymous key would serve one program's timings
            # to every other program sharing the cache_dir
            raise ValueError(
                "GAConfig.cache_dir requires a program fingerprint; call "
                "ga_search (which keys the cache by the region "
                "graph) or pass a pre-built Evaluator")
        if cfg.pool is not None:
            raise ValueError(
                "GAConfig.pool requires a fitness-factory ProcessPool; call "
                "ga_search / Offloader.plan (which own the pool "
                "lifecycle) or pass a pre-built Evaluator")
        evaluator = Evaluator(fitness_fn, workers=cfg.workers,
                              screen_top_k=cfg.screen_top_k,
                              compile_workers=cfg.compile_workers)

    multi = objective_fn is not None
    archive: dict[tuple, Evaluation] = {}   # every measured pattern (multi)

    def _front_of_archive() -> list[Evaluation]:
        """Non-dominated subset of every pattern seen, fastest-first."""
        evs = [e for e in archive.values()
               if e.valid and math.isfinite(e.time_s)]
        pts = [objective_fn(e) for e in evs]
        keep = [k for k in pareto_front(pts)
                if all(math.isfinite(v) for v in pts[k])]
        return sorted((evs[k] for k in keep), key=lambda e: e.time_s)

    def finish(best, history, baseline) -> GAResult:
        st = evaluator.stats
        corr = getattr(evaluator, "surrogate_rank_correlation",
                       lambda: float("nan"))()
        if owns_evaluator:
            evaluator.close()
        if multi:
            front = _front_of_archive()
        else:
            front = [best] if best.valid and math.isfinite(best.time_s) \
                else []
        return GAResult(
            best, history, evaluations=st.measurements,
            cache_hits=st.cache_hits + st.inflight_hits,
            baseline=baseline, persistent_hits=st.persistent_hits,
            screened_out=st.screened_out,
            duplicates_avoided=dup_avoided,
            wall_s=time.perf_counter() - t_start,
            eval_wall_s=st.eval_wall_s,
            surrogate_rank_corr=corr,
            compile_overlap_saved_s=getattr(st, "compile_overlap_saved_s",
                                            0.0),
            front=front)

    dup_avoided = 0
    if length == 0:
        ev = evaluator.evaluate(())
        if multi:
            archive[ev.bits] = ev
        return finish(ev, [], ev)

    def _remutate(chromo: list, pos: int) -> None:
        """Reassign one gene: bit flip for binary, random *other* value else
        (binary keeps the historical rng stream byte-identical)."""
        if arity == 2:
            chromo[pos] ^= 1
        else:
            chromo[pos] = int((chromo[pos] + 1 + rng.integers(0, arity - 1))
                              % arity)

    # --- population init: all-off / all-on, warm-start seeds, random -------
    pop: list[tuple] = [tuple([0] * length), tuple([1] * length)]
    for s in seeds:
        s = tuple(int(v) for v in s)
        if len(s) == length and all(0 <= v < arity for v in s) \
                and s not in pop:
            pop.append(s)
    while len(pop) < cfg.population:
        pop.append(tuple(int(b) for b in rng.integers(0, arity, length)))
    pop = pop[: cfg.population]

    baseline = evaluator.evaluate(tuple([0] * length))
    history: list[dict] = []
    best: Optional[Evaluation] = None
    stale = 0

    for gen in range(cfg.generations):
        # whole-generation batch: dedup + (optionally) parallel measurement
        with obs_trace.span("ga.generation", generation=gen) as gspan:
            evals = evaluator.evaluate_batch(pop)
            gen_best = min(evals, key=lambda e: e.time_s)
            if best is None or gen_best.time_s < best.time_s:
                best = gen_best
                stale = 0
            else:
                stale += 1
            finite = [e.time_s for e in evals
                      if math.isfinite(e.time_s)]
            entry = {
                "generation": gen,
                "best_time_s": best.time_s,
                "gen_best_time_s": gen_best.time_s,
                "mean_time_s": float(np.mean(finite)) if finite
                else float("inf"),
                "n_invalid": sum(1 for e in evals if not e.valid),
            }
            if multi:
                for p, e in zip(pop, evals):
                    archive[p] = e
                entry["front_size"] = len(_front_of_archive())
                obs_metrics.gauge("ga.front_size").set(entry["front_size"])
            history.append(entry)
            gspan.set(**history[-1])
        obs_metrics.counter("ga.generations").inc()
        obs_metrics.gauge("ga.best_time_s").set(best.time_s)
        obs_metrics.gauge("ga.gen_mean_time_s").set(
            history[-1]["mean_time_s"]
            if math.isfinite(history[-1]["mean_time_s"]) else -1.0)
        obs_metrics.counter("ga.invalid").inc(history[-1]["n_invalid"])
        if log:
            log(f"gen {gen}: best={best.time_s:.6g}s "
                f"mean={history[-1]['mean_time_s']:.6g}s "
                f"invalid={history[-1]['n_invalid']}")
        if cfg.patience is not None and stale >= cfg.patience:
            break

        if not multi:
            # --- selection: fitness-proportional (roulette) ----------------
            fits = np.array([e.fitness for e in evals])
            if fits.sum() <= 0:
                probs = np.full(len(pop), 1.0 / len(pop))
            else:
                probs = fits / fits.sum()

            ranked = sorted(zip(pop, evals), key=lambda pe: pe[1].time_s)
            next_pop: list[tuple] = [p for p, _ in ranked[: cfg.elite]]
            proposed = set(next_pop)                              # elite copy

            def draw_parents() -> tuple[int, int]:
                i, j = rng.choice(len(pop), size=2, p=probs)
                return int(i), int(j)
        else:
            # --- NSGA selection: non-domination rank + crowding ------------
            pts = [objective_fn(e) for e in evals]
            rank = [0] * len(pop)
            crowd = [0.0] * len(pop)
            for r, fr in enumerate(non_dominated_sort(pts)):
                fr_dist = crowding_distances([pts[i] for i in fr])
                for i, d in zip(fr, fr_dist):
                    rank[i] = r
                    crowd[i] = d
            order = sorted(range(len(pop)),
                           key=lambda i: (rank[i], -crowd[i]))
            next_pop = []
            for i in order:           # elites: best by (rank, crowding),
                if pop[i] not in next_pop:          # distinct patterns only
                    next_pop.append(pop[i])
                if len(next_pop) >= cfg.elite:
                    break
            proposed = set(next_pop)

            def _tourney() -> int:
                """Binary tournament: lower rank wins, crowding breaks ties
                (prefer the less crowded — keeps front spread)."""
                i, j = (int(v) for v in rng.integers(0, len(pop), size=2))
                return i if (rank[i], -crowd[i]) <= (rank[j], -crowd[j]) \
                    else j

            def draw_parents() -> tuple[int, int]:
                return _tourney(), _tourney()

        while len(next_pop) < cfg.population:
            i, j = draw_parents()
            a, b = list(pop[i]), list(pop[j])
            if rng.random() < cfg.crossover_rate and length > 1:
                cut = int(rng.integers(1, length))
                a = a[:cut] + b[cut:]
            for t in range(length):                       # gene mutation
                if rng.random() < cfg.mutation_rate:
                    _remutate(a, t)
            # duplicate-avoiding offspring (arXiv:2002.12115): a child whose
            # pattern is already measured (or already in this generation)
            # wastes its measurement slot — re-mutate it a bounded number of
            # times; an unresolvable duplicate is kept (cache hit, harmless)
            retries = 0
            while (retries < cfg.dup_retries
                   and (tuple(a) in proposed
                        or evaluator.is_measured(tuple(a)))):
                _remutate(a, int(rng.integers(0, length)))
                retries += 1
            child = tuple(a)
            if retries and child not in proposed \
                    and not evaluator.is_measured(child):
                dup_avoided += 1
            next_pop.append(child)
            proposed.add(child)
        pop = next_pop

    assert best is not None
    return finish(best, history, baseline)
