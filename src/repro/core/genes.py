"""Gene encoding of offload patterns (paper §3.2.1).

A chromosome is a binary string, one bit per offloadable region: ``1`` = run
the region on the accelerator (its offloaded alternative), ``0`` = keep the
reference path.  The encoding is language/frontend-independent; frontends
only contribute the ordered site list.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core.ir import Region, RegionGraph


@dataclass(frozen=True)
class Site:
    """One gene position: a region plus its off/on implementations."""

    region: str
    ref_impl: Any
    offload_impl: Any


@dataclass(frozen=True)
class GeneCoding:
    sites: tuple[Site, ...]

    @property
    def length(self) -> int:
        return len(self.sites)

    def decode(self, bits: Sequence[int]) -> dict[str, Any]:
        """bits -> {region name: chosen implementation}."""
        assert len(bits) == self.length, (len(bits), self.length)
        return {
            s.region: (s.offload_impl if b else s.ref_impl)
            for s, b in zip(self.sites, bits)
        }

    def all_off(self) -> tuple[int, ...]:
        return (0,) * self.length

    def all_on(self) -> tuple[int, ...]:
        return (1,) * self.length


def coding_from_graph(graph: RegionGraph,
                      exclude: Sequence[str] = ()) -> GeneCoding:
    """Build the gene coding from a region graph's offloadable regions,
    excluding regions already claimed by the function-block pass (paper
    §4.2: ループ文オフロードはオフロード可能だった機能ブロック部分を抜いた
    コードに対して試行)."""
    sites = []
    for r in graph.offloadable():
        if r.name in exclude:
            continue
        ref = r.alternatives[0] if r.alternatives else "ref"
        off = r.alternatives[1] if len(r.alternatives) > 1 else "offload"
        sites.append(Site(r.name, ref, off))
    return GeneCoding(tuple(sites))
