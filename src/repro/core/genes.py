"""Gene encoding of offload patterns (paper §3.2.1), generalized to a
multi-destination alphabet (arXiv:2011.12431 direction).

The paper's chromosome is a binary string, one gene per offloadable region:
``1`` = run the region on the accelerator, ``0`` = keep the reference path.
This module keeps that encoding as the default while letting a gene range
over a *destination alphabet* — an ordered tuple of :class:`Destination`
names such as ``("cpu", "gpu", "fpga_stub")``.  Gene value ``k`` assigns the
region to alphabet entry ``k``; value 0 is always the reference (CPU) path
and value 1 the primary accelerator, so binary chromosomes keep their exact
historical meaning.

Destinations are pluggable via :func:`register_destination`.  A destination
may be *cost-only* (``executable=False``): regions assigned to it execute
their reference implementation for correctness, and a deterministic modeled
cost (:func:`modeled_cost_s`) is charged on top of the measurement — so the
enlarged search space is real (the GA weighs it) before the hardware exists.
The encoding stays language/frontend-independent; frontends only contribute
the ordered site list.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core.ir import Region, RegionGraph

# ---------------------------------------------------------------------------
# destination alphabet
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Destination:
    """One place a region can run.

    ``executable`` destinations map to a real implementation of the site
    (``impl_index`` selects it: 0 = reference, 1 = offloaded alternative).
    Cost-only destinations (``executable=False``) execute the reference
    implementation and charge a modeled time instead — a stand-in device
    whose cost model keeps the search space honest before hardware exists.
    """

    name: str
    executable: bool = True
    impl_index: int = 0
    # cost model for cost-only destinations (seconds):
    launch_overhead_s: float = 0.0     # fixed per-region dispatch/transfer cost
    per_trip_s: float = 0.0            # modeled cost per (static) loop trip
    # energy model (repro.core.objectives): watts this destination draws
    # while it executes a region's trips — the modeled prior behind the
    # ``energy`` objective on hosts with no power counters.  The shipped
    # values are deliberately *different* per destination so mixed-
    # destination Pareto fronts exist on CPU-only CI.
    active_power_w: float = 0.0


CPU = Destination("cpu", executable=True, impl_index=0,
                  active_power_w=65.0)
GPU = Destination("gpu", executable=True, impl_index=1,
                  active_power_w=250.0)
#: FPGA stub: no backend yet — reference execution plus a modeled cost of a
#: PCIe-attached reconfigurable card (fixed DMA/launch latency, cheap trips,
#: low board power: the paper's power-saving destination).
FPGA_STUB = Destination("fpga_stub", executable=False, impl_index=0,
                        launch_overhead_s=2e-4, per_trip_s=5e-8,
                        active_power_w=30.0)
#: variant destinations: same accelerator, different *implementation* of the
#: site (the kernel-substitution alphabet — a gene picks which code runs).
GPU_FUSED = Destination("gpu_fused", executable=True, impl_index=1,
                        active_power_w=250.0)
GPU_PALLAS = Destination("gpu_pallas", executable=True, impl_index=2,
                         active_power_w=220.0)

_DESTINATIONS: dict[str, Destination] = {
    d.name: d for d in (CPU, GPU, FPGA_STUB, GPU_FUSED, GPU_PALLAS)
}

#: the paper's original binary CPU/GPU alphabet — the default everywhere.
DEFAULT_ALPHABET: tuple[str, ...] = ("cpu", "gpu")
#: the extended mixed-destination alphabet from the ROADMAP.
EXTENDED_ALPHABET: tuple[str, ...] = ("cpu", "gpu", "fpga_stub")
#: the implementation-variant alphabet the measured jaxpr frontend proposes:
#: gene k selects site implementation k — reference, the fused-jnp rewrite,
#: or the Pallas kernel (see repro.kernels.registry).
VARIANT_ALPHABET: tuple[str, ...] = ("cpu", "gpu_fused", "gpu_pallas")


def register_destination(dest: Destination, replace: bool = False) -> None:
    """Add a destination to the alphabet registry (pluggable devices)."""
    if dest.name in _DESTINATIONS and not replace:
        raise ValueError(f"destination {dest.name!r} already registered")
    _DESTINATIONS[dest.name] = dest


def get_destination(name: str) -> Destination:
    try:
        return _DESTINATIONS[name]
    except KeyError:
        raise KeyError(f"unknown destination {name!r}; registered: "
                       f"{sorted(_DESTINATIONS)}") from None


def destination_names() -> tuple[str, ...]:
    return tuple(sorted(_DESTINATIONS))


# ---------------------------------------------------------------------------
# gene coding
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Site:
    """One gene position: a region plus its implementation menu.

    The first two implementations keep the paper's off/on pair; regions
    with more than one accelerated alternative (kernel-substitution
    variants) extend the menu via ``extra_impls``, indexed by
    ``Destination.impl_index`` (2 = the first extra, and so on).

    ``members`` marks a *function-block* site (arXiv 2004.09883): the named
    regions are the block's constituents.  While the block gene sits on an
    accelerated implementation it **claims** them — their own genes are
    inert and they decode to their reference path (the block adapter
    computes the whole span), so the loop-level search space shrinks to the
    unclaimed remainder.
    """

    region: str
    ref_impl: Any
    offload_impl: Any
    extra_impls: tuple = ()
    members: tuple = ()

    @property
    def impls(self) -> tuple:
        """Implementation by index — what ``Destination.impl_index`` selects."""
        return (self.ref_impl, self.offload_impl) + tuple(self.extra_impls)


@dataclass(frozen=True)
class GeneCoding:
    sites: tuple[Site, ...]
    destinations: tuple[str, ...] = DEFAULT_ALPHABET

    @property
    def length(self) -> int:
        return len(self.sites)

    @property
    def arity(self) -> int:
        """Alphabet size: how many values each gene ranges over."""
        return len(self.destinations)

    def decode(self, values: Sequence[int]) -> dict[str, Any]:
        """values -> {region name: chosen implementation}.

        A cost-only destination decodes to the site implementation its
        ``impl_index`` names (the reference path for the shipped stubs), so
        executors run correct code; the modeled cost is charged separately
        (:func:`modeled_cost_s`).
        """
        assert len(values) == self.length, (len(values), self.length)
        out: dict[str, Any] = {}
        for s, v in zip(self.sites, values):
            dest = get_destination(self.destinations[int(v)])
            impls = s.impls
            out[s.region] = impls[min(dest.impl_index, len(impls) - 1)]
        claimed = self.claimed_members(values)
        if claimed:
            for s in self.sites:
                if s.region in claimed:
                    out[s.region] = s.ref_impl
        return out

    def claimed_members(self, values: Sequence[int]) -> frozenset:
        """Regions claimed by active block genes: every member of a block
        site whose gene decodes to a non-reference implementation.  Claimed
        regions' own genes are inert for this chromosome."""
        claimed: set[str] = set()
        for s, v in zip(self.sites, values):
            if not s.members:
                continue
            dest = get_destination(self.destinations[int(v)])
            impls = s.impls
            if impls[min(dest.impl_index, len(impls) - 1)] != s.ref_impl:
                claimed.update(s.members)
        return frozenset(claimed)

    def destinations_of(self, values: Sequence[int]) -> dict[str, str]:
        """values -> {region name: destination name}."""
        assert len(values) == self.length, (len(values), self.length)
        return {s.region: self.destinations[int(v)]
                for s, v in zip(self.sites, values)}

    def all_off(self) -> tuple[int, ...]:
        return (0,) * self.length

    def all_on(self) -> tuple[int, ...]:
        return (1,) * self.length


def coding_from_graph(graph: RegionGraph,
                      exclude: Sequence[str] = (),
                      destinations: Sequence[str] = DEFAULT_ALPHABET
                      ) -> GeneCoding:
    """Build the gene coding from a region graph's offloadable regions,
    excluding regions already claimed by the function-block pass (paper
    §4.2: ループ文オフロードはオフロード可能だった機能ブロック部分を抜いた
    コードに対して試行)."""
    for d in destinations:
        get_destination(d)           # fail fast on unknown alphabet entries
    sites = []
    for r in graph.offloadable():
        if r.name in exclude:
            continue
        ref = r.alternatives[0] if r.alternatives else "ref"
        off = r.alternatives[1] if len(r.alternatives) > 1 else "offload"
        sites.append(Site(r.name, ref, off, tuple(r.alternatives[2:]),
                          members=tuple(r.meta.get("block_members", ()))))
    return GeneCoding(tuple(sites), tuple(destinations))


# ---------------------------------------------------------------------------
# cost model for cost-only destinations
# ---------------------------------------------------------------------------


def _trip_product(graph: RegionGraph, region: Region) -> int:
    """Static dynamic-trip estimate: own trip count times enclosing loops'."""
    trips = region.trip_count or 1 if region.kind == "loop" else 1
    r = region
    while r.parent is not None:
        r = graph.by_name(r.parent)
        if r.kind == "loop":
            trips *= r.trip_count or 1
    return trips


def modeled_cost_s(graph: RegionGraph, coding: GeneCoding,
                   values: Sequence[int]) -> float:
    """Deterministic modeled time for genes on cost-only destinations.

    Charged on top of the measured time of the chromosome (whose cost-only
    regions executed their reference path), so patterns that park work on a
    stub device pay that device's modeled latency in the fitness.
    """
    total = 0.0
    claimed = coding.claimed_members(values)
    for site, v in zip(coding.sites, values):
        if site.region in claimed:
            continue                 # the block adapter computes this region
        dest = get_destination(coding.destinations[int(v)])
        if dest.executable:
            continue
        region = graph.by_name(site.region)
        total += (dest.launch_overhead_s
                  + _trip_product(graph, region) * dest.per_trip_s)
    return total
