"""Gene encoding of offload patterns (paper §3.2.1), generalized to a
multi-destination alphabet (arXiv:2011.12431 direction).

The paper's chromosome is a binary string, one gene per offloadable region:
``1`` = run the region on the accelerator, ``0`` = keep the reference path.
This module keeps that encoding as the default while letting a gene range
over a *destination alphabet* — an ordered tuple of :class:`Destination`
names such as ``("cpu", "gpu", "fpga_stub")``.  Gene value ``k`` assigns the
region to alphabet entry ``k``; value 0 is always the reference (CPU) path
and value 1 the primary accelerator, so binary chromosomes keep their exact
historical meaning.

Destinations are pluggable via :func:`register_destination`.  A destination
may be *cost-only* (``is_cost_only``): regions assigned to it execute
their reference implementation for correctness, and a deterministic modeled
cost (:func:`modeled_cost_s`) is charged on top of the measurement — so the
enlarged search space is real (the GA weighs it) before the hardware exists.
The encoding stays language/frontend-independent; frontends only contribute
the ordered site list.

Destination API v2 is a small frozen hierarchy: :class:`Device` is a single
physical device; :class:`MeshDestination` places a region on an ``n``-device
mesh along a named axis with a sharding spec (arXiv 2011.12431's mixed
offloading destinations, extended to placement × parallelism).  The wire
format IS the destination name (``mesh:data:4:batch``), so alphabets,
SeedBank records, phenotype keys, and PlanStore payloads — all of which
carry name strings — round-trip mesh specs with no schema change.  On hosts
with fewer than ``n`` devices a mesh destination degrades to cost-only:
reference execution plus a modeled per-shard-transfer + collective cost
(:func:`repro.core.transfer_planner.modeled_mesh_cost_s`).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core.ir import Region, RegionGraph

# ---------------------------------------------------------------------------
# destination alphabet
# ---------------------------------------------------------------------------

#: modeled watt prior for destinations that declare no ``active_power_w``
#: (a conventional host CPU package) — the energy model's fallback.
DEFAULT_ACTIVE_POWER_W = 65.0
#: modeled per-device watt prior for mesh destinations (GPU-class devices).
MESH_DEVICE_POWER_W = 250.0

_PROBED_DEVICE_COUNT: Optional[int] = None


def probed_device_count() -> int:
    """How many accelerator-visible devices this process has (cached).

    Mesh destinations compare their ``n`` against this to decide between
    genuine shard_map execution and cost-only modeling.  Falls back to 1
    when jax is unavailable or backend init fails."""
    global _PROBED_DEVICE_COUNT
    if _PROBED_DEVICE_COUNT is None:
        try:
            import jax
            _PROBED_DEVICE_COUNT = int(jax.device_count())
        except Exception:
            _PROBED_DEVICE_COUNT = 1
    return _PROBED_DEVICE_COUNT


@dataclass(frozen=True)
class Destination:
    """One place a region can run (Destination API v2 base).

    ``executable`` destinations map to a real implementation of the site
    (``impl_index`` selects it: 0 = reference, 1 = offloaded alternative).
    Cost-only destinations (``is_cost_only``) execute the reference
    implementation and charge a modeled time instead — a stand-in device
    whose cost model keeps the search space honest before hardware exists.

    The v2 surface every consumer goes through:

    * ``wire()`` / ``from_wire()`` — the one serialization (the name string)
      used by gene alphabets, SeedBank cross-alphabet mapping, phenotype
      keys, and PlanStore payloads.
    * ``watts()`` — modeled draw while executing (per-device prior ×
      ``device_count``), the energy objective's input.
    * ``is_cost_only`` — whether assignment charges a model instead of
      running offloaded code *on this host* (environment-dependent for
      meshes, static for stub devices).
    * ``placement_tag`` — non-None when the assignment changes the
      phenotype beyond the decoded impl map (stub parking, mesh placement),
      so the measurement cache never conflates such chromosomes.
    """

    name: str
    executable: bool = True
    impl_index: int = 0
    # cost model for cost-only destinations (seconds):
    launch_overhead_s: float = 0.0     # fixed per-region dispatch/transfer cost
    per_trip_s: float = 0.0            # modeled cost per (static) loop trip
    # energy model (repro.core.objectives): watts this destination draws
    # while it executes a region's trips — the modeled prior behind the
    # ``energy`` objective on hosts with no power counters.  The shipped
    # values are deliberately *different* per destination so mixed-
    # destination Pareto fronts exist on CPU-only CI.
    active_power_w: float = 0.0

    # -- v2 API ------------------------------------------------------------
    @property
    def device_count(self) -> int:
        return 1

    @property
    def is_cost_only(self) -> bool:
        return not self.executable

    @property
    def placement_tag(self) -> Optional[str]:
        return self.name if self.is_cost_only else None

    def watts(self) -> float:
        per_device = (self.active_power_w if self.active_power_w > 0
                      else DEFAULT_ACTIVE_POWER_W)
        return per_device * self.device_count

    def wire(self) -> str:
        """Stable wire string: the name is the serialization."""
        return self.name

    @classmethod
    def from_wire(cls, wire: str) -> "Destination":
        return get_destination(wire)


@dataclass(frozen=True)
class Device(Destination):
    """A single physical (or stand-in) device — the scalar v1 alphabet."""


@dataclass(frozen=True)
class MeshDestination(Destination):
    """Place a region on an ``n``-device mesh along one named axis.

    ``axis`` is the mesh axis kind — ``"data"`` shards the batch (leading)
    dimension, ``"model"`` the feature (trailing) dimension.  ``spec``
    names the sharded dimension (``"batch"``, ``"feature"``, or ``"dimK"``
    for an explicit index) and defaults from the axis.  The canonical name
    doubles as the wire format: ``mesh:{axis}:{n}:{spec}``.

    Decoding keeps ``impl_index`` 0 (the reference implementation): the
    substitution engine replaces the site's span with a shard_map'd run of
    that same span when the host has >= ``n`` devices; otherwise the
    destination is cost-only and :func:`modeled_cost_s` charges per-shard
    transfers plus a modeled collective term."""

    name: str = ""
    axis: str = "data"
    n: int = 2
    spec: str = ""

    def __post_init__(self) -> None:
        if self.axis not in ("data", "model"):
            raise ValueError(f"mesh axis must be 'data' or 'model', "
                             f"got {self.axis!r}")
        if self.n < 1:
            raise ValueError(f"mesh size must be >= 1, got {self.n}")
        spec = self.spec or ("batch" if self.axis == "data" else "feature")
        if spec not in ("batch", "feature") and not (
                spec.startswith("dim") and spec[3:].isdigit()):
            raise ValueError(f"mesh spec must be 'batch', 'feature' or "
                             f"'dimN', got {spec!r}")
        object.__setattr__(self, "spec", spec)
        object.__setattr__(self, "name", f"mesh:{self.axis}:{self.n}:{spec}")
        if self.active_power_w <= 0:
            object.__setattr__(self, "active_power_w", MESH_DEVICE_POWER_W)

    @property
    def device_count(self) -> int:
        return self.n

    @property
    def shard_dim(self) -> int:
        """Which dimension the spec shards: 0 (batch), -1 (feature), or K."""
        if self.spec == "batch":
            return 0
        if self.spec == "feature":
            return -1
        return int(self.spec[3:])

    def available(self) -> bool:
        """Whether this host can genuinely build the mesh."""
        return probed_device_count() >= self.n

    @property
    def is_cost_only(self) -> bool:
        return not self.available()

    @property
    def placement_tag(self) -> Optional[str]:
        # mesh placement always changes the phenotype (sharded execution or
        # modeled charge), even when the decoded impl map is the reference
        return self.name

    @classmethod
    def from_wire(cls, wire: str) -> "MeshDestination":
        parts = wire.split(":")
        if len(parts) not in (3, 4) or parts[0] != "mesh":
            raise ValueError(f"not a mesh wire string: {wire!r} "
                             f"(want 'mesh:<axis>:<n>[:<spec>]')")
        try:
            n = int(parts[2])
        except ValueError:
            raise ValueError(f"mesh size not an int in {wire!r}") from None
        return cls(axis=parts[1], n=n, spec=parts[3] if len(parts) == 4 else "")


CPU = Device("cpu", executable=True, impl_index=0,
             active_power_w=65.0)
GPU = Device("gpu", executable=True, impl_index=1,
             active_power_w=250.0)
#: FPGA stub: no backend yet — reference execution plus a modeled cost of a
#: PCIe-attached reconfigurable card (fixed DMA/launch latency, cheap trips,
#: low board power: the paper's power-saving destination).
FPGA_STUB = Device("fpga_stub", executable=False, impl_index=0,
                   launch_overhead_s=2e-4, per_trip_s=5e-8,
                   active_power_w=30.0)
#: variant destinations: same accelerator, different *implementation* of the
#: site (the kernel-substitution alphabet — a gene picks which code runs).
GPU_FUSED = Device("gpu_fused", executable=True, impl_index=1,
                   active_power_w=250.0)
GPU_PALLAS = Device("gpu_pallas", executable=True, impl_index=2,
                    active_power_w=220.0)

_DESTINATIONS: dict[str, Destination] = {
    d.name: d for d in (CPU, GPU, FPGA_STUB, GPU_FUSED, GPU_PALLAS)
}

#: the paper's original binary CPU/GPU alphabet — the default everywhere.
DEFAULT_ALPHABET: tuple[str, ...] = ("cpu", "gpu")
#: the extended mixed-destination alphabet from the ROADMAP.
EXTENDED_ALPHABET: tuple[str, ...] = ("cpu", "gpu", "fpga_stub")
#: the implementation-variant alphabet the measured jaxpr frontend proposes:
#: gene k selects site implementation k — reference, the fused-jnp rewrite,
#: or the Pallas kernel (see repro.kernels.registry).
VARIANT_ALPHABET: tuple[str, ...] = ("cpu", "gpu_fused", "gpu_pallas")


def register_destination(dest: Destination, replace: bool = False) -> None:
    """Add a destination to the alphabet registry (pluggable devices)."""
    if dest.name in _DESTINATIONS and not replace:
        raise ValueError(f"destination {dest.name!r} already registered")
    _DESTINATIONS[dest.name] = dest


def get_destination(name: str) -> Destination:
    dest = _DESTINATIONS.get(name)
    if dest is not None:
        return dest
    if name.startswith("mesh:"):
        # mesh wire strings are an open alphabet: parse and cache on demand
        # (under the canonical name AND the alias spelled without a spec)
        try:
            dest = MeshDestination.from_wire(name)
        except ValueError as e:
            raise KeyError(f"bad mesh destination {name!r}: {e}") from None
        _DESTINATIONS.setdefault(dest.name, dest)
        _DESTINATIONS.setdefault(name, dest)
        return dest
    raise KeyError(f"unknown destination {name!r}; registered: "
                   f"{sorted(_DESTINATIONS)}")


def destination_names() -> tuple[str, ...]:
    return tuple(sorted(_DESTINATIONS))


#: mesh sizes the frontends propose when the host has the devices for them.
MESH_PROPOSAL_SIZES: tuple[int, ...] = (2, 4, 8)


def mesh_proposals(axes: Sequence[str] = ("data",),
                   sizes: Sequence[int] = MESH_PROPOSAL_SIZES,
                   device_count: Optional[int] = None) -> tuple[str, ...]:
    """Mesh destination names this host can genuinely execute (n <= devices).

    Returns () on single-device hosts so CI alphabets, fingerprints and
    committed baselines stay byte-stable; explicit mesh names in
    ``OffloadConfig.destinations`` still work anywhere (cost-modeled)."""
    ndev = probed_device_count() if device_count is None else device_count
    return tuple(MeshDestination(axis=axis, n=n).name
                 for axis in axes for n in sizes if 2 <= n <= ndev)


def with_mesh_destinations(base: Sequence[str],
                           axes: Sequence[str] = ("data",),
                           sizes: Sequence[int] = MESH_PROPOSAL_SIZES,
                           device_count: Optional[int] = None
                           ) -> tuple[str, ...]:
    """``base`` alphabet extended with this host's executable mesh genes."""
    base = tuple(base)
    return base + tuple(m for m in mesh_proposals(axes, sizes, device_count)
                        if m not in base)


# ---------------------------------------------------------------------------
# gene coding
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Site:
    """One gene position: a region plus its implementation menu.

    The first two implementations keep the paper's off/on pair; regions
    with more than one accelerated alternative (kernel-substitution
    variants) extend the menu via ``extra_impls``, indexed by
    ``Destination.impl_index`` (2 = the first extra, and so on).

    ``members`` marks a *function-block* site (arXiv 2004.09883): the named
    regions are the block's constituents.  While the block gene sits on an
    accelerated implementation it **claims** them — their own genes are
    inert and they decode to their reference path (the block adapter
    computes the whole span), so the loop-level search space shrinks to the
    unclaimed remainder.
    """

    region: str
    ref_impl: Any
    offload_impl: Any
    extra_impls: tuple = ()
    members: tuple = ()

    @property
    def impls(self) -> tuple:
        """Implementation by index — what ``Destination.impl_index`` selects."""
        return (self.ref_impl, self.offload_impl) + tuple(self.extra_impls)


@dataclass(frozen=True)
class GeneCoding:
    sites: tuple[Site, ...]
    destinations: tuple[str, ...] = DEFAULT_ALPHABET

    @property
    def length(self) -> int:
        return len(self.sites)

    @property
    def arity(self) -> int:
        """Alphabet size: how many values each gene ranges over."""
        return len(self.destinations)

    def decode(self, values: Sequence[int]) -> dict[str, Any]:
        """values -> {region name: chosen implementation}.

        A cost-only destination decodes to the site implementation its
        ``impl_index`` names (the reference path for the shipped stubs), so
        executors run correct code; the modeled cost is charged separately
        (:func:`modeled_cost_s`).
        """
        assert len(values) == self.length, (len(values), self.length)
        out: dict[str, Any] = {}
        for s, v in zip(self.sites, values):
            dest = get_destination(self.destinations[int(v)])
            impls = s.impls
            out[s.region] = impls[min(dest.impl_index, len(impls) - 1)]
        claimed = self.claimed_members(values)
        if claimed:
            for s in self.sites:
                if s.region in claimed:
                    out[s.region] = s.ref_impl
        return out

    def claimed_members(self, values: Sequence[int]) -> frozenset:
        """Regions claimed by active block genes: every member of a block
        site whose gene decodes to a non-reference implementation.  Claimed
        regions' own genes are inert for this chromosome."""
        claimed: set[str] = set()
        for s, v in zip(self.sites, values):
            if not s.members:
                continue
            dest = get_destination(self.destinations[int(v)])
            impls = s.impls
            if impls[min(dest.impl_index, len(impls) - 1)] != s.ref_impl:
                claimed.update(s.members)
        return frozenset(claimed)

    def destinations_of(self, values: Sequence[int]) -> dict[str, str]:
        """values -> {region name: destination name}."""
        assert len(values) == self.length, (len(values), self.length)
        return {s.region: self.destinations[int(v)]
                for s, v in zip(self.sites, values)}

    def all_off(self) -> tuple[int, ...]:
        return (0,) * self.length

    def all_on(self) -> tuple[int, ...]:
        return (1,) * self.length


def coding_from_graph(graph: RegionGraph,
                      exclude: Sequence[str] = (),
                      destinations: Sequence[str] = DEFAULT_ALPHABET
                      ) -> GeneCoding:
    """Build the gene coding from a region graph's offloadable regions,
    excluding regions already claimed by the function-block pass (paper
    §4.2: ループ文オフロードはオフロード可能だった機能ブロック部分を抜いた
    コードに対して試行)."""
    for d in destinations:
        get_destination(d)           # fail fast on unknown alphabet entries
    sites = []
    for r in graph.offloadable():
        if r.name in exclude:
            continue
        ref = r.alternatives[0] if r.alternatives else "ref"
        off = r.alternatives[1] if len(r.alternatives) > 1 else "offload"
        sites.append(Site(r.name, ref, off, tuple(r.alternatives[2:]),
                          members=tuple(r.meta.get("block_members", ()))))
    return GeneCoding(tuple(sites), tuple(destinations))


# ---------------------------------------------------------------------------
# cost model for cost-only destinations
# ---------------------------------------------------------------------------


def _trip_product(graph: RegionGraph, region: Region) -> int:
    """Static dynamic-trip estimate: own trip count times enclosing loops'."""
    trips = region.trip_count or 1 if region.kind == "loop" else 1
    r = region
    while r.parent is not None:
        r = graph.by_name(r.parent)
        if r.kind == "loop":
            trips *= r.trip_count or 1
    return trips


def site_modeled_cost_s(graph: RegionGraph, region: Region,
                        dest: Destination) -> float:
    """Deterministic modeled seconds for parking one region on ``dest``.

    Stub devices charge their launch + per-trip model; mesh destinations
    charge per-shard transfers plus a modeled collective for the axis
    (:func:`repro.core.transfer_planner.modeled_mesh_cost_s`), with the
    region's def/use sets standing in for byte volumes (1.0 each — the
    same unit-bytes convention the transfer objective uses)."""
    trips = _trip_product(graph, region)
    if isinstance(dest, MeshDestination):
        from repro.core import transfer_planner as tp
        return tp.modeled_mesh_cost_s(
            h2d_bytes=float(len(region.uses)),
            d2h_bytes=float(len(region.defs)),
            trips=trips, axis=dest.axis, n=dest.n)
    return dest.launch_overhead_s + trips * dest.per_trip_s


def modeled_cost_s(graph: RegionGraph, coding: GeneCoding,
                   values: Sequence[int],
                   mesh_executed: bool = False) -> float:
    """Deterministic modeled time for genes on cost-only destinations.

    Charged on top of the measured time of the chromosome (whose cost-only
    regions executed their reference path), so patterns that park work on a
    stub device pay that device's modeled latency in the fitness.

    Mesh genes charge the mesh cost model unless ``mesh_executed`` — the
    flag a frontend sets when its measured path genuinely decodes mesh
    destinations through shard_map (the jaxpr engine on a multi-device
    host), in which case the measurement already contains the real cost.
    An unavailable mesh (``is_cost_only``) charges the model regardless.
    """
    total = 0.0
    claimed = coding.claimed_members(values)
    for site, v in zip(coding.sites, values):
        if site.region in claimed:
            continue                 # the block adapter computes this region
        dest = get_destination(coding.destinations[int(v)])
        if isinstance(dest, MeshDestination):
            if mesh_executed and not dest.is_cost_only:
                continue             # really ran sharded: measured, not modeled
        elif not dest.is_cost_only:
            continue
        total += site_modeled_cost_s(graph, graph.by_name(site.region), dest)
    return total
